"""Roofline latency model f_L(chips, batch) properties."""
import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import ARCHS, get_config
from repro.core.latency_model import (CHIP_LEVELS, CostOverride, LatencyModel)


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("mode", ["prefill", "decode"])
def test_latency_weakly_decreasing_in_chips(arch, mode):
    lm = LatencyModel(get_config(arch), mode=mode,
                      seq=4096 if mode == "decode" else 128)
    lats = [lm.latency(c, 16) for c in CHIP_LEVELS]
    finite = [l for l in lats if math.isfinite(l)]
    assert len(finite) >= 3
    # weakly decreasing within 1% numerical slack
    for a, b in zip(finite, finite[1:]):
        assert b <= a * 1.01


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_costs_positive_and_batch_scaling(arch):
    lm = LatencyModel(get_config(arch), mode="prefill", seq=128)
    f1, h1, ar1, a2a1 = lm.costs(1)
    f16, h16, ar16, a2a16 = lm.costs(16)
    assert f1 > 0 and h1 > 0 and ar1 >= 0
    assert f16 > f1                     # flops scale with batch
    assert h16 >= h1                    # bytes at least weight-streaming


def test_knee_spread_matches_paper_structure():
    """Paper Table 6: knees spread over ~6%-100%, lightweight models low."""
    knees = {}
    for arch, cfg in ARCHS.items():
        lm = LatencyModel(cfg, mode="prefill", seq=128)
        knees[arch] = lm.knee_chips(16) / 256
    assert knees["granite-moe-3b-a800m"] < knees["yi-9b"]
    assert knees["whisper-small"] < knees["chameleon-34b"]
    assert min(knees.values()) <= 0.3
    assert max(knees.values()) >= 0.5
    assert sum(knees.values()) > 1.0     # multiplexing pressure exists


def test_min_chips_to_fit():
    lm = LatencyModel(get_config("chameleon-34b"), mode="prefill", seq=128)
    assert lm.min_chips_to_fit() >= 4          # 68 GB of bf16 weights
    assert not math.isfinite(lm.latency(1, 1))
    lm_small = LatencyModel(get_config("qwen2-0.5b"), mode="prefill", seq=128)
    assert lm_small.min_chips_to_fit() == 1


def test_override_replaces_analytic_costs():
    lm = LatencyModel(get_config("olmo-1b"), mode="prefill", seq=128,
                      override=CostOverride(flops=1e12, hbm_bytes=1e9,
                                            ar_bytes=1e8, a2a_bytes=0.0,
                                            batch=8))
    f, h, ar, a2a = lm.costs(16)
    assert f == pytest.approx(2e12)
    assert h == pytest.approx(2e9)
    assert ar == pytest.approx(2e8)


def test_decode_memory_bound_dense():
    """Decode at small batch must be memory-bound (weight streaming)."""
    cfg = get_config("deepseek-7b")
    lm = LatencyModel(cfg, mode="decode", seq=4096)
    flops, hbm, _, _ = lm.costs(8)
    c = 32
    t_comp_ideal = flops / (c * lm.hw.peak_flops)
    t_mem = hbm / (c * lm.hw.hbm_bw)
    assert t_mem > t_comp_ideal          # arithmetic intensity below ridge


def test_ssm_knee_lower_than_dense_peer():
    """mamba2-1.3b (attention-free) should right-size smaller than a dense
    model of similar scale at decode."""
    k_ssm = LatencyModel(get_config("mamba2-1.3b"), mode="decode",
                         seq=32768).knee_chips(32)
    k_dense = LatencyModel(get_config("yi-9b"), mode="decode",
                           seq=32768).knee_chips(32)
    assert k_ssm <= k_dense


@settings(max_examples=20, deadline=None)
@given(batch=st.integers(min_value=1, max_value=64),
       chips=st.sampled_from(CHIP_LEVELS),
       arch=st.sampled_from(sorted(ARCHS)))
def test_property_latency_positive_finite_or_inf(batch, chips, arch):
    lm = LatencyModel(get_config(arch), mode="prefill", seq=128)
    lat = lm.latency(chips, batch)
    assert lat > 0
    if chips >= lm.min_chips_to_fit(batch):
        assert math.isfinite(lat)
        assert lm.throughput(chips, batch) > 0
