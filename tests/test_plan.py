"""Declarative step-plan serving API (repro.serving.plan).

The load-bearing claim is PLAN EQUIVALENCE: any interleaving of chunk
sizes and preemption points yields per-request token streams bit-exact
with the unchunked, no-preemption path — chunked prefill rides the same
``decode_step`` the generation loop uses (teacher-forced), and recompute
preemption restarts a request from scratch, so greedy decode is
deterministic either way. Asserted per model family (dense / SSM /
hybrid / encoder-decoder; MoE's expert-capacity dropping is batch-shape
dependent and excluded, same caveat as packed prefill), plus a
hypothesis sweep over random chunk budgets and forced preemption points
with a seeded no-hypothesis sibling, a compile-count gate for the chunk
executables (O(log max_len), like packed prefill), and the bounded-
dispatch invariant (<= 3 model dispatches per tick).
"""
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.serving.engine import make_engine
from repro.serving.plan import (PlannerConfig, PrefillChunk, StepPlan,
                                StepPlanner, serve_ticks)
from repro.serving.request import Request, RequestQueue

FAMILIES = {
    "dense": "olmo-1b",
    "ssm": "mamba2-1.3b",
    "hybrid": "zamba2-7b",
    "encdec": "whisper-small",
}
CACHE_LEN = 32
N_SLOTS = 4
PAGE = 8


def _make_prompt(cfg, rid: int, length: int):
    rng = np.random.default_rng(1000 + rid)
    b = {"tokens": jnp.asarray(
        rng.integers(1, cfg.vocab_size, size=(1, length)).astype(np.int32))}
    if cfg.has_encoder:
        from repro.serving import modality
        b["enc_embeds"] = modality.audio_frames(cfg, 1)
    return b


@pytest.fixture(scope="module")
def engines():
    """One engine per (family, page budget) for the whole module — jit
    caches persist across tests, exactly like the pool's standby
    engines, so the suite compiles each executable once."""
    built = {}

    def get(family: str, pages=None):
        key = (family, pages)
        if key not in built:
            cfg = get_config(FAMILIES[family]).reduced()
            eng = make_engine(cfg, cache_len=CACHE_LEN).init_slots(
                N_SLOTS, paged=True, page_size=PAGE, total_pages=pages)
            built[key] = (cfg, eng)
        return built[key]

    return get


def _reset(eng):
    eng.release_all_slots()
    eng.reset_stats()
    if getattr(eng, "_draft", None) is not None:
        eng._draft.reset_stats()


def _workload(cfg, seed: int, n: int, prompt_range=(3, 20),
              budget_range=(2, 8)):
    rng = np.random.default_rng(seed)
    reqs, prompts = [], {}
    for i in range(n):
        p = int(rng.integers(*prompt_range))
        nt = int(rng.integers(*budget_range))
        reqs.append(Request(arrival=0.0, rid=i, model=cfg.name, slo=1e9,
                            n_tokens=nt, prompt_len=p))
        prompts[i] = _make_prompt(cfg, i, p)
    return reqs, prompts


def _serve(cfg, eng, reqs, prompts, *, chunk_tokens=0, lazy=False,
           planner_cls=StepPlanner, spec_k=0, spec_knee_batch=None,
           **planner_kw):
    _reset(eng)
    q = RequestQueue(cfg.name, slo=1e9)
    planner = planner_cls(eng, q, PlannerConfig(
        chunk_tokens=chunk_tokens, lazy=lazy, gen_len=4, spec_k=spec_k,
        spec_knee_batch=spec_knee_batch), **planner_kw)
    srv = serve_ticks(planner, reqs, lambda r: prompts[r.rid])
    assert not srv.truncated
    return {r: tuple(t) for r, t in planner.streams.items()}, planner, srv


# ---------------------------------------------------------------------------
# plan equivalence: chunked / lazy / preempted == unchunked, per family
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_chunked_prefill_streams_bit_exact(engines, family):
    """Every chunk-size interleaving of the same workload produces the
    identical per-request token streams as whole-prompt admission."""
    cfg, eng = engines(family)
    reqs, prompts = _workload(cfg, seed=7, n=6)
    base, _, _ = _serve(cfg, eng, reqs, prompts, chunk_tokens=0)
    assert base and all(len(t) for t in base.values())
    for ct in (3, 8):
        got, _, _ = _serve(cfg, eng, reqs, prompts, chunk_tokens=ct)
        assert got == base, f"{family} chunk_tokens={ct} diverged"


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_lazy_preemption_streams_bit_exact(engines, family):
    """Lazy page reservation under real pressure (tight pool → preempt +
    requeue + re-prefill) still yields the unchunked streams."""
    cfg, eng_base = engines(family)
    reqs, prompts = _workload(cfg, seed=3, n=8, budget_range=(10, 20),
                              prompt_range=(4, 12))
    base, _, _ = _serve(cfg, eng_base, reqs, prompts, chunk_tokens=0)
    cfg2, eng_tight = engines(family, pages=6)
    got, planner, _ = _serve(cfg2, eng_tight, reqs, prompts,
                             chunk_tokens=4, lazy=True)
    assert got == base, f"{family} lazy+chunked diverged"
    if eng_tight.paged:     # pure SSM has no pages to run out of
        assert planner.metrics.preemptions > 0
        assert planner.metrics.requeues == planner.metrics.preemptions


class _ForcedPreempt(StepPlanner):
    """Test harness: additionally preempt the newest resident at the
    given tick indices — arbitrary preemption points, not just
    page-pressure ones."""

    def __init__(self, *args, preempt_ticks=(), **kw):
        super().__init__(*args, **kw)
        self._tick = 0
        self._preempt_ticks = set(preempt_ticks)

    def build(self, now):
        plan = super().build(now)
        if self._tick in self._preempt_ticks and self._resident:
            v = self._pick_victim(excluded=set(plan.preemptions))
            if v is not None:
                self._preempt(v, plan, now)
        self._tick += 1
        return plan


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_forced_preemption_points_bit_exact(engines, family):
    """Preemption at arbitrary ticks — mid-decode AND mid-prefill — is
    invisible in the final streams (seeded sibling of the hypothesis
    sweep below, covering every family)."""
    cfg, eng = engines(family)
    reqs, prompts = _workload(cfg, seed=11, n=5)
    base, _, _ = _serve(cfg, eng, reqs, prompts, chunk_tokens=0)
    for ticks in ((2,), (1, 4, 9), (0, 3)):
        got, planner, _ = _serve(cfg, eng, reqs, prompts, chunk_tokens=3,
                                 planner_cls=_ForcedPreempt,
                                 preempt_ticks=ticks)
        assert got == base, f"{family} preempt@{ticks} diverged"
        assert planner.metrics.preemptions >= 1


def test_plan_interleavings_property():
    """Hypothesis sweep (one cheap family): random workloads × random
    chunk budgets × random preemption points all reproduce the
    unchunked, no-preemption streams bit-exactly."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    cfg = get_config(FAMILIES["dense"]).reduced()
    eng = make_engine(cfg, cache_len=CACHE_LEN).init_slots(
        N_SLOTS, paged=True, page_size=PAGE)
    baselines = {}

    @settings(max_examples=10, deadline=None, derandomize=True)
    @given(seed=st.integers(0, 3), chunk=st.integers(1, 12),
           preempts=st.lists(st.integers(0, 12), max_size=3))
    def check(seed, chunk, preempts):
        reqs, prompts = _workload(cfg, seed=seed, n=5)
        if seed not in baselines:
            baselines[seed] = _serve(cfg, eng, reqs, prompts,
                                     chunk_tokens=0)[0]
        got, _, _ = _serve(cfg, eng, reqs, prompts, chunk_tokens=chunk,
                           planner_cls=_ForcedPreempt,
                           preempt_ticks=preempts)
        assert got == baselines[seed]

    check()


# ---------------------------------------------------------------------------
# speculative ticks interleaved against preempt / chunk events (ISSUE 9)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def spec_engine():
    """Dense engine paired with a DIVERGENT same-shape draft (other init
    seed) — the adversarial speculation config: drafts are frequently
    wrong, so every sweep below exercises rejection + rollback, not just
    the all-accepted fast path."""
    import jax

    from repro.models.registry import build_model
    from repro.serving.engine import InferenceEngine

    cfg = get_config(FAMILIES["dense"]).reduced()
    eng = make_engine(cfg, cache_len=CACHE_LEN).init_slots(
        N_SLOTS, paged=True, page_size=PAGE)
    api = build_model(cfg)
    draft = InferenceEngine(api, api.init(jax.random.PRNGKey(99)),
                            cache_len=CACHE_LEN).init_slots(
        N_SLOTS, paged=False)
    eng.attach_draft(draft, spec_k=3)
    return cfg, eng


def test_spec_interleaved_with_preemption_bit_exact(spec_engine):
    """Seeded sibling with speculation ON: draft/verify rounds
    interleaved against forced preemption points and chunked prefill are
    invisible in the final streams — rollbacks, the draft-twin
    desync/re-init after a victim returns, and chunk continuations
    compose without leaking a token or a page."""
    cfg, eng = spec_engine
    reqs, prompts = _workload(cfg, seed=11, n=5)
    base, _, _ = _serve(cfg, eng, reqs, prompts, chunk_tokens=0)
    for ticks in ((2,), (1, 4, 9), (0, 3)):
        got, planner, _ = _serve(cfg, eng, reqs, prompts, chunk_tokens=3,
                                 spec_k=3, planner_cls=_ForcedPreempt,
                                 preempt_ticks=ticks)
        assert got == base, f"spec+preempt@{ticks} diverged"
        assert planner.metrics.preemptions >= 1
        assert eng.stats.spec_rounds > 0, "speculation never engaged"
        eng.check_page_invariants()
    assert eng.free_pages == eng.total_pages


def test_spec_interleavings_property(spec_engine):
    """Hypothesis sweep with speculation ON: random workloads × random
    chunk budgets × random preemption points × knee gating all reproduce
    the plain (unchunked, no-preemption, non-speculative) streams
    bit-exactly, with zero leaked pages. ``derandomize=True`` makes the
    sweep its own seeded replay — two runs of this test execute the
    identical example sequence against a module-scope engine."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    cfg, eng = spec_engine
    baselines = {}

    @settings(max_examples=10, deadline=None, derandomize=True)
    @given(seed=st.integers(0, 3), chunk=st.integers(1, 12),
           preempts=st.lists(st.integers(0, 12), max_size=3),
           knee=st.sampled_from([None, 2]))
    def check(seed, chunk, preempts, knee):
        reqs, prompts = _workload(cfg, seed=seed, n=5)
        if seed not in baselines:
            baselines[seed] = _serve(cfg, eng, reqs, prompts,
                                     chunk_tokens=0)[0]
        got, _, _ = _serve(cfg, eng, reqs, prompts, chunk_tokens=chunk,
                           spec_k=3, spec_knee_batch=knee,
                           planner_cls=_ForcedPreempt,
                           preempt_ticks=preempts)
        assert got == baselines[seed]
        eng.check_page_invariants()
        assert eng.free_pages == eng.total_pages

    check()


# ---------------------------------------------------------------------------
# compile discipline + bounded dispatches
# ---------------------------------------------------------------------------
def test_chunk_compile_count_gate():
    """CI gate: chunk continuations compile onto the SAME O(log max_len)
    (token bucket, row bucket, segment bucket) lattice as packed prefill
    — initial chunks ride the packed-prefill executables, and dense
    continuations reroute through the incremental chunk-attention
    executables (``_chunk_prefill_jit``), so however many distinct chunk
    shapes a stream produces, the executable count stays O(log) per axis
    (the same discipline as ``test_packed_prefill_compile_count_gate``)."""
    from repro.serving.engine import _packed_bucket, _pow2_at_least

    cfg = get_config(FAMILIES["dense"]).reduced()
    eng = make_engine(cfg, cache_len=CACHE_LEN).init_slots(
        N_SLOTS, paged=True, page_size=PAGE)
    rng = np.random.default_rng(0)
    n_chunks = n_incr = 0
    for trial in range(10):
        ct = int(rng.integers(1, 14))
        reqs, prompts = _workload(cfg, seed=trial, n=3,
                                  prompt_range=(2, 24), budget_range=(1, 3))
        _serve(cfg, eng, reqs, prompts, chunk_tokens=ct)
        n_chunks += eng.stats.chunk_prefills
        n_incr += eng.stats.incr_chunks
    assert n_chunks > 10                    # plenty of distinct shapes ran
    # dense continuations actually rerouted through the incremental path
    # (O(chunk) work instead of an O(L) recompute per continuation)
    assert n_incr > 0
    ckeys = set(eng._chunk_prefill_jit)
    assert ckeys and len(ckeys) <= 8, ckeys
    assert all(t == _packed_bucket(t) for t, _, _ in ckeys), ckeys
    assert all(r == _pow2_at_least(r) or r == eng.slot_len
               for _, r, _ in ckeys), ckeys
    assert all(s == _pow2_at_least(s) for _, _, s in ckeys), ckeys
    assert eng.jit_cache_sizes()["chunk_prefill"] >= len(ckeys)
    keys = set(eng._packed_prefill_jit)
    buckets = {t for t, _, _ in keys}
    rows = {r for _, r, _ in keys}
    segs = {s for _, _, s in keys}
    # every executable key sits on the half-pow2 / pow2 lattice ...
    assert all(b == _packed_bucket(b) for b in buckets), buckets
    assert all(r == _pow2_at_least(r) for r in rows), rows
    assert all(s == _pow2_at_least(s) for s in segs), segs
    # ... whose density is O(log) along each axis: <= 2 token buckets
    # and 1 row/segment bucket per octave, never one per chunk shape
    assert len(buckets) <= 2 * math.ceil(math.log2(max(buckets))) + 2
    assert len(rows) <= math.ceil(math.log2(max(rows))) + 2
    assert len(segs) <= math.ceil(math.log2(max(max(segs), 2))) + 2
    assert eng.jit_cache_sizes()["packed_prefill"] >= len(keys)


def test_execute_bounded_dispatches(engines):
    """One tick = at most one packed prefill + one chunk scan + one
    decode step, whatever the plan holds (the §6 tick-granularity
    invariant the plan API encodes)."""
    cfg, eng = engines("dense")
    _reset(eng)
    # resident decoder
    d0 = eng.insert(_make_prompt(cfg, 90, 4), n_tokens=8)
    # mid-prefill slot (first chunk of a long prompt)
    long_b = _make_prompt(cfg, 91, 16)
    plan0 = StepPlan(admissions=[PrefillChunk(
        rid=91, batch={"tokens": long_b["tokens"][:, :6]}, start=0,
        length=6, final=False, n_tokens=4,
        reserve_tokens=min(16 + 4, eng.slot_len))])
    r0 = eng.execute(plan0)
    s1 = r0.admitted[91]
    before = eng.stats
    n_pref, n_chunk, n_dec = (before.prefills, before.chunk_prefills,
                              before.decode_steps)
    plan = StepPlan(
        admissions=[
            PrefillChunk(rid=92, batch=_make_prompt(cfg, 92, 5), start=0,
                         length=5, final=True, n_tokens=4),
            # continuation carries the FULL prefix (prefix recompute)
            PrefillChunk(rid=91,
                         batch={"tokens": long_b["tokens"][:, :12]},
                         start=6, length=6, final=False, slot=s1),
        ],
        decodes=[d0])
    res = eng.execute(plan)
    assert res.dispatches == 3
    # one packed admission prefill + one packed chunk continuation
    assert eng.stats.prefills == n_pref + 2
    assert eng.stats.chunk_prefills == n_chunk + 1
    assert eng.stats.decode_steps == n_dec + 1       # ONE slot step
    assert d0 in res.tokens and len(res.tokens) == 1
    _reset(eng)


def test_masked_step_leaves_unstepped_slots_bit_identical(engines):
    """step(decodes=[a]) must not perturb slot b: b's subsequent stream
    equals the stream it produces with no interleaved a-steps at all."""
    cfg, eng = engines("dense")
    _reset(eng)
    pa, pb = _make_prompt(cfg, 80, 6), _make_prompt(cfg, 81, 9)
    sb = eng.insert(pb, n_tokens=5)
    ref = []
    for _ in range(5):
        tok, _ = eng.step([sb])
        ref.append(int(tok[sb]))
    _reset(eng)
    sa = eng.insert(pa, n_tokens=64)
    sb = eng.insert(pb, n_tokens=5)
    got = []
    for i in range(5):
        tok, _ = eng.step([sa])        # interleaved a-only steps
        tok, _ = eng.step([sa, sb])
        got.append(int(tok[sb]))
    assert got == ref
    _reset(eng)


# ---------------------------------------------------------------------------
# lazy reservation: strictly more residents at equal page budget
# ---------------------------------------------------------------------------
def test_lazy_reservation_admits_more_residents(engines):
    """At an identical page budget, lazy (prompt-only) reservation keeps
    strictly more sequences resident than up-front prompt+budget
    reservation, and completes the same work bit-exactly — preemption
    absorbs the overcommit."""
    cfg, _ = engines("dense")
    pages = 8
    reqs, prompts = _workload(cfg, seed=5, n=10, prompt_range=(4, 8),
                              budget_range=(12, 24))
    results = {}
    for mode in ("eager", "lazy"):
        eng = make_engine(cfg, cache_len=CACHE_LEN).init_slots(
            N_SLOTS, paged=True, page_size=PAGE, total_pages=pages)
        streams, planner, srv = _serve(cfg, eng, reqs, prompts,
                                       lazy=(mode == "lazy"))
        results[mode] = (streams, planner, srv)
    (s_e, p_e, srv_e), (s_l, p_l, srv_l) = (results["eager"],
                                            results["lazy"])
    assert s_l == s_e                       # same tokens out
    assert srv_l.peak_resident > srv_e.peak_resident
    assert p_l.metrics.preemptions > 0 and p_l.metrics.requeues > 0
    assert p_e.metrics.preemptions == 0


# ---------------------------------------------------------------------------
# planner admission gate details
# ---------------------------------------------------------------------------
def test_impossible_requests_are_dropped_loudly(engines):
    """A request that can never fit (prompt >= slot_len, or full
    residency above the whole pool) is dropped and counted, not spun on
    forever."""
    cfg, eng = engines("dense")
    _reset(eng)
    q = RequestQueue(cfg.name, slo=1e9)
    planner = StepPlanner(eng, q, PlannerConfig(gen_len=4))
    big = Request(arrival=0.0, rid=0, model=cfg.name, slo=1e9, n_tokens=4,
                  prompt_len=CACHE_LEN)
    ok = Request(arrival=0.0, rid=1, model=cfg.name, slo=1e9, n_tokens=2,
                 prompt_len=4)
    srv = serve_ticks(planner, [big, ok], lambda r: _make_prompt(
        cfg, r.rid, r.prompt_len))
    assert not srv.truncated
    assert q.dropped == 1 and q.violated == 1
    assert len(planner.streams[1]) == 2
    _reset(eng)


def test_head_reservation_clears_when_reserved_head_expires(engines):
    """Regression: a head reservation is head-scoped. When the reserved
    request expires (or otherwise stops being the head), its pages must
    be released to later admissions — a stale reservation would withhold
    them from every non-head request forever."""
    cfg, _ = engines("dense")
    eng = make_engine(cfg, cache_len=32).init_slots(
        4, paged=True, page_size=PAGE, total_pages=6)
    planner = StepPlanner(config=PlannerConfig(gen_len=8))
    q = RequestQueue(cfg.name, slo=1e9)
    prompt = {"tokens": jnp.ones((1, 8), jnp.int32)}
    # occupy 4 of 6 pages
    a1 = eng.insert(prompt, n_tokens=8)
    a2 = eng.insert(prompt, n_tokens=8)
    # large head B (4 pages) blocks and ages a reservation over 3 scans
    big = Request(arrival=0.0, rid=0, model=cfg.name, slo=0.5, n_tokens=24)
    q.push(big)
    for now in (0.0, 0.1, 0.2):
        # blocked head goes straight back to the queue each scan
        assert planner.select_admissible(eng, q, 8, 4, now, 8) == []
    assert planner._resv_rid == big.rid and planner._resv_pages >= 3
    # B expires; a1 frees (4 pages free); two smalls (2 pages each) must
    # BOTH admit — the dead head's reservation may not shadow them
    eng.free(a1)
    q.push(Request(arrival=1.0, rid=1, model=cfg.name, slo=10.0,
                   n_tokens=8))
    q.push(Request(arrival=1.1, rid=2, model=cfg.name, slo=10.0,
                   n_tokens=8))
    kept = planner.select_admissible(eng, q, 8, 4, now=2.0, gen_len=8)
    assert [r.rid for r, _ in kept] == [1, 2]
    assert q.dropped == 1                  # B, at its SLO
    assert planner._resv_rid is None
    eng.free(a2)


def test_tick_server_honors_arrival_times(engines):
    """Requests arriving mid-serve are admitted when they arrive — the
    tick plane rides the shared core event loop's arrival semantics."""
    cfg, eng = engines("dense")
    reqs, prompts = _workload(cfg, seed=13, n=4)
    base, _, _ = _serve(cfg, eng, reqs, prompts, chunk_tokens=0)
    staggered = [Request(arrival=i * 2.5e-3, rid=r.rid, model=r.model,
                         slo=r.slo, n_tokens=r.n_tokens,
                         prompt_len=r.prompt_len)
                 for i, r in enumerate(reqs)]
    got, _, srv = _serve(cfg, eng, staggered, prompts, chunk_tokens=4)
    assert not srv.truncated
    assert got == base
