"""Serving engine + request machinery + sliding-window cache correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.registry import build_model
from repro.serving.engine import make_engine
from repro.serving.request import Request, RequestGenerator, RequestQueue


def test_generate_shapes_and_determinism():
    cfg = get_config("olmo-1b").reduced()
    eng = make_engine(cfg, cache_len=64)
    batch = {"tokens": jnp.ones((2, 8), jnp.int32)}
    out1 = eng.generate(batch, 6)
    out2 = eng.generate(batch, 6)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_sliding_window_ring_cache_matches_full_for_short_seq():
    """While pos < window, the ring cache must behave exactly like a full
    cache: logits from windowed decode == full-attention decode."""
    import dataclasses
    cfg = get_config("yi-9b").reduced()
    cfg_win = dataclasses.replace(cfg, sliding_window=24)
    api_full = build_model(cfg)
    api_win = build_model(cfg_win)
    params = api_full.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 10), 0,
                              cfg.vocab_size)
    lf, cache_f = api_full.prefill(params, {"tokens": toks}, 40)
    lw, cache_w = api_win.prefill(params, {"tokens": toks}, 24)  # ring=window
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lw), atol=1e-5)
    tok = jnp.argmax(lf, -1).astype(jnp.int32)
    for _ in range(8):                            # still inside the window
        lf, cache_f = api_full.decode_step(params, tok, cache_f)
        lw, cache_w = api_win.decode_step(params, tok, cache_w)
        np.testing.assert_allclose(np.asarray(lf), np.asarray(lw), atol=1e-4)
        tok = jnp.argmax(lf, -1).astype(jnp.int32)


def test_ring_cache_wraps_beyond_window():
    """Past the window the ring keeps only the last W tokens and stays
    finite/deterministic."""
    import dataclasses
    cfg = dataclasses.replace(get_config("olmo-1b").reduced(),
                              sliding_window=8)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    toks = jnp.ones((1, 4), jnp.int32)
    logits, cache = api.prefill(params, {"tokens": toks}, 8)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(20):                           # wraps 2.5x
        logits, cache = api.decode_step(params, tok, cache)
        assert bool(jnp.isfinite(logits).all())
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert int(cache["pos"][0]) == 24


# ------------------------------------------------------------- requests --
def test_request_queue_slo_accounting():
    q = RequestQueue("m", slo=0.05)
    q.push(Request(arrival=0.0, rid=0, model="m", slo=0.05))
    q.push(Request(arrival=0.01, rid=1, model="m", slo=0.05))
    batch = q.pop_batch(10, now=0.02)
    assert len(batch) == 2
    q.complete(batch, finish_time=0.04)           # within both deadlines
    assert q.violated == 0
    q.push(Request(arrival=0.0, rid=2, model="m", slo=0.05))
    batch = q.pop_batch(10, now=0.1)              # expired before pop
    assert batch == []
    assert q.violated == 1 and q.dropped == 1


def test_request_queue_edf_order():
    q = RequestQueue("m", slo=1.0)
    q.push(Request(arrival=0.5, rid=0, model="m", slo=1.0))
    q.push(Request(arrival=0.1, rid=1, model="m", slo=1.0))
    batch = q.pop_batch(1, now=0.6)
    assert batch[0].rid == 1                      # oldest first


def test_request_queue_records_completion_latency():
    q = RequestQueue("m", slo=0.05)
    q.push(Request(arrival=0.0, rid=0, model="m", slo=0.05))
    q.push(Request(arrival=0.01, rid=1, model="m", slo=0.05))
    q.complete(q.pop_batch(10, now=0.02), finish_time=0.04)
    assert q.latencies == pytest.approx([0.04, 0.03])
    assert q.latency_quantile(0.5) == pytest.approx(0.03)
    assert q.latency_quantile(0.99) == pytest.approx(0.04)
    assert q.late == 0


def test_request_queue_late_completion_is_violation():
    """A request SERVED past its deadline is an SLO miss, distinct from
    one dropped while queued."""
    q = RequestQueue("m", slo=0.05)
    q.push(Request(arrival=0.0, rid=0, model="m", slo=0.05))
    batch = q.pop_batch(1, now=0.04)              # popped in time ...
    q.complete(batch, finish_time=0.09)           # ... but finished late
    assert q.completed == 1
    assert q.late == 1 and q.violated == 1 and q.dropped == 0
    assert q.latencies == pytest.approx([0.09])


def test_latency_quantile_empty_queue_default():
    import math
    q = RequestQueue("m", slo=0.05)
    assert math.isnan(q.latency_quantile(0.5))
    assert q.latency_quantile(0.5, default=0.0) == 0.0


def test_generator_rate_and_determinism():
    g1 = RequestGenerator("m", rate_per_s=1000, slo=0.1, seed=5)
    g2 = RequestGenerator("m", rate_per_s=1000, slo=0.1, seed=5)
    r1 = g1.until(1.0)
    r2 = g2.until(1.0)
    assert len(r1) == len(r2)
    assert [r.arrival for r in r1] == [r.arrival for r in r2]
    assert 800 <= len(r1) <= 1200                 # ~rate·duration
    # arrivals strictly increasing
    ts = [r.arrival for r in r1]
    assert all(a < b for a, b in zip(ts, ts[1:]))


def test_generator_rate_change():
    g = RequestGenerator("m", rate_per_s=100, slo=0.1, seed=1)
    n1 = len(g.until(1.0))
    g.set_rate(1000)
    n2 = len(g.until(2.0))
    assert n2 > 5 * n1
