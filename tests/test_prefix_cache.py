"""Radix prompt cache (ISSUE 8): prefix sharing over paged KV with COW.

Two layers of proof:

* **Tree unit tests** drive ``PrefixCache`` directly over a bare
  ``PageAllocator``: longest-prefix match at page granularity, match-time
  pinning and ``release_hit``, insert dedup and page-boundary splits,
  partial-page matches returning a COW source, the ``min_covered``
  hit-quality floor (rejects pin nothing), LRU eviction that never
  victimizes a leaf whose pages are all still row-shared, and flush.

* **Serving tests** prove the load-bearing claim on a real engine: a
  shared-prefix stream served with the cache ON emits BIT-IDENTICAL
  greedy streams to the cache-off run while dispatching strictly fewer
  prefill tokens, reusing only warmed executables (zero recompiles);
  cold cache pages are evicted before any live resident is preempted;
  ``recover()`` keeps the hot radix subtree while its conservation audit
  accounts every page (free + cache-held == total); and incapable
  families (SSM state is not page-aliasable) refuse the cache loudly
  while the pool skips them gracefully.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.serving.engine import make_engine
from repro.serving.kv_cache import PageAllocator
from repro.serving.plan import PlannerConfig, StepPlanner, serve_ticks
from repro.serving.prefix_cache import PrefixCache
from repro.serving.request import Request, RequestQueue

CACHE_LEN = 32
N_SLOTS = 4
PAGE = 8
MODEL = "olmo-1b"


# ---------------------------------------------------------------------------
# tree unit tests: PrefixCache over a bare allocator, no engine
# ---------------------------------------------------------------------------
def _tree(num_pages=12, ps=4):
    a = PageAllocator(num_pages)
    return a, PrefixCache(a, ps)


def _toks(*vals):
    return list(vals)


def test_match_on_empty_tree_is_miss():
    a, c = _tree()
    assert c.match([1, 2, 3, 4, 5]) is None
    assert c.stats.misses == 1 and c.stats.hits == 0
    assert a.free_pages == 12
    c.check_invariants()


def test_insert_match_pin_release_roundtrip():
    a, c = _tree(ps=4)
    pages = a.alloc(2)                    # the "registering row" owns these
    c.insert(_toks(1, 2, 3, 4, 5, 6, 7, 8), pages)
    assert c.held_pages == 2
    assert all(a.refcount(p) == 2 for p in pages)   # row + tree
    hit = c.match(_toks(1, 2, 3, 4, 5, 6, 7, 8, 9, 9), max_covered=9)
    assert hit is not None and hit.covered == 8
    assert hit.pages == tuple(pages) and hit.cow_src is None
    assert all(a.refcount(p) == 3 for p in pages)   # + match pin
    c.release_hit(hit)
    assert all(a.refcount(p) == 2 for p in pages)
    # registering row frees; the tree's hold keeps the pages resident
    assert a.release(pages) == 0
    assert all(a.refcount(p) == 1 for p in pages)
    c.check_invariants()


def test_insert_dedupes_and_splits_at_page_boundary():
    a, c = _tree(ps=4)
    p1 = a.alloc(3)
    base = _toks(1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12)
    assert c.insert(base, p1) == 3
    # identical prefix: nothing new retained
    p2 = a.alloc(3)
    assert c.insert(base, p2) == 0
    a.free(p2)
    # diverge after page 2: the edge splits at the boundary and both
    # suffixes stay matchable
    p3 = a.alloc(3)
    other = _toks(1, 2, 3, 4, 5, 6, 7, 8, 90, 91, 92, 93)
    assert c.insert(other, p3) == 1       # only the divergent page is new
    assert c.held_pages == 4
    h1 = c.match(base + [99])
    h2 = c.match(other + [99])
    assert h1.covered == 12 and h1.pages == tuple(p1)
    assert h2.covered == 12 and h2.pages == (p1[0], p1[1], p3[2])
    c.release_hit(h1)
    c.release_hit(h2)
    c.check_invariants()
    # p3's first two pages were never retained by the tree
    assert a.release(p3[:2]) == 2


def test_partial_page_match_returns_cow_source():
    a, c = _tree(ps=4)
    pages = a.alloc(2)
    c.insert(_toks(1, 2, 3, 4, 5, 6, 7, 8), pages)
    # diverges inside page 2 after two tokens: page 1 aliased, page 2 COW
    hit = c.match(_toks(1, 2, 3, 4, 5, 6, 70, 71, 72))
    assert hit.covered == 6
    assert hit.pages == (pages[0],) and hit.cow_src == pages[1]
    assert a.refcount(pages[0]) == 3      # row + tree + pin
    assert a.refcount(pages[1]) == 3      # row + tree + COW pin
    c.release_hit(hit)
    assert c.stats.cow_hits == 1
    c.check_invariants()


def test_min_covered_floor_rejects_and_pins_nothing():
    a, c = _tree(ps=4)
    pages = a.alloc(1)
    c.insert(_toks(1, 2, 3, 4), pages)
    refs = {p: a.refcount(p) for p in pages}
    assert c.match(_toks(1, 2, 3, 4, 5), min_covered=5) is None
    assert c.stats.misses == 1 and c.stats.hits == 0
    assert {p: a.refcount(p) for p in pages} == refs
    # at the floor it is a hit again
    hit = c.match(_toks(1, 2, 3, 4, 5), min_covered=4)
    assert hit is not None and hit.covered == 4
    c.release_hit(hit)


def test_evict_lru_skips_row_shared_leaves():
    a, c = _tree(num_pages=12, ps=4)
    p_cold = a.alloc(1)
    c.insert(_toks(1, 2, 3, 4), p_cold)          # colder (inserted first)
    p_warm = a.alloc(1)
    c.insert(_toks(9, 9, 9, 9), p_warm)
    # the cold leaf is still row-shared: evicting it would free nothing,
    # so eviction must take the warmer but freeable leaf instead
    a.release(p_warm)                             # row gone, tree ref only
    assert c.evict(1) == 1
    assert c.stats.evictions == 1 and c.stats.evicted_pages == 1
    hit = c.match(_toks(1, 2, 3, 4))
    assert hit is not None                        # cold leaf survived
    c.release_hit(hit)
    # once the row releases, the leaf becomes a victim and actually frees
    a.release(p_cold)
    assert c.evict(1) == 1
    assert c.held_pages == 0
    assert a.free_pages == 12
    c.check_invariants()


def test_peek_is_read_only_and_page_granular():
    """``peek`` (ISSUE 9: the admission-ordering probe) reports the
    whole-page covered length like ``match`` would, but is STRICTLY
    read-only: no clock tick, no LRU touch, no stats, no pins — probing
    N queued requests per tick must not perturb eviction order or leak
    references."""
    a, c = _tree(ps=4)
    pages = a.alloc(2)
    c.insert(_toks(1, 2, 3, 4, 5, 6, 7, 8), pages)
    child = next(iter(c._root.children.values()))
    clock, lu = c._clock, child.last_used
    stats = dataclasses.replace(c.stats)
    refs = {p: a.refcount(p) for p in pages}
    assert c.peek(_toks(1, 2, 3, 4, 5, 6, 7, 8, 9)) == 8
    assert c.peek(_toks(1, 2, 3, 4, 5, 6, 7, 8)) == 8
    # max_covered truncates to whole pages, like match's page walk
    assert c.peek(_toks(1, 2, 3, 4, 5, 6, 7, 8), max_covered=7) == 4
    # mid-page divergence: only the whole matching page counts (no COW
    # source from a probe — peek pins nothing)
    assert c.peek(_toks(1, 2, 3, 4, 5, 6, 70, 71)) == 4
    assert c.peek(_toks(9, 9, 9, 9)) == 0
    assert c.peek(_toks(1, 2)) == 0               # shorter than a page
    assert c._clock == clock and child.last_used == lu
    assert c.stats == stats
    assert {p: a.refcount(p) for p in pages} == refs
    c.check_invariants()


def test_flush_releases_every_hold():
    a, c = _tree(ps=4)
    p1, p2 = a.alloc(2), a.alloc(1)
    c.insert(_toks(1, 2, 3, 4, 5, 6, 7, 8), p1)
    c.insert(_toks(7, 7, 7, 7), p2)
    a.release(p1)
    a.release(p2)                                 # rows gone, tree holds 3
    assert a.free_pages == 9
    assert c.flush() == 3
    assert a.free_pages == 12 and c.held_pages == 0
    assert c.match(_toks(1, 2, 3, 4, 5)) is None
    c.check_invariants()


# ---------------------------------------------------------------------------
# serving tests: one warmed dense engine, cache on vs off
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def engine():
    cfg = get_config(MODEL).reduced()
    eng = make_engine(cfg, cache_len=CACHE_LEN).init_slots(
        N_SLOTS, paged=True, page_size=PAGE)
    assert eng.prefix_cache_capable()
    eng.enable_prefix_cache()
    eng.warm_prefix_ops()
    return cfg, eng


def _shared_workload(cfg, seed, n, template_lens=(20, 8), budgets=(3, 7)):
    """Heavy-tailed shared-prefix stream; template length 20 is not a
    page multiple, so some hits diverge mid-page and exercise COW."""
    rng = np.random.default_rng(seed)
    temps = [rng.integers(1, cfg.vocab_size, size=s).astype(np.int32)
             for s in template_lens]
    reqs, prompts = [], {}
    for i in range(n):
        t = temps[int(rng.integers(0, len(temps)))]
        tail = rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(2, 6))).astype(np.int32)
        toks = np.concatenate([t, tail])
        reqs.append(Request(arrival=0.0, rid=i, model=cfg.name, slo=1e9,
                            n_tokens=int(rng.integers(*budgets)),
                            prompt_len=len(toks)))
        prompts[i] = {"tokens": jnp.asarray(toks[None, :])}
    return reqs, prompts


def _serve(cfg, eng, reqs, prompts, *, prefix_cache=False, **planner_kw):
    eng.release_all_slots()               # frees rows AND flushes the cache
    eng.reset_stats()
    for r in reqs:
        r.state = "pending"
    planner = StepPlanner(eng, RequestQueue(cfg.name, slo=1e9),
                          PlannerConfig(gen_len=4, prefix_cache=prefix_cache,
                                        **planner_kw))
    srv = serve_ticks(planner, reqs, lambda r: prompts[r.rid],
                      stall_limit=50)
    assert not srv.truncated
    # drain invariant under sharing: every page is either free or held
    # by the cache, and the full refcount audit passes
    held = eng.prefix_cache.held_pages if eng.prefix_cache else 0
    assert eng.free_pages + held == eng.total_pages
    eng.check_page_invariants()
    if eng.prefix_cache:
        eng.prefix_cache.check_invariants()
    streams = {r: tuple(t) for r, t in planner.streams.items()}
    return streams, dataclasses.replace(eng.stats), planner, srv


def test_serve_bit_exact_with_fewer_prefill_tokens(engine):
    """The acceptance bar: cache-on greedy streams are BIT-EXACT with
    cache-off while admission prefill tokens drop, hits/COW/teacher-forced
    counters surface, and nothing recompiles."""
    cfg, eng = engine
    reqs, prompts = _shared_workload(cfg, seed=3, n=10)
    base, st_off, _, _ = _serve(cfg, eng, reqs, prompts)
    jit_before = eng.jit_cache_sizes()
    got, st_on, planner, _ = _serve(cfg, eng, reqs, prompts,
                                    prefix_cache=True)
    assert got == base
    assert st_on.prefill_tokens < st_off.prefill_tokens
    assert st_on.prefix_hits > 0
    assert st_on.prefix_hit_tokens > 0
    assert st_on.cow_copies > 0           # template 20 diverges mid-page
    assert st_on.forced_catchup_tokens > 0
    assert eng.jit_cache_sizes() == jit_before, "prefix cache recompiled"


def test_chunked_admission_unaffected_by_hits(engine):
    """Hits ride whole-prompt-style admission (zero-cost leading chunk +
    teacher-forced tail); chunked prefill for misses coexists and the
    streams still match the cache-off chunked run."""
    cfg, eng = engine
    reqs, prompts = _shared_workload(cfg, seed=11, n=8)
    base, _, _, _ = _serve(cfg, eng, reqs, prompts, chunk_tokens=3)
    got, st_on, _, _ = _serve(cfg, eng, reqs, prompts, chunk_tokens=3,
                              prefix_cache=True)
    assert got == base
    assert st_on.prefix_hits > 0


def test_cold_cache_evicted_before_preemption(engine):
    """Page pressure from new admissions evicts cold radix nodes first;
    no live resident is preempted while the cache can still pay."""
    cfg, eng = engine
    rng = np.random.default_rng(5)
    reqs, prompts = [], {}
    # distinct long prompts: every admission misses, registrations pile
    # pages into the cache, later waves must reclaim them to admit
    for i in range(8):
        toks = rng.integers(1, cfg.vocab_size, size=22).astype(np.int32)
        reqs.append(Request(arrival=0.0, rid=i, model=cfg.name, slo=1e9,
                            n_tokens=4, prompt_len=len(toks)))
        prompts[i] = {"tokens": jnp.asarray(toks[None, :])}
    base, _, _, _ = _serve(cfg, eng, reqs, prompts)
    got, _, planner, _ = _serve(cfg, eng, reqs, prompts, prefix_cache=True)
    assert got == base
    assert eng.prefix_cache.stats.evictions > 0, \
        "page pressure never evicted the cache"
    assert planner.metrics.preemptions == 0, \
        "resident preempted while cold cache pages were available"


def test_recover_persists_hot_nodes_and_conserves_pages(engine):
    """ISSUE 10 satellite: ``recover()`` keeps the hot radix subtree
    (``retain_recent``) instead of flushing — a mid-run engine reset
    drops slot state but not the warmed working set — and its
    conservation audit accounts the survivors: free + cache-held ==
    total. A stale tree (everything past ``prefix_hot_window``) still
    prunes to nothing."""
    cfg, eng = engine
    reqs, prompts = _shared_workload(cfg, seed=17, n=6)
    _serve(cfg, eng, reqs, prompts, prefix_cache=True)
    held = eng.prefix_cache.held_pages
    assert held > 0                           # registrations persist
    eng.recover()
    # recently-used nodes survive the reset; every non-cache page is free
    assert eng.prefix_cache.held_pages > 0
    assert (eng.free_pages + eng.prefix_cache.held_pages
            == eng.total_pages)
    eng.check_page_invariants()
    # a fresh serve over the same templates HITS the persisted nodes
    # immediately (cache already warm — no same-run registration needed)
    hits_before = eng.prefix_cache.stats.hits
    planner = StepPlanner(eng, RequestQueue(cfg.name, slo=1e9),
                          PlannerConfig(gen_len=4, prefix_cache=True))
    reqs2, prompts2 = _shared_workload(cfg, seed=17, n=4)
    serve_ticks(planner, reqs2, lambda r: prompts2[r.rid], stall_limit=50)
    assert eng.prefix_cache.stats.hits > hits_before, \
        "persisted nodes never served a hit after recovery"
    # ...and an engine whose cache went cold prunes it all at recover()
    eng.prefix_cache._clock += eng.prefix_hot_window + 1
    eng.recover()
    assert eng.prefix_cache.held_pages == 0
    assert eng.free_pages == eng.total_pages
    eng.release_all_slots()


def test_same_tick_shared_prefills_dedup_to_canonical_pages(engine):
    """ISSUE 10 satellite: identical-prefix prompts admitted in the SAME
    tick all prefill (none can hit a cache the others have not registered
    yet), but at registration the later rows' leading full pages are
    repointed onto the first registrant's canonical pages and the
    duplicates freed — cross-request dedup at insert time — with streams
    bit-exact vs the cache-off run."""
    cfg, eng = engine
    rng = np.random.default_rng(29)
    shared = rng.integers(1, cfg.vocab_size, size=16).astype(np.int32)
    reqs, prompts = [], {}
    for i in range(3):
        tail = rng.integers(1, cfg.vocab_size, size=3 + i).astype(np.int32)
        toks = np.concatenate([shared, tail])
        reqs.append(Request(arrival=0.0, rid=i, model=cfg.name, slo=1e9,
                            n_tokens=4, prompt_len=len(toks)))
        prompts[i] = {"tokens": jnp.asarray(toks[None, :])}
    base, st_off, _, _ = _serve(cfg, eng, reqs, prompts)
    assert st_off.dedup_pages == 0        # counter is cache-gated
    jit_before = eng.jit_cache_sizes()
    got, st_on, _, _ = _serve(cfg, eng, reqs, prompts, prefix_cache=True)
    assert got == base
    # 16 shared tokens = 2 full pages; the 2nd and 3rd registrants each
    # release their duplicate pair when repointed onto the canonical pair
    assert st_on.dedup_pages == 4
    assert eng.jit_cache_sizes() == jit_before    # repoint never compiles


def test_select_admissible_prefers_cache_hot_prefixes(engine):
    """ISSUE 9 satellite: with the cache on, the admission gate
    stable-sorts cache-HOT requests (read-only ``peek`` covers the
    ``prefix_min_frac`` floor) ahead of cold ones within the admitted
    batch — a hot admission aliases pages instead of prefilling, so
    serving it first spends strictly less of the pool. Pop order is
    unchanged: every request still admits this wave, hot or not."""
    cfg, eng = engine
    rng = np.random.default_rng(21)
    temp = rng.integers(1, cfg.vocab_size, size=16).astype(np.int32)

    def prompt(tail_seed, hot):
        r2 = np.random.default_rng(tail_seed)
        head = temp if hot else r2.integers(
            1, cfg.vocab_size, size=16).astype(np.int32)
        tail = r2.integers(1, cfg.vocab_size, size=4).astype(np.int32)
        return {"tokens": jnp.asarray(
            np.concatenate([head, tail])[None, :])}

    # warm: one served templated request registers temp's 2 full pages
    warm = [Request(arrival=0.0, rid=0, model=cfg.name, slo=1e9,
                    n_tokens=2, prompt_len=20)]
    _serve(cfg, eng, warm, {0: prompt(100, hot=True)}, prefix_cache=True)
    assert eng.prefix_cache.held_pages >= 2
    stats = dataclasses.replace(eng.prefix_cache.stats)

    # fresh planner over the warm engine: cold, hot, cold, hot
    q = RequestQueue(cfg.name, slo=1e9)
    planner = StepPlanner(eng, q, PlannerConfig(gen_len=4,
                                                prefix_cache=True))
    order = [(1, False), (2, True), (3, False), (4, True)]
    for rid, hot in order:
        planner.submit(Request(arrival=0.0, rid=rid, model=cfg.name,
                               slo=1e9, n_tokens=2, prompt_len=20),
                       prompt(200 + rid, hot))
    kept = planner.select_admissible(eng, q, prompt_len=20, max_batch=4,
                                     now=0.0, gen_len=4)
    assert [r.rid for r, _ in kept] == [2, 4, 1, 3]
    assert len(q) == 0                    # pop order / quota unchanged
    # the probe was read-only: no hit/miss/pin accounting moved
    assert eng.prefix_cache.stats == stats
    eng.prefix_cache.check_invariants()


def test_incapable_family_refuses_cache():
    """SSM state folds the whole prefix into non-shareable per-row state:
    the engine refuses loudly; best-effort callers (the pool) gate on
    ``prefix_cache_capable`` instead."""
    cfg = get_config("mamba2-1.3b").reduced()
    eng = make_engine(cfg, cache_len=16).init_slots(2, paged=True,
                                                    page_size=8)
    assert not eng.prefix_cache_capable()
    with pytest.raises(ValueError, match="prefix cache"):
        eng.enable_prefix_cache()
    assert eng.prefix_cache is None
    eng.warm_prefix_ops()                     # no-op without a cache
