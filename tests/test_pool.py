"""Serving control plane: policy conformance over real engines + metrics.

The conformance suite runs each policy family over a scripted (seeded)
arrival trace against the SAME warmed EnginePool of real jitted slot
engines and asserts the §6 invariants hold on the real data plane exactly
as they do in the analytic simulator: no oversubscription, no starved
model, monotone served counts, and zero recompilation while serving.
"""
import math

import pytest

from repro.core.scheduler import POLICIES, SchedView, chips_for_frac
from repro.core.simulator import RunRequest
from repro.serving.controller import (Controller, ControllerConfig,
                                      make_generators)
from repro.serving.metrics import jain_index, percentile
from repro.serving.pool import build_pool
from repro.serving.request import Request

MODELS = ["qwen2-0.5b", "olmo-1b", "mamba2-1.3b"]
RATE = 1500.0
DURATION = 0.03
GEN_LEN = 3


@pytest.fixture(scope="module")
def pool():
    """One warmed pool for the whole module — standby engines compile once
    and every policy run reuses them (exactly how the bench works)."""
    return build_pool(MODELS, request_rate=RATE, base_slots=2, cache_len=32)


def _serve(pool, policy_name, *, rate=RATE, duration=DURATION, seed0=0):
    pool.reset()
    policy = POLICIES[policy_name](pool.profiles)
    gens = make_generators(pool, rate, seed0=seed0)
    ctl = Controller(pool, policy, gens,
                     ControllerConfig(duration=duration, gen_len=GEN_LEN))
    return ctl, ctl.run()


# ------------------------------------------------------- policy conformance
@pytest.mark.parametrize("policy", ["temporal", "gslice", "maxmin", "dstack"])
def test_policy_conformance_on_real_engines(pool, policy):
    ctl, res = _serve(pool, policy)
    # no oversubscription: aggregate granted chip fraction never exceeded 1
    assert not ctl.oversubscribed, f"{policy} oversubscribed the pod"
    assert ctl.max_alloc <= 1.0 + 1e-6
    # no starved model: every hosted model completed work
    for n, m in res.per_model.items():
        assert m.completed > 0, f"{n} starved under {policy}"
        assert m.runs > 0
    # served counts are cumulative and monotone
    counts = [c for _, c in ctl.served_timeline]
    assert counts == sorted(counts)
    assert counts and counts[-1] == res.total_completed
    # bookkeeping is consistent with the queues
    assert res.total_completed == sum(
        q.completed for q in pool.queues.values())
    assert 0.0 <= res.occupancy <= 1.0 + 1e-6
    assert res.steps > 0 and res.wall_s > 0
    assert not res.truncated


def test_fixed_batch_mps_may_oversubscribe_but_serves(pool):
    ctl, res = _serve(pool, "fixed_batch_mps")
    # MPS models uncontrolled sharing: admissions are explicitly flagged
    # oversubscribe, so the invariant flag must NOT trip ...
    assert not ctl.oversubscribed
    # ... and all models still make progress
    assert all(m.completed > 0 for m in res.per_model.values())


def test_pool_run_is_deterministic(pool):
    _, r1 = _serve(pool, "dstack")
    _, r2 = _serve(pool, "dstack")
    assert {n: m.completed for n, m in r1.per_model.items()} \
        == {n: m.completed for n, m in r2.per_model.items()}
    assert r1.total_violated == r2.total_violated
    assert r1.duration == r2.duration


def test_no_recompilation_while_serving(pool):
    """The acceptance bar: standby allocations are compiled once, up
    front; serving any policy afterwards must not grow any jit cache."""
    _serve(pool, "temporal")
    before = pool.jit_cache_sizes()
    for policy in ("maxmin", "dstack"):
        _serve(pool, policy)
    assert pool.jit_cache_sizes() == before


def test_spatial_policies_beat_temporal_on_pool(pool):
    """The paper's core claim, end to end on real engines: spatial packing
    (D-STACK) outperforms pure temporal sharing on the same workload."""
    _, r_t = _serve(pool, "temporal")
    _, r_d = _serve(pool, "dstack")
    assert r_d.throughput() > r_t.throughput()
    assert r_d.total_violated <= r_t.total_violated


def test_drain_mode_backstop_terminates(pool):
    """A drain run whose policy keeps waking but never gets anything
    admitted (here: it plans runs for an unknown model while a hosted
    model's queue is non-empty) must exit at max_time, like the
    simulator — not spin forever."""
    pool.reset()

    class Stubborn:
        name = "stubborn"

        def plan(self, now, view):
            return [RunRequest("no-such-model", chips=8, batch=1)]

        def next_wakeup(self, now):
            return now + 0.01

    pool.push(Request(arrival=0.0, rid=0, model=sorted(pool.hosts)[0],
                      slo=1.0))
    ctl = Controller(pool, Stubborn(), [],
                     ControllerConfig(drain=True, duration=0.0,
                                      arrival_horizon=0.01, max_time=0.25))
    res = ctl.run()
    assert res.total_completed == 0
    assert res.steps == 0
    assert res.truncated          # a backstopped run is flagged as such
    pool.reset()


# ---------------------------------------------------- admission starvation
def test_pop_admissible_bypass_is_bounded_by_slo_expiry():
    """Regression for the ROADMAP anti-starvation follow-on: small
    requests may bypass a page-blocked large one (packing over strict
    FIFO), but the bypassed request cannot starve past its SLO — at its
    deadline the next admission scan drops and counts it, so the bypass
    window is exactly the request's remaining SLO budget."""
    pool = build_pool(["olmo-1b"], base_slots=4, cache_len=32,
                      pages={"olmo-1b": 5})
    pool.reset()
    name = sorted(pool.hosts)[0]
    # A small (2 pages), B large (4 pages), C small (2 pages); pool = 5
    pool.push(Request(arrival=0.0, rid=0, model=name, slo=10.0, n_tokens=8))
    pool.push(Request(arrival=1e-5, rid=1, model=name, slo=0.4, n_tokens=24))
    pool.push(Request(arrival=2e-5, rid=2, model=name, slo=10.0, n_tokens=8))
    run = pool.admit(RunRequest(name, chips=4096, batch=3), 0.0, GEN_LEN)
    # C bypassed the page-blocked B; B went back to the queue, counted once
    assert run is not None and run.batch == 2
    assert len(pool.queues[name]) == 1
    assert pool._metrics[name].blocked_on_memory == 1
    while not pool.step_run(run, 0.1):
        pass
    # a second pre-deadline admission with pages free admits B normally —
    # bypass is opportunistic packing, not a priority demotion ...
    run2 = pool.admit(RunRequest(name, chips=4096, batch=1), 0.2, GEN_LEN)
    assert run2 is not None
    assert [r.rid for r in run2.slots.values()] == [1]   # B, FIFO head
    while not pool.step_run(run2, 0.3):
        pass
    # ... and a bypassed request that DOES reach its deadline is dropped
    # and counted at the next scan, never silently starved forever
    pool.push(Request(arrival=0.3, rid=4, model=name, slo=0.05, n_tokens=24))
    pool.push(Request(arrival=0.31, rid=5, model=name, slo=10.0, n_tokens=8))
    q = pool.queues[name]
    run3 = pool.admit(RunRequest(name, chips=4096, batch=1), 1.0, GEN_LEN)
    assert run3 is not None
    assert [r.rid for r in run3.slots.values()] == [5]
    assert q.dropped == 1 and q.violated == 1            # rid=4, at its SLO
    while not pool.step_run(run3, 1.1):
        pass
    pool.reset()


def test_head_reservation_ages_for_page_blocked_fifo_head():
    """Anti-starvation follow-on to the SLO-expiry bound above: a
    page-blocked large request at the FIFO head accrues a page
    reservation that AGES (one page per planning scan), so a steady
    stream of small requests stops re-snatching every freed page and the
    large request admits long before its SLO backstop. Compared head-on:
    the same tight-pool workload served with and without reservation —
    with it, the large request finishes before the small-request stream
    is exhausted; without it, every small bypasses first."""
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.serving.engine import make_engine
    from repro.serving.plan import PlannerConfig, StepPlanner, serve_ticks
    from repro.serving.request import RequestQueue

    cfg = get_config("olmo-1b").reduced()
    name = cfg.name

    def serve(head_reservation: bool):
        eng = make_engine(cfg, cache_len=32).init_slots(
            4, paged=True, page_size=8, total_pages=5)
        q = RequestQueue(name, slo=1e9)
        completion_order = []

        class Rec(StepPlanner):
            def observe(self, res, now):
                for req in super().observe(res, now):
                    completion_order.append(req.rid)
                return []

        planner = Rec(eng, q, PlannerConfig(
            gen_len=4, head_reservation=head_reservation))
        # rid 0: small head-of-line filler (2 pages); rid 1: LARGE (4
        # pages — blocked while anything else is resident); rid 2..7:
        # a steady small stream (2 pages each)
        reqs = [Request(arrival=0.0, rid=0, model=name, slo=1e9,
                        n_tokens=8, prompt_len=2),
                Request(arrival=1e-5, rid=1, model=name, slo=1e9,
                        n_tokens=30, prompt_len=2)]
        reqs += [Request(arrival=2e-5 + i * 1e-5, rid=2 + i, model=name,
                         slo=1e9, n_tokens=8, prompt_len=2)
                 for i in range(6)]
        prompts = {r.rid: {"tokens": jnp.ones((1, 2), jnp.int32)}
                   for r in reqs}
        srv = serve_ticks(planner, reqs, lambda r: prompts[r.rid])
        assert not srv.truncated
        assert sorted(completion_order) == [r.rid for r in reqs]
        return completion_order.index(1)

    with_resv = serve(True)
    without = serve(False)
    # without reservation the large request is bypassed by every small
    # one; with aging reservation it completes well before the tail
    assert without == len(range(8)) - 1          # dead last
    assert with_resv < without


# --------------------------------------------------------- SchedView adapter
def test_pool_implements_schedview(pool):
    assert isinstance(pool, SchedView)
    # and the analytic simulator satisfies the same protocol
    from repro.core.profiles import build_profile
    from repro.core.simulator import Simulator
    profiles = {"qwen2-0.5b": build_profile("qwen2-0.5b")}
    sim = Simulator(profiles, POLICIES["temporal"](profiles), [])
    assert isinstance(sim, SchedView)


def test_admit_selects_standby_allocation(pool):
    pool.reset()
    name = sorted(pool.hosts)[0]
    host = pool.hosts[name]
    chips_opts = sorted(host.allocations)
    # ask for more than any standby allocation -> granted the largest
    pool.push(Request(arrival=0.0, rid=0, model=name, slo=1.0))
    run = pool.admit(RunRequest(name, chips=4096, batch=1), 0.0, GEN_LEN)
    assert run is not None and run.chips == chips_opts[-1]
    assert run.engine.alloc_chips == run.chips
    # model already running -> second admission refused
    pool.push(Request(arrival=0.0, rid=1, model=name, slo=1.0))
    assert pool.admit(RunRequest(name, chips=4096, batch=1), 0.0,
                      GEN_LEN) is None
    while not pool.step_run(run, 0.0):
        pass
    # ask below the smallest -> falls back to the smallest standby engine,
    # and the quantization upgrade is counted (not silent)
    pool.push(Request(arrival=0.0, rid=2, model=name, slo=1.0))
    run = pool.admit(RunRequest(name, chips=1, batch=1), 0.0, GEN_LEN)
    assert run is not None and run.chips == chips_opts[0]
    assert pool._metrics[name].alloc_upgrades == 1
    while not pool.step_run(run, 0.0):
        pass
    pool.reset()


def test_admit_caps_batch_to_free_slots(pool):
    pool.reset()
    name = sorted(pool.hosts)[0]
    n_slots = max(a.n_slots for a in pool.hosts[name].allocations.values())
    for i in range(n_slots + 3):
        pool.push(Request(arrival=0.0, rid=i, model=name, slo=1.0))
    run = pool.admit(RunRequest(name, chips=4096, batch=n_slots + 3), 0.0,
                     GEN_LEN)
    assert run is not None and run.batch == n_slots
    assert len(pool.queues[name]) == 3          # surplus stays queued
    while not pool.step_run(run, 0.0):
        pass
    pool.reset()


# ------------------------------------------------------------ fairness metric
def test_jain_index():
    assert jain_index([1.0, 1.0, 1.0, 1.0]) == pytest.approx(1.0)
    assert jain_index([5.0, 5.0]) == pytest.approx(1.0)
    # one consumer hogs everything -> 1/n
    assert jain_index([1.0, 0.0, 0.0, 0.0]) == pytest.approx(0.25)
    # more unequal -> strictly less fair
    assert jain_index([3.0, 1.0]) < jain_index([2.0, 1.0]) < 1.0
    # degenerate inputs are vacuously fair
    assert jain_index([]) == 1.0
    assert jain_index([0.0, 0.0]) == 1.0


def test_percentile_nearest_rank():
    xs = [1.0, 2.0, 3.0, 4.0]
    assert percentile(xs, 0.5) == 2.0
    assert percentile(xs, 0.99) == 4.0
    assert percentile(xs, 0.0) == 1.0
    assert math.isnan(percentile([], 0.5))


# ----------------------------------------------------------- chips_for_frac
def test_chips_for_frac_parametrized_by_pod_size():
    assert chips_for_frac(0.5, 256) == 128
    assert chips_for_frac(0.5, 64) == 32
    assert chips_for_frac(0.3, 16) == 4       # pow2 floor of 4.8
    assert chips_for_frac(1.0, 8) == 8
    assert chips_for_frac(0.001, 256) == 0
