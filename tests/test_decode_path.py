"""Decode hot-path correctness: ragged decode attention, scan-based
generation parity, slot-based continuous batching, drain-mode arrivals."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.models import layers as L
from repro.serving.engine import make_engine

KEY = jax.random.PRNGKey(7)


def _qkv(b, c, h, kv, d):
    ks = jax.random.split(KEY, 3)
    return (jax.random.normal(ks[0], (b, h, d)),
            jax.random.normal(ks[1], (b, c, kv, d)),
            jax.random.normal(ks[2], (b, c, kv, d)))


# ------------------------------------------------- ragged decode attention
RAGGED_CASES = [
    # (b, h, kv, d, cache, lengths, block)
    (4, 8, 2, 64, 256, [0, 77, 256, 130], 64),     # incl. empty + full rows
    (3, 4, 4, 64, 128, [1, 128, 64], 128),         # single block
    (2, 14, 2, 64, 256, [100, 3], 64),             # qwen2-like heads
    (5, 8, 1, 64, 512, [0, 0, 512, 256, 511], 128),  # MQA, multiple empties
    (2, 8, 2, 64, 768, [700, 0], 512),     # cache not divisible by block_k:
                                           # kernel must halve block to 256
]


@pytest.mark.parametrize("b,h,kv,d,c,lengths,blk", RAGGED_CASES)
def test_ragged_decode_kernel_matches_ref(b, h, kv, d, c, lengths, blk):
    q, kc, vc = _qkv(b, c, h, kv, d)
    lv = jnp.asarray(lengths, jnp.int32)
    out = decode_attention(q, kc, vc, lv, block_k=blk, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, lv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("b,h,kv,d,c,lengths,blk", RAGGED_CASES)
def test_ragged_jnp_fallback_matches_ref(b, h, kv, d, c, lengths, blk):
    q, kc, vc = _qkv(b, c, h, kv, d)
    lv = jnp.asarray(lengths, jnp.int32)
    out = L.decode_attention(q, kc, vc, lv)
    want = ref.decode_attention_ref(q, kc, vc, lv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_ragged_scalar_broadcast_equivalence():
    """A scalar valid_len must equal the same length broadcast as (B,)."""
    q, kc, vc = _qkv(3, 128, 4, 2, 64)
    s = decode_attention(q, kc, vc, 90, block_k=64, interpret=True)
    v = decode_attention(q, kc, vc, jnp.full((3,), 90, jnp.int32),
                         block_k=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(v))


def test_ragged_rows_independent():
    """Changing one row's length must not change other rows' outputs."""
    q, kc, vc = _qkv(4, 128, 4, 2, 64)
    l1 = jnp.asarray([64, 128, 32, 5], jnp.int32)
    l2 = jnp.asarray([64, 7, 32, 5], jnp.int32)      # only row 1 differs
    o1 = L.decode_attention(q, kc, vc, l1)
    o2 = L.decode_attention(q, kc, vc, l2)
    keep = np.array([0, 2, 3])
    np.testing.assert_array_equal(np.asarray(o1)[keep], np.asarray(o2)[keep])


# -------------------------------------------------- scan-based generation
@pytest.mark.parametrize("arch", ["olmo-1b", "mamba2-1.3b", "zamba2-7b",
                                  "whisper-small"])
def test_scan_generate_matches_eager_greedy(arch):
    """The fused lax.scan token loop must be bit-exact with the per-token
    eager loop under greedy decoding — for every model family."""
    cfg = get_config(arch).reduced()
    eng = make_engine(cfg, cache_len=64)
    batch = {"tokens": jax.random.randint(KEY, (3, 8), 0, cfg.vocab_size)}
    if cfg.has_encoder:
        from repro.serving import modality
        batch["enc_embeds"] = modality.audio_frames(cfg, 3)
    scan = eng.generate(dict(batch), 10)
    eager = eng.generate_eager(dict(batch), 10)
    assert scan.shape == (3, 10)
    np.testing.assert_array_equal(np.asarray(scan), np.asarray(eager))


def test_bucket_len_policy():
    eng = make_engine(get_config("olmo-1b").reduced(), cache_len=64)
    assert eng.bucket_len(10) == 64           # floored at base cache_len
    assert eng.bucket_len(64) == 64
    assert eng.bucket_len(65) == 128          # next pow2
    assert eng.bucket_len(200) == 256
    # a stream of varying lengths maps onto O(log) buckets
    assert {eng.bucket_len(n) for n in range(1, 257)} == {64, 128, 256}


def test_generate_compiles_once_per_bucket():
    eng = make_engine(get_config("olmo-1b").reduced(), cache_len=32)
    for s in (12, 16, 20, 28):                # needs 36..60: all bucket to 64
        eng.generate({"tokens": jnp.ones((2, s), jnp.int32)}, 24)
    assert set(eng._prefill_jit) == {64}
    assert len(eng._gen_jit) == 1


def test_generate_token_count_bucketed():
    """Varying max_new_tokens must reuse one pow2-bucketed scan
    executable, and still return exactly the requested count."""
    eng = make_engine(get_config("olmo-1b").reduced(), cache_len=64)
    for t in (9, 12, 16):                     # all bucket to 16
        out = eng.generate({"tokens": jnp.ones((2, 8), jnp.int32)}, t)
        assert out.shape == (2, t)
    assert len(eng._gen_jit) == 1
    # and a truncated call equals the prefix of a longer one (greedy)
    a = eng.generate({"tokens": jnp.ones((2, 8), jnp.int32)}, 9)
    b = eng.generate({"tokens": jnp.ones((2, 8), jnp.int32)}, 16)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b)[:, :9])


def test_generate_exact_count_non_pow2_regression():
    """The pow2-bucketed scan computes t_bucket >= max_new_tokens steps
    and must hand back EXACTLY the requested count — the surplus is
    sliced off, never returned, and never eats into the requested tokens.
    Locks the contract for greedy AND sampled paths at non-pow2 counts,
    with the greedy slice bit-equal to the eager (unbucketed) engine."""
    from repro.serving.engine import SamplingParams

    eng = make_engine(get_config("olmo-1b").reduced(), cache_len=32)
    batch = {"tokens": jnp.ones((2, 8), jnp.int32)}
    for t in (1, 3, 5, 7, 11):
        out = eng.generate(dict(batch), t)
        assert out.shape == (2, t)
        eager = eng.generate_eager(dict(batch), t)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(eager))
    sp = SamplingParams(temperature=0.8, top_k=8)
    out = eng.generate(dict(batch), 5, rng=jax.random.PRNGKey(1),
                       sampling=sp)
    assert out.shape == (2, 5)
    assert (np.asarray(out) >= 0).all()


# --------------------------------------------- slot continuous batching
def _prompts(cfg, n, s=8):
    return [{"tokens": jax.random.randint(jax.random.PRNGKey(100 + i),
                                          (1, s), 0, cfg.vocab_size)}
            for i in range(n)]


def test_slot_insert_free_roundtrip_keeps_other_slots_unchanged():
    """Insert/free churn in neighboring slots must not perturb a resident
    sequence: its greedy token stream must match a solo run."""
    cfg = get_config("olmo-1b").reduced()     # dense: rows are independent
    pa, pb, pc = _prompts(cfg, 3)

    eng = make_engine(cfg, cache_len=32).init_slots(2)
    sa = eng.insert(pa)
    sb = eng.insert(pb)
    stream = [np.asarray(eng.step()[0])[sb] for _ in range(2)]
    eng.free(sa)                              # churn: free + reuse slot
    sc = eng.insert(pc)
    assert sc == sa                           # slot actually reused
    stream += [np.asarray(eng.step()[0])[sb] for _ in range(2)]

    solo = make_engine(cfg, cache_len=32).init_slots(2)
    sb2 = solo.insert(pb)
    want = [np.asarray(solo.step()[0])[sb2] for _ in range(4)]
    assert stream == want


def test_slot_free_then_insert_fresh_sequence():
    """A freed slot reused by a new request behaves like a fresh prefill."""
    cfg = get_config("olmo-1b").reduced()
    pa, pb = _prompts(cfg, 2)
    eng = make_engine(cfg, cache_len=32).init_slots(2)
    sa = eng.insert(pa)
    for _ in range(3):
        eng.step()
    eng.free(sa)
    sb = eng.insert(pb)
    got = [np.asarray(eng.step()[0])[sb] for _ in range(3)]

    solo = make_engine(cfg, cache_len=32).init_slots(2)
    sb2 = solo.insert(pb)
    want = [np.asarray(solo.step()[0])[sb2] for _ in range(3)]
    assert got == want


def test_vacant_slot_position_stays_pinned():
    """Freed slots' positions must not creep upward with every step —
    otherwise vacant rows drift back to full-cache attention cost."""
    cfg = get_config("olmo-1b").reduced()
    pa, pb = _prompts(cfg, 2)
    eng = make_engine(cfg, cache_len=32).init_slots(2)
    sa = eng.insert(pa)
    sb = eng.insert(pb)
    eng.free(sa)
    for _ in range(5):
        eng.step()
    assert int(eng._slot_cache["pos"][sa]) == 0
    assert int(eng._slot_cache["pos"][sb]) == 8 + 5       # prompt + 5 steps


def test_slot_exhaustion_raises():
    cfg = get_config("olmo-1b").reduced()
    eng = make_engine(cfg, cache_len=32).init_slots(1)
    (p,) = _prompts(cfg, 1)
    eng.insert(p)
    with pytest.raises(RuntimeError):
        eng.insert(p)
    assert eng.free_slots == 0


# ------------------------------------------------------- top-k/p sampling
def test_sample_logits_top_k1_and_tiny_top_p_are_greedy():
    lg = jax.random.normal(KEY, (4, 50))
    greedy = np.asarray(jnp.argmax(lg, -1))
    for kw in ({"top_k": 1}, {"top_p": 1e-6}, {"temperature": 0.0}):
        got = L.sample_logits(jax.random.PRNGKey(3), lg, **kw)
        np.testing.assert_array_equal(np.asarray(got), greedy)


def test_sample_logits_top_k_support():
    lg = jax.random.normal(KEY, (2, 64))
    top5 = np.asarray(jax.lax.top_k(lg, 5)[1])
    keys = jax.random.split(jax.random.PRNGKey(0), 200)
    toks = np.asarray(jax.vmap(
        lambda k: L.sample_logits(k, lg, top_k=5, temperature=1.5))(keys))
    for row in range(2):
        assert set(toks[:, row]) <= set(top5[row])


def test_sample_logits_top_p_nucleus():
    # one token holds ~90% of the mass; top_p=0.5 must always pick it
    lg = jnp.full((1, 32), 0.0).at[0, 7].set(6.0)
    keys = jax.random.split(jax.random.PRNGKey(1), 100)
    toks = np.asarray(jax.vmap(
        lambda k: L.sample_logits(k, lg, top_p=0.5))(keys))
    assert (toks == 7).all()


def test_generate_sampling_inside_scan():
    """Sampling runs INSIDE the fused scan (one executable per sampling
    config), is deterministic under a fixed rng, and greedy parity of the
    default path is untouched."""
    from repro.serving.engine import SamplingParams
    cfg = get_config("olmo-1b").reduced()
    eng = make_engine(cfg, cache_len=64)
    batch = {"tokens": jnp.ones((2, 8), jnp.int32)}
    greedy = eng.generate(dict(batch), 8)
    sp = SamplingParams(temperature=0.8, top_k=8, top_p=0.9)
    a = eng.generate(dict(batch), 8, rng=jax.random.PRNGKey(4), sampling=sp)
    b = eng.generate(dict(batch), 8, rng=jax.random.PRNGKey(4), sampling=sp)
    assert a.shape == (2, 8)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # separate executables: one greedy, one for this sampling config
    assert len(eng._gen_jit) == 2
    # greedy path still bit-exact with the eager loop
    np.testing.assert_array_equal(np.asarray(greedy),
                                  np.asarray(eng.generate_eager(dict(batch), 8)))


# ------------------------------------------------------ drain-mode horizon
def test_drain_mode_rate_generators_with_horizon():
    """Regression: drain=True + rate generators used to materialize zero
    arrivals (horizon 0.0) and silently simulate an empty workload."""
    from repro.core.profiles import build_profile
    from repro.core.scheduler import POLICIES
    from repro.core.simulator import SimConfig, Simulator
    from repro.serving.request import RequestGenerator

    profiles = {n: build_profile(n, request_rate=500)
                for n in ["qwen2-0.5b", "yi-9b"]}
    gens = [RequestGenerator(n, 500, profiles[n].slo, seed=i)
            for i, n in enumerate(profiles)]
    res = Simulator(profiles, POLICIES["dstack"](profiles), gens,
                    SimConfig(drain=True, drop_expired=False, duration=0,
                              arrival_horizon=0.5)).run()
    assert res.total_completed > 0
    assert res.makespan > 0


def test_drain_mode_rate_generators_without_horizon_raises():
    from repro.core.profiles import build_profile
    from repro.core.scheduler import POLICIES
    from repro.core.simulator import SimConfig, Simulator
    from repro.serving.request import RequestGenerator

    profiles = {"qwen2-0.5b": build_profile("qwen2-0.5b", request_rate=500)}
    gens = [RequestGenerator("qwen2-0.5b", 500, 1.0, seed=0)]
    with pytest.raises(ValueError):
        Simulator(profiles, POLICIES["dstack"](profiles), gens,
                  SimConfig(drain=True, duration=0)).run()
