"""Async serving gateway + tiered tenant-fair admission + traffic
scenarios (ISSUE 10).

Three claim groups:

* **Bit-exactness.** The gateway's asyncio drive loop is a line-for-line
  mirror of ``core.eventloop.run_event_loop``, so serving a trace through
  ``AsyncGateway`` yields streams BIT-IDENTICAL to ``serve_ticks`` on the
  same planner/engine — with zero recompiles, telemetry detached (the
  zero-cost default), under wall-clock pacing, with concurrent stream
  consumers, and under the full seeded chaos schedule (survivors exact).

* **Lifecycle edges.** Client disconnects mid-chunked-prefill and
  mid-spec-round become ``Cancel`` plan events that leak zero pages; a
  deadline blown at submit raises a typed rejection with queue-expiry
  accounting; a deadline blown while queued keeps the queue drop path; a
  shed request never holds a page.

* **Tiers + tenants + traffic.** ``TieredAdmission`` admits by weighted
  tier with a provable lowest-tier starvation bound and deficit-based
  tenant round-robin; the traffic generators are seeded-deterministic
  and the burst scenario floods one tenant/tier the way the bench's
  acceptance criterion assumes.
"""
import asyncio

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.serving import traffic
from repro.serving.engine import InferenceEngine, make_engine
from repro.serving.faults import FaultInjector
from repro.serving.gateway import (AsyncGateway, DeadlineRejection,
                                   ShedRejection)
from repro.serving.plan import (PlannerConfig, StepPlanner, TieredAdmission,
                                serve_ticks)
from repro.serving.request import Request, RequestQueue
from repro.serving.telemetry import Telemetry, TraceRecorder

CACHE_LEN = 32
N_SLOTS = 4
PAGE = 8
MODEL = "olmo-1b"


@pytest.fixture(scope="module")
def engine():
    cfg = get_config(MODEL).reduced()
    eng = make_engine(cfg, cache_len=CACHE_LEN).init_slots(
        N_SLOTS, paged=True, page_size=PAGE)
    return cfg, eng


@pytest.fixture(scope="module")
def spec_engine(engine):
    """The module engine paired with an identical-weights draft, so
    spec rounds accept everything and streams stay plain-greedy."""
    cfg, eng = engine
    draft = InferenceEngine(eng.api, eng.params,
                            cache_len=CACHE_LEN).init_slots(
        N_SLOTS, paged=False)
    eng.attach_draft(draft, spec_k=3)
    yield cfg, eng
    eng._draft = None                     # later tests run draft-free


def _make_prompt(cfg, rid: int, length: int):
    rng = np.random.default_rng(1000 + rid)
    return {"tokens": jnp.asarray(
        rng.integers(1, cfg.vocab_size, size=(1, length)).astype(np.int32))}


def _workload(cfg, seed: int, n: int, *, spread=0.0, prompt_range=(3, 12),
              budget_range=(3, 8), slo=1e9):
    """Seeded workload; ``spread`` > 0 staggers arrivals over that many
    virtual seconds so deliveries interleave with ticks."""
    rng = np.random.default_rng(seed)
    reqs, prompts = [], {}
    for i in range(n):
        p = int(rng.integers(*prompt_range))
        nt = int(rng.integers(*budget_range))
        t = float(rng.uniform(0.0, spread)) if spread else 0.0
        reqs.append(Request(arrival=t, rid=i, model=cfg.name, slo=slo,
                            n_tokens=nt, prompt_len=p))
        prompts[i] = _make_prompt(cfg, i, p)
    reqs.sort(key=lambda r: r.arrival)
    return reqs, prompts


def _reset(cfg, eng, reqs, **planner_kw):
    eng.release_all_slots()
    eng.reset_stats()
    for r in reqs:
        r.state = "pending"
        r.finish = -1.0
    return StepPlanner(eng, RequestQueue(cfg.name, slo=1e9),
                       PlannerConfig(gen_len=4, **planner_kw))


def _tick_serve(cfg, eng, reqs, prompts, **planner_kw):
    planner = _reset(cfg, eng, reqs, **planner_kw)
    srv = serve_ticks(planner, reqs, lambda r: prompts[r.rid],
                      stall_limit=50)
    assert not srv.truncated
    return {r: tuple(t) for r, t in planner.streams.items()}, planner, srv


def _gw_serve(cfg, eng, reqs, prompts, *, wall_clock=False, faults=None,
              on_tick=None, max_retries=None, telemetry=None, **planner_kw):
    """Serve a trace through the gateway; ALWAYS audit page conservation
    on the way out (the zero-leak bar every lifecycle edge must meet)."""
    planner = _reset(cfg, eng, reqs, **planner_kw)
    planner.telemetry = telemetry
    if faults is not None:
        eng.attach_faults(faults, max_retries=max_retries)
    gw = AsyncGateway(planner, wall_clock=wall_clock, faults=faults,
                      on_tick=on_tick, stall_limit=50)
    try:
        streams = gw.serve_trace(reqs, prompts)
    finally:
        if faults is not None:
            eng.attach_faults(None, max_retries=2)
    assert not gw.truncated
    held = eng.prefix_cache.held_pages if eng.prefix_cache else 0
    assert eng.free_pages + held == eng.total_pages, "leaked pages"
    assert eng.check_page_invariants()
    return streams, planner, gw


# ---------------------------------------------------------------------------
# bit-exactness: gateway == serve_ticks, telemetry detached, 0 recompiles
# ---------------------------------------------------------------------------
def test_gateway_trace_bit_exact_vs_serve_ticks(engine):
    """The acceptance bar: a staggered-arrival trace served through the
    async gateway emits token streams BIT-IDENTICAL to driving the
    TickServer directly, over the same number of ticks, compiling
    nothing, with telemetry detached (its zero-cost default)."""
    cfg, eng = engine
    reqs, prompts = _workload(cfg, seed=11, n=10, spread=0.01)
    base, _, srv = _tick_serve(cfg, eng, reqs, prompts,
                               chunk_tokens=3, lazy=True)
    assert base and any(len(t) for t in base.values())
    jit_before = eng.jit_cache_sizes()
    streams, planner, gw = _gw_serve(cfg, eng, reqs, prompts,
                                     chunk_tokens=3, lazy=True)
    assert planner.telemetry is None       # detached: the is-None path ran
    got = {rid: tuple(st.tokens) for rid, st in streams.items()}
    assert got == base
    assert all(st.state == "completed" for st in streams.values())
    assert gw.server.ticks == srv.ticks    # identical tick interleaving
    assert eng.jit_cache_sizes() == jit_before
    # the client surface agrees with the planner's record token-for-token
    for rid, st in streams.items():
        assert st.tokens == list(planner.streams[rid])


def test_gateway_concurrent_consumers_and_wall_clock(engine):
    """Wall-clock pacing with every stream drained by its own consumer
    task mid-run changes NOTHING: tokens arrive in order, exactly once,
    and match the virtual-clock run bit-for-bit."""
    cfg, eng = engine
    reqs, prompts = _workload(cfg, seed=11, n=10, spread=0.01)
    base, _, _ = _tick_serve(cfg, eng, reqs, prompts,
                             chunk_tokens=3, lazy=True)
    planner = _reset(cfg, eng, reqs, chunk_tokens=3, lazy=True)
    gw = AsyncGateway(planner, wall_clock=True, stall_limit=50)

    async def main():
        gw.schedule(reqs, prompts)
        consumers = [asyncio.create_task(st.collect())
                     for st in gw.streams.values()]
        await gw.run()
        return await asyncio.gather(*consumers)

    collected = asyncio.run(main())
    assert not gw.truncated
    got = {st.rid: tuple(st.tokens) for st in gw.streams.values()}
    assert got == base
    assert [tuple(t) for t in collected] \
        == [tuple(gw.streams[st.rid].tokens) for st in gw.streams.values()]
    # wall mode really paced against the host clock past the last arrival
    assert gw.now >= max(r.arrival for r in reqs)
    assert eng.free_pages == eng.total_pages


# ---------------------------------------------------------------------------
# lifecycle edges: disconnects, deadlines, shedding
# ---------------------------------------------------------------------------
def test_disconnect_mid_chunked_prefill_through_gateway(engine):
    """A client that disconnects while its request is still PREFILLING
    (chunked, pages already written) becomes a Cancel plan event: zero
    pages leak, the bystander's stream is untouched, and the client's
    stream closes with state ``cancelled`` having yielded nothing."""
    cfg, eng = engine
    long_req = Request(arrival=0.0, rid=0, model=cfg.name, slo=1e9,
                       n_tokens=4, prompt_len=24)
    side = Request(arrival=0.0, rid=1, model=cfg.name, slo=1e9,
                   n_tokens=6, prompt_len=4)
    prompts = {0: _make_prompt(cfg, 0, 24), 1: _make_prompt(cfg, 1, 4)}
    base, _, _ = _tick_serve(cfg, eng, [side], {1: prompts[1]})
    hold = {}

    def disconnect_mid_prefill(server, now):
        if "pages" in hold:
            return
        for slot, r in server.planner._resident.items():
            if r.req.rid == 0 and r.prefilling and r.done > 0:
                hold["pages"] = eng.slot_page_count(slot)
                assert hold["gw"].cancel(0)
                return

    planner = _reset(cfg, eng, [long_req, side], chunk_tokens=3)
    gw = AsyncGateway(planner, on_tick=disconnect_mid_prefill,
                      stall_limit=50)
    hold["gw"] = gw
    streams = gw.serve_trace([long_req, side], prompts)
    assert hold.get("pages", 0) > 0, "never caught it mid-prefill"
    assert streams[0].state == "cancelled" and streams[0].tokens == []
    assert streams[1].state == "completed"
    assert tuple(streams[1].tokens) == base[1]
    q = planner.queue
    assert q.cancelled == 1 and q.completed == 1 and q.violated == 0
    assert eng.free_pages == eng.total_pages


def test_disconnect_mid_spec_round_through_gateway(spec_engine):
    """Same edge one layer deeper: the disconnect lands while the victim
    is DECODING THROUGH SPEC ROUNDS (draft attached, proposals in
    flight). The Cancel frees its pages, survivors stay bit-exact with
    the no-cancel speculative run, and speculation actually happened."""
    cfg, eng = spec_engine
    reqs, prompts = _workload(cfg, seed=23, n=5, budget_range=(6, 10))
    base, _, _ = _gw_serve(cfg, eng, reqs, prompts, spec_k=3)
    assert eng.stats.spec_rounds > 0
    hold = {}

    def disconnect_mid_spec(server, now):
        if hold.get("done"):
            return
        pl = server.planner
        if eng.stats.spec_rounds == 0:
            return                        # no round verified yet
        for slot, r in pl._resident.items():
            if r.req.rid == 2 and not r.prefilling:
                hold["done"] = now
                assert hold["gw"].cancel(2)
                return

    planner = _reset(cfg, eng, reqs, spec_k=3)
    gw = AsyncGateway(planner, on_tick=disconnect_mid_spec, stall_limit=50)
    hold["gw"] = gw
    streams = gw.serve_trace(reqs, prompts)
    assert hold.get("done") is not None, "cancel never fired"
    assert eng.stats.spec_rounds > 0
    assert streams[2].state == "cancelled"
    assert len(streams[2].tokens) < len(base[2].tokens)
    for rid, st in streams.items():
        if rid != 2:
            assert st.state == "completed"
            assert st.tokens == base[rid].tokens, f"survivor {rid} diverged"
    assert planner.queue.cancelled == 1
    assert eng.free_pages == eng.total_pages


def test_deadline_at_submit_vs_deadline_in_queue(engine):
    """Two distinct deadline paths, same accounting. AT SUBMIT: the
    gateway fails fast with a typed ``DeadlineRejection`` — the request
    never enters the queue, never holds a page, yet counts dropped +
    violated exactly like a queue-side expiry. IN QUEUE: a request that
    expires while waiting (pages exhausted by residents) takes the
    queue's drop path and its stream closes terminally."""
    cfg, eng = engine
    # --- at submit (live mode): deadline already in the past
    planner = _reset(cfg, eng, [])
    gw = AsyncGateway(planner)
    stale = Request(arrival=-1.0, rid=90, model=cfg.name, slo=0.5,
                    n_tokens=2, prompt_len=4)

    async def live():
        task = asyncio.create_task(gw.run(hold_open=True))
        await asyncio.sleep(0)
        with pytest.raises(DeadlineRejection):
            gw.submit(stale, _make_prompt(cfg, 90, 4))
        gw.close()
        await task

    asyncio.run(live())
    q = planner.queue
    assert stale.state == "deadline_aborted"
    assert (q.dropped, q.violated) == (1, 1)
    assert 90 not in gw.streams            # no stream was ever created
    assert eng.free_pages == eng.total_pages
    # --- in queue (trace mode): slot-hogging residents starve a later
    # request whose tight SLO expires before admission reaches it
    hogs = [Request(arrival=0.0, rid=i, model=cfg.name, slo=1e9,
                    n_tokens=8, prompt_len=24) for i in range(5)]
    # strictly later arrival: FIFO keeps it behind every hog until a
    # slot frees, by which point its deadline has long passed
    tight = Request(arrival=5e-4, rid=5, model=cfg.name, slo=2e-3,
                    n_tokens=2, prompt_len=24)
    prompts = {i: _make_prompt(cfg, i, 24) for i in range(6)}
    streams, planner, _ = _gw_serve(cfg, eng, hogs + [tight], prompts)
    q = planner.queue
    assert streams[5].state == "deadline_aborted"
    assert streams[5].tokens == []
    assert (q.dropped, q.completed) == (1, 5)
    terminal = q.completed + q.dropped
    assert terminal == 6                   # conservation over the trace


def test_shed_request_never_holds_pages(engine):
    """Both shed surfaces: a trace replay closes shed streams terminally
    (state ``shed``, zero tokens), and a live submit raises a typed
    ``ShedRejection`` — in both cases free pages at the instant of the
    shed equal free pages had the request never arrived."""
    cfg, eng = engine
    reqs, prompts = _workload(cfg, seed=5, n=8)
    streams, planner, _ = _gw_serve(cfg, eng, reqs, prompts,
                                    shed_queue_depth=2)
    q = planner.queue
    assert q.shed > 0
    shed = [st for st in streams.values() if st.state == "shed"]
    assert len(shed) == q.shed
    assert all(st.tokens == [] for st in shed)
    assert q.completed + q.shed == len(reqs)
    # live surface
    planner = _reset(cfg, eng, [], shed_queue_depth=0)
    gw = AsyncGateway(planner)
    free0 = eng.free_pages
    req = Request(arrival=0.0, rid=50, model=cfg.name, slo=1e9,
                  n_tokens=2, prompt_len=4)

    async def live():
        task = asyncio.create_task(gw.run(hold_open=True))
        await asyncio.sleep(0)
        with pytest.raises(ShedRejection):
            gw.submit(req, _make_prompt(cfg, 50, 4))
        gw.close()
        await task

    asyncio.run(live())
    assert req.state == "shed"
    assert eng.free_pages == free0
    assert 50 not in gw.streams


def test_live_submit_cancel_and_drain(engine):
    """Live mode end-to-end: submits against a running gateway stream
    tokens back; a mid-flight disconnect cancels cleanly; ``close()``
    drains and the loop exits with every page home."""
    cfg, eng = engine
    planner = _reset(cfg, eng, [])
    gw = AsyncGateway(planner)
    prompts = {i: _make_prompt(cfg, i, 5) for i in range(3)}

    async def live():
        task = asyncio.create_task(gw.run(hold_open=True))
        await asyncio.sleep(0)
        sts = [gw.submit(Request(arrival=gw.now, rid=i, model=cfg.name,
                                 slo=1e9, n_tokens=10, prompt_len=5),
                         prompts[i]) for i in range(3)]
        # let a tick or two run, then the client for rid 1 walks away
        for _ in range(4):
            await asyncio.sleep(0)
        sts[1].cancel()
        gw.close()
        await task
        return sts

    sts = asyncio.run(live())
    assert sts[1].state == "cancelled"
    assert len(sts[1].tokens) < 10              # actually cut short
    for st in (sts[0], sts[2]):
        assert st.state == "completed" and len(st.tokens) == 10
    assert planner.queue.cancelled == 1 and planner.queue.completed == 2
    assert eng.free_pages == eng.total_pages


# ---------------------------------------------------------------------------
# chaos THROUGH the gateway: seeded faults + disconnects, survivors exact
# ---------------------------------------------------------------------------
def test_chaos_through_gateway_survivors_bit_exact(engine):
    """ISSUE 10 satellite: the PR 6 chaos schedule (dispatch faults,
    allocator failures, stuck ticks, client disconnects, deadline
    aborts, shedding) driven THROUGH the gateway drains with per-cause
    terminal counters partitioning the offered load, zero leaked pages,
    survivors bit-exact with the fault-free gateway run, closed streams
    carrying each terminal cause, and a seed replay reproducing it all."""
    cfg, eng = engine
    reqs, prompts = _workload(cfg, seed=31, n=10, budget_range=(4, 10))
    reqs = [Request(arrival=r.arrival, rid=r.rid, model=r.model,
                    slo=(8e-3 if r.rid in (4, 7) else 1e9),
                    n_tokens=r.n_tokens, prompt_len=r.prompt_len)
            for r in reqs]
    base, _, _ = _gw_serve(cfg, eng, reqs, prompts)   # fault-free
    jit_before = eng.jit_cache_sizes()
    hold = {"cancelled": []}

    def chaos_script(server, now):
        for tick, rid in ((2, 3), (6, 8)):
            if server.ticks == tick and rid not in hold["cancelled"]:
                if hold["gw"].cancel(rid):
                    hold["cancelled"].append(rid)

    def run_chaos():
        inj = FaultInjector(seed=13, dispatch_rate=0.08, alloc_rate=0.05,
                            stuck_rate=0.04, max_faults=12)
        planner = _reset(cfg, eng, reqs, chunk_tokens=3, lazy=True,
                         deadline_aborts=True, shed_queue_depth=8)
        eng.attach_faults(inj, max_retries=1)
        gw = AsyncGateway(planner, faults=inj, on_tick=chaos_script,
                          stall_limit=50)
        hold["gw"] = gw
        try:
            streams = gw.serve_trace(reqs, prompts)
        finally:
            eng.attach_faults(None, max_retries=2)
        assert not gw.truncated
        return streams, planner, inj

    streams, planner, inj = run_chaos()
    q = planner.queue
    assert inj.total > 0 and hold["cancelled"]
    terminal = (q.completed + q.cancelled + q.deadline_aborted + q.shed
                + q.dropped)
    assert terminal == len(reqs), (
        q.completed, q.cancelled, q.deadline_aborted, q.shed, q.dropped)
    assert q.cancelled == len(hold["cancelled"])
    # every stream closed with its request's terminal cause; survivors
    # match the fault-free gateway run token for token
    for rid, st in streams.items():
        assert st.state == st.req.state and st.state != "pending"
        if st.state == "completed":
            assert st.tokens == base[rid].tokens, f"survivor {rid} diverged"
    assert eng.free_pages == eng.total_pages
    assert eng.jit_cache_sizes() == jit_before
    # seeded replay: identical outcomes, stream for stream
    counters = (q.completed, q.cancelled, q.deadline_aborted, q.shed,
                q.dropped)
    hold["cancelled"] = []
    streams2, planner2, inj2 = run_chaos()
    q2 = planner2.queue
    assert inj2.injected == inj.injected
    assert (q2.completed, q2.cancelled, q2.deadline_aborted, q2.shed,
            q2.dropped) == counters
    assert {r: tuple(s.tokens) for r, s in streams2.items()} \
        == {r: tuple(s.tokens) for r, s in streams.items()}


# ---------------------------------------------------------------------------
# telemetry: lifecycle instants when attached (and only then)
# ---------------------------------------------------------------------------
def test_gateway_lifecycle_edges_land_as_telemetry_instants(engine):
    cfg, eng = engine
    reqs, prompts = _workload(cfg, seed=3, n=3)
    tel = Telemetry(trace=TraceRecorder(capacity=4096))
    hold = {}

    def cancel_once(server, now):
        if server.ticks == 1 and not hold.get("done"):
            hold["done"] = hold["gw"].cancel(2)

    planner = _reset(cfg, eng, reqs)
    planner.telemetry = tel
    gw = AsyncGateway(planner, on_tick=cancel_once, stall_limit=50)
    hold["gw"] = gw
    gw.serve_trace(reqs, prompts)
    assert hold.get("done")
    names = [e["name"] for e in tel.trace.events]
    assert names.count("arrival") == len(reqs)
    assert "gw_disconnect" in names
    closes = [e for e in tel.trace.events if e["name"] == "gw_stream_close"]
    assert len(closes) == len(reqs)
    assert {e["args"]["cause"] for e in closes} == {"completed", "cancelled"}


# ---------------------------------------------------------------------------
# tiered, tenant-fair admission (unit: no engine)
# ---------------------------------------------------------------------------
def _mk(rid, arrival, tier, tenant="t"):
    return Request(arrival=arrival, rid=rid, model="m", slo=1e9,
                   n_tokens=4, prompt_len=4, tier=tier, tenant=tenant)


def _drain_picks(q, adm, now=0.0, cost=10.0):
    order = []
    while True:
        req = q.pop_pick(now, key=adm.key())
        if req is None:
            return order
        order.append(req.rid)
        adm.admitted(req, cost, list(q))


def test_lowest_tier_starvation_bound():
    """The documented bound: once the batch head has been bypassed by
    ``bypass_limit`` higher-tier admissions it outranks EVERYTHING on
    the next pick — so batch work admits after at most ``bypass_limit``
    interactive admissions, never starves."""
    adm = TieredAdmission(dict(traffic.TIER_WEIGHTS), bypass_limit=2)
    q = RequestQueue("m", slo=1e9)
    q.push(_mk(0, 0.0, "batch"))
    for i in range(1, 6):
        q.push(_mk(i, 0.1 * i, "interactive"))
    order = _drain_picks(q, adm)
    # two bypasses, then the starving batch head jumps the line
    assert order[:3] == [1, 2, 0]
    assert order[3:] == [3, 4, 5]


def test_tier_weights_order_admissions():
    """With no starvation in play, higher-weight tiers admit strictly
    first; within a tier FIFO holds (single tenant degenerates
    exactly to arrival order)."""
    adm = TieredAdmission(dict(traffic.TIER_WEIGHTS), bypass_limit=100)
    q = RequestQueue("m", slo=1e9)
    q.push(_mk(0, 0.0, "batch"))
    q.push(_mk(1, 0.1, "standard"))
    q.push(_mk(2, 0.2, "interactive"))
    q.push(_mk(3, 0.3, "interactive"))
    q.push(_mk(4, 0.4, "standard"))
    assert _drain_picks(q, adm) == [2, 3, 1, 4, 0]


def test_tenant_deficit_round_robins_within_tier():
    """Within one tier, the deficit counter alternates tenants even when
    one tenant's requests all arrived first — a burst cannot monopolize
    admission against another tenant's stream."""
    adm = TieredAdmission(dict(traffic.TIER_WEIGHTS))
    q = RequestQueue("m", slo=1e9)
    for i in range(3):                     # acme burst, arrives first
        q.push(_mk(i, 0.01 * i, "standard", "acme"))
    for i in range(3, 5):                  # globex trickle, arrives later
        q.push(_mk(i, 0.1 + 0.01 * i, "standard", "globex"))
    assert _drain_picks(q, adm) == [0, 3, 1, 4, 2]


def test_unknown_tier_maps_to_default_and_fifo_degenerates():
    adm = TieredAdmission({"interactive": 4.0, "standard": 2.0},
                          default_tier="standard")
    assert adm.weight(_mk(0, 0.0, "no-such-tier")) == 2.0
    # one tier, one tenant: exact FIFO
    adm2 = TieredAdmission({"standard": 1.0})
    q = RequestQueue("m", slo=1e9)
    for i in range(4):
        q.push(_mk(i, 0.1 * i, "standard"))
    assert _drain_picks(q, adm2) == [0, 1, 2, 3]
    with pytest.raises(ValueError):
        TieredAdmission({})


def test_tiered_serve_end_to_end_with_tenant_metrics(engine):
    """Tiers through the full plane: a contended mixed-tier trace served
    with ``PlannerConfig.tiers`` admits interactive work first, streams
    everything to completion, and the per-tenant token accounting feeds
    ``ModelPoolMetrics.tenant_fairness`` (Jain over tenants)."""
    cfg, eng = engine
    rng = np.random.default_rng(41)
    reqs, prompts = [], {}
    tiers = ["interactive", "batch"] * 4
    for i, tier in enumerate(tiers):
        p = int(rng.integers(3, 8))
        reqs.append(Request(arrival=0.0, rid=i, model=cfg.name, slo=1e9,
                            n_tokens=4, prompt_len=p, tier=tier,
                            tenant=("acme", "globex")[i % 2]))
        prompts[i] = _make_prompt(cfg, i, p)
    streams, planner, _ = _gw_serve(cfg, eng, reqs, prompts,
                                    tiers=dict(traffic.TIER_WEIGHTS))
    assert all(st.state == "completed" for st in streams.values())
    m = planner.metrics
    assert set(m.tenant_tokens) == {"acme", "globex"}
    assert sum(m.tenant_tokens.values()) == 4 * len(reqs)
    assert 0.0 < m.tenant_fairness() <= 1.0
    # first tokens: interactive requests all beat every batch request
    first = {r.rid: r.first_token for r in reqs}
    worst_interactive = max(first[r.rid] for r in reqs
                            if r.tier == "interactive")
    best_batch = min(first[r.rid] for r in reqs if r.tier == "batch")
    assert worst_interactive <= best_batch
    assert eng.free_pages == eng.total_pages


# ---------------------------------------------------------------------------
# traffic scenarios: seeded determinism + shapes
# ---------------------------------------------------------------------------
def _sig(reqs):
    return [(round(r.arrival, 12), r.rid, r.tier, r.tenant, r.prompt_len,
             r.n_tokens) for r in reqs]


def test_traffic_scenarios_deterministic_and_well_formed():
    cfg = traffic.TrafficConfig(model="m", duration=1.0, rate=80.0, seed=9)
    for name in traffic.SCENARIOS:
        a = traffic.make_scenario(name, cfg)
        b = traffic.make_scenario(name, cfg)
        assert a and _sig(a) == _sig(b), f"{name} not seed-deterministic"
        assert [r.rid for r in a] == list(range(len(a)))
        assert all(0.0 <= r.arrival < cfg.duration for r in a)
        assert all(r.tier in traffic.TIER_SLO_UNITS for r in a)
        assert all(r.slo == traffic.TIER_SLO_UNITS[r.tier] * cfg.slo_unit
                   for r in a)
        c = traffic.make_scenario(
            name, traffic.TrafficConfig(model="m", duration=1.0,
                                        rate=80.0, seed=10))
        assert _sig(a) != _sig(c), f"{name} ignores its seed"
    with pytest.raises(ValueError):
        traffic.make_scenario("nope", cfg)


def test_burst_trace_floods_one_tenant_one_tier():
    cfg = traffic.TrafficConfig(model="m", duration=1.0, rate=60.0, seed=4)
    reqs = traffic.burst_trace(cfg, burst_mult=6.0)
    start, end = 0.25, 0.5                 # default window
    inside = [r for r in reqs if start <= r.arrival < end]
    outside = [r for r in reqs if not start <= r.arrival < end]
    # the window's arrival rate is several times the background's
    assert len(inside) / 0.25 > 3 * len(outside) / 0.75
    flood = [r for r in inside if r.tenant == "globex" and r.tier == "batch"]
    assert len(flood) > len(inside) / 2
    by_tier = traffic.offered_by(reqs, "tier")
    assert by_tier["batch"] > by_tier["interactive"]


def test_synth_prompts_and_attainment_helpers():
    cfg = traffic.TrafficConfig(model="m", duration=0.5, rate=40.0, seed=1)
    reqs = traffic.poisson_trace(cfg)
    p1 = traffic.synth_prompts(reqs, vocab=128, seed=0)
    p2 = traffic.synth_prompts(reqs, vocab=128, seed=0)
    assert all(np.array_equal(p1[r]["tokens"], p2[r]["tokens"]) for r in p1)
    assert all(p1[r.rid]["tokens"].shape == (1, r.prompt_len) for r in reqs)
    # attainment joins finish vs deadline: stamp outcomes by hand
    for i, r in enumerate(reqs):
        if i % 3 == 0:
            r.state, r.finish = "completed", r.deadline - 1e-6   # on time
        elif i % 3 == 1:
            r.state, r.finish = "completed", r.deadline + 1.0    # late
        else:
            r.state = "shed"
    att = traffic.attainment_by(reqs, "tier")
    offered = traffic.offered_by(reqs, "tier")
    assert set(att) <= set(offered)
    ontime = sum(1 for r in reqs
                 if r.state == "completed" and r.finish <= r.deadline)
    assert sum(att[k] * offered[k] for k in att) == pytest.approx(ontime)
