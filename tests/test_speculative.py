"""Cross-model speculative decoding over the engine pool (ISSUE 9).

The load-bearing claim is DRAFT/VERIFY EQUIVALENCE: greedy speculative
serving emits per-request token streams bit-exact with non-speculative
greedy serving, whatever the draft proposes — acceptance is an arg-max
identity (the verify chunk's logits are computed by the same incremental
chunk-attention contract the decode step obeys), and a rejected draft
rolls the slot back to exactly the state the plain decode path would
hold. Asserted with an identical-weights draft (acceptance 1.0), a
divergent draft (real rejections + rollbacks), under lazy paging with
page pressure, and with knee/EMA gating flipping speculation on and off
mid-stream (the draft-twin desync/re-init path). Plus: page conservation
and canonical free-list order after rollback-heavy serves, a compile
gate (verification rides the pre-warmed chunk/packed lattice — zero new
executables between warm serves), spec counters surfacing through
EngineStats → Prometheus → trace instants, and the pool-plane
``enable_speculation`` wiring including the vocabulary-compatibility
refusal."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.registry import build_model
from repro.serving.engine import InferenceEngine, make_engine
from repro.serving.plan import PlannerConfig, StepPlanner, serve_ticks
from repro.serving.request import Request, RequestQueue

CACHE_LEN = 32
N_SLOTS = 4
PAGE = 8
TARGET = "olmo-1b"
DRAFT = "qwen2-0.5b"          # a genuinely smaller dense model; reduced
                              # configs share one clamped vocabulary

INCAPABLE = {
    "ssm": "mamba2-1.3b",         # no KV pages to verify against
    "hybrid": "zamba2-7b",        # per-row conv/ssm state beyond pages+pos
    "encdec": "whisper-small",    # per-row cross-attention K/V
    "moe": "phi3.5-moe-42b-a6.6b",  # capacity dropping is batch-shape dep.
}


def _make_prompt(cfg, rid: int, length: int):
    rng = np.random.default_rng(1000 + rid)
    return {"tokens": jnp.asarray(
        rng.integers(1, cfg.vocab_size, size=(1, length)).astype(np.int32))}


def _workload(cfg, seed: int, n: int, prompt_range=(3, 20),
              budget_range=(2, 10)):
    rng = np.random.default_rng(seed)
    reqs, prompts = [], {}
    for i in range(n):
        p = int(rng.integers(*prompt_range))
        nt = int(rng.integers(*budget_range))
        reqs.append(Request(arrival=0.0, rid=i, model=cfg.name, slo=1e9,
                            n_tokens=nt, prompt_len=p))
        prompts[i] = _make_prompt(cfg, i, p)
    return reqs, prompts


def _serve(cfg, eng, reqs, prompts, **planner_kw):
    eng.release_all_slots()
    eng.reset_stats()
    if eng._draft is not None:
        eng._draft.reset_stats()
    q = RequestQueue(cfg.name, slo=1e9)
    planner = StepPlanner(eng, q, PlannerConfig(gen_len=4, **planner_kw))
    srv = serve_ticks(planner, reqs, lambda r: prompts[r.rid])
    assert not srv.truncated
    return {r: tuple(t) for r, t in planner.streams.items()}, planner, srv


@pytest.fixture(scope="module")
def target():
    """One warm (target, identical-weights draft) pair for the module —
    jit caches persist across tests like the pool's standby engines."""
    cfg = get_config(TARGET).reduced()
    eng = make_engine(cfg, cache_len=CACHE_LEN).init_slots(
        N_SLOTS, paged=True, page_size=PAGE)
    draft = InferenceEngine(eng.api, eng.params,
                            cache_len=CACHE_LEN).init_slots(
        N_SLOTS, paged=False)
    eng.attach_draft(draft, spec_k=3)
    return cfg, eng


@pytest.fixture(scope="module")
def divergent_target():
    """Target paired with a SAME-SHAPE draft whose weights diverge (other
    init seed): drafts are frequently wrong, so every serve exercises
    rejection + rollback."""
    cfg = get_config(TARGET).reduced()
    eng = make_engine(cfg, cache_len=CACHE_LEN).init_slots(
        N_SLOTS, paged=True, page_size=PAGE)
    api = build_model(cfg)
    draft = InferenceEngine(api, api.init(__import__("jax").random.PRNGKey(99)),
                            cache_len=CACHE_LEN).init_slots(
        N_SLOTS, paged=False)
    eng.attach_draft(draft, spec_k=3)
    return cfg, eng


# ---------------------------------------------------------------------------
# draft/verify equivalence: speculative greedy == plain greedy, bit-exact
# ---------------------------------------------------------------------------
def test_speculative_streams_bit_exact(target):
    """Identical-weights draft: every proposal verifies (acceptance 1.0)
    and the streams are the plain-greedy streams, token for token."""
    cfg, eng = target
    reqs, prompts = _workload(cfg, seed=7, n=6)
    base, _, _ = _serve(cfg, eng, reqs, prompts)
    assert base and all(len(t) for t in base.values())
    got, _, _ = _serve(cfg, eng, reqs, prompts, spec_k=3)
    assert got == base
    assert eng.stats.spec_rounds > 0
    assert eng.stats.accepted_tokens == eng.stats.draft_tokens
    assert eng.stats.rollbacks == 0
    # speculation replaced most per-token decode dispatches
    assert eng.stats.decode_steps < sum(len(t) for t in base.values()) / 2


def test_divergent_draft_rolls_back_bit_exact(divergent_target):
    """A frequently-wrong draft: rejections roll back to the exact plain
    decode state, so the streams are STILL bit-exact — speculation can
    cost throughput, never correctness."""
    cfg, eng = divergent_target
    reqs, prompts = _workload(cfg, seed=11, n=6)
    base, _, _ = _serve(cfg, eng, reqs, prompts)
    got, _, _ = _serve(cfg, eng, reqs, prompts, spec_k=3)
    assert got == base
    assert eng.stats.rollbacks > 0, "divergent draft never rejected"
    assert eng.stats.accepted_tokens < eng.stats.draft_tokens


def test_rollback_conserves_pages_and_free_list_canonical(divergent_target):
    """Rejection-heavy serving: every page is conserved (allocator audit)
    and after recovery the free list is back in canonical descending
    order — seeded replays reproduce identical page placement."""
    cfg, eng = divergent_target
    reqs, prompts = _workload(cfg, seed=13, n=8, budget_range=(4, 12))
    _serve(cfg, eng, reqs, prompts, spec_k=3)
    assert eng.stats.rollbacks > 0
    assert eng.check_page_invariants()
    eng.release_all_slots()
    assert eng.free_pages == eng.total_pages
    eng.recover()
    free = eng._kv.allocator._free
    assert free == sorted(free, reverse=True), "free list not canonical"


def test_lazy_page_pressure_degrades_never_preempts(target):
    """Tight lazy pool: speculation degrades k (down to plain decode)
    rather than preempting a resident, and the streams stay bit-exact."""
    cfg, eng_base = target
    reqs, prompts = _workload(cfg, seed=3, n=8, budget_range=(10, 20),
                              prompt_range=(4, 12))
    base, _, _ = _serve(cfg, eng_base, reqs, prompts)
    eng = make_engine(cfg, cache_len=CACHE_LEN).init_slots(
        N_SLOTS, paged=True, page_size=PAGE, total_pages=10)
    draft = InferenceEngine(eng.api, eng.params,
                            cache_len=CACHE_LEN).init_slots(
        N_SLOTS, paged=False)
    eng.attach_draft(draft, spec_k=3)
    got, planner, _ = _serve(cfg, eng, reqs, prompts, spec_k=3, lazy=True)
    assert got == base
    assert eng.check_page_invariants()


def test_gating_desync_and_reinit_bit_exact(target):
    """The roofline-knee gate flips speculation off whenever the decode
    batch is at/over the knee, so slots alternate plain and speculative
    ticks — every plain tick desyncs the draft twin, every later spec
    round re-initializes it from the recorded history. Still bit-exact."""
    cfg, eng = target
    reqs, prompts = _workload(cfg, seed=5, n=6, budget_range=(4, 10))
    base, _, _ = _serve(cfg, eng, reqs, prompts)
    got, _, _ = _serve(cfg, eng, reqs, prompts, spec_k=3, spec_knee_batch=3)
    assert got == base
    assert 0 < eng.stats.spec_rounds
    assert eng.stats.decode_steps > 0      # both modes actually ran


def test_knee_gate_disables_speculation(target):
    """Batch always >= knee -> compute-bound -> never speculate."""
    cfg, eng = target
    reqs, prompts = _workload(cfg, seed=7, n=6)
    base, _, _ = _serve(cfg, eng, reqs, prompts)
    got, _, _ = _serve(cfg, eng, reqs, prompts, spec_k=3, spec_knee_batch=1)
    assert got == base
    assert eng.stats.spec_rounds == 0


def test_acceptance_ema_gate_with_probes(divergent_target):
    """A draft below the acceptance floor disables itself via the trailing
    EMA; periodic probe rounds keep measuring it. Streams bit-exact."""
    cfg, eng = divergent_target
    reqs, prompts = _workload(cfg, seed=17, n=8, budget_range=(6, 14))
    base, _, _ = _serve(cfg, eng, reqs, prompts)
    got, planner, srv = _serve(cfg, eng, reqs, prompts, spec_k=3,
                               spec_min_accept=0.95, spec_probe_every=5)
    assert got == base
    # the gate engaged: fewer spec rounds than eligible decode ticks
    assert eng.stats.spec_rounds < srv.ticks
    assert planner._spec_accept_ema < 1.0


def test_speculation_worthwhile_knee_gate():
    from repro.core.scheduler import speculation_worthwhile
    assert speculation_worthwhile(4, None)          # no knee: CPU tests
    assert speculation_worthwhile(3, 4)             # memory-bound
    assert not speculation_worthwhile(4, 4)         # at the knee
    assert not speculation_worthwhile(9, 4)         # compute-bound


# ---------------------------------------------------------------------------
# compile gate: verification rides pre-warmed executables
# ---------------------------------------------------------------------------
def test_speculative_compile_gate(target):
    """Zero recompiles while serving: a second speculative serve over a
    DIFFERENT workload adds no executables — the draft scan is one traced
    signature and every verify chunk lands on the packed-bucket lattice
    the first serve warmed."""
    cfg, eng = target
    reqs, prompts = _workload(cfg, seed=23, n=6)
    _serve(cfg, eng, reqs, prompts, spec_k=3)       # warm
    warm = dict(eng.jit_cache_sizes())
    assert warm.get("draft_scan", 0) >= 1
    assert warm.get("chunk_prefill", 0) >= 1        # verify path live
    _serve(cfg, eng, reqs, prompts, spec_k=3)       # measured re-serve
    assert eng.jit_cache_sizes() == warm, "speculative serving recompiled"
    # every verify executable sits on the same pow2 lattice the packed
    # machinery buckets to — verification rides it, it does not fork a
    # per-shape executable family of its own
    from repro.serving.engine import _packed_bucket, _pow2_at_least
    for t, r, s in eng._chunk_prefill_jit:
        assert t == _packed_bucket(t) and s == _pow2_at_least(s)
        assert r is None or r == _pow2_at_least(r) or r == eng.slot_len


# ---------------------------------------------------------------------------
# capability boundaries
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("family", sorted(INCAPABLE))
def test_incapable_family_refuses_draft(family):
    cfg = get_config(INCAPABLE[family]).reduced()
    eng = make_engine(cfg, cache_len=CACHE_LEN).init_slots(
        2, paged=bool(build_model(cfg).paged_keys), page_size=PAGE)
    assert not eng.spec_capable()
    draft = InferenceEngine(eng.api, eng.params,
                            cache_len=CACHE_LEN).init_slots(2, paged=False)
    with pytest.raises(ValueError):
        eng.attach_draft(draft, spec_k=3)


def test_vocab_mismatch_refused():
    """Cross-model pairing demands one shared vocabulary — token ids must
    mean the same thing to drafter and verifier."""
    cfg = get_config(TARGET).reduced()
    eng = make_engine(cfg, cache_len=CACHE_LEN).init_slots(
        2, paged=True, page_size=PAGE)
    small = dataclasses.replace(cfg, vocab_size=256)
    api = build_model(small)
    draft = InferenceEngine(api, api.init(__import__("jax").random.PRNGKey(0)),
                            cache_len=CACHE_LEN).init_slots(2, paged=False)
    with pytest.raises(ValueError):
        eng.attach_draft(draft, spec_k=3)


# ---------------------------------------------------------------------------
# observability: counters surface through stats -> Prometheus -> trace
# ---------------------------------------------------------------------------
def test_spec_counters_surface_everywhere(target):
    from repro.serving.telemetry import (MetricsRegistry, Telemetry,
                                         TraceRecorder, export_engine_stats)
    cfg, eng = target
    reqs, prompts = _workload(cfg, seed=31, n=4)
    tel = Telemetry(trace=TraceRecorder())
    eng.attach_telemetry(tel)
    eng._draft.attach_telemetry(tel)
    try:
        _serve(cfg, eng, reqs, prompts, spec_k=3)
    finally:
        eng.attach_telemetry(None)
        eng._draft.attach_telemetry(None)
    kinds = {ev["name"] for ev in tel.trace.events}
    assert {"spec_draft", "spec_verify", "spec_round"} <= kinds
    rounds = [ev for ev in tel.trace.events if ev["name"] == "spec_round"]
    assert all("accepted" in ev["args"] and "drafted" in ev["args"]
               for ev in rounds)
    reg = MetricsRegistry()
    export_engine_stats(reg, eng.stats, cfg.name)
    text = reg.render()
    for metric in ("dstack_draft_tokens_total", "dstack_accepted_tokens_total",
                   "dstack_spec_rounds_total", "dstack_spec_rollbacks_total",
                   "dstack_incr_chunks_total"):
        assert metric in text, metric


# ---------------------------------------------------------------------------
# pool plane: cross-model wiring
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_pool_cross_model_speculation():
    """``EnginePool.enable_speculation`` pairs a small hosted model as the
    drafter for a large target; pool serving completes with spec rounds
    on the books and the counters mirrored into PoolResult."""
    from repro.core.simulator import RunRequest
    from repro.serving.pool import build_pool
    pool = build_pool([TARGET, DRAFT], base_slots=2, cache_len=CACHE_LEN,
                      prompt_len=8, page_size=PAGE)
    paired = pool.enable_speculation(TARGET, DRAFT, spec_k=3)
    assert paired >= 1
    for i in range(4):
        pool.push(Request(arrival=0.0, rid=i, model=TARGET, slo=1e9,
                          n_tokens=6, prompt_len=8))
    run = pool.admit(RunRequest(model=TARGET, chips=1, batch=2),
                     now=0.0, gen_len=6)
    assert run is not None
    steps = 0
    while not pool.step_run(run, now=float(steps)) and steps < 64:
        steps += 1
    assert steps < 64
    eng = run.engine
    assert eng.stats.spec_rounds > 0
    assert eng.stats.accepted_tokens <= eng.stats.draft_tokens
    res = pool.snapshot("test", duration=1.0, wall_s=0.0, steps=steps)
    m = res.per_model[TARGET]
    assert m.spec_rounds == eng.stats.spec_rounds
    assert m.draft_tokens == eng.stats.draft_tokens
