"""Optimizer math, loss behavior, checkpoint roundtrip, data pipeline."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.registry import build_model
from repro.training import checkpoint
from repro.training.optimizer import AdamW
from repro.training.train_step import _chunked_ce, lm_loss, make_train_step


def test_adamw_matches_manual_step():
    opt = AdamW(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                warmup_steps=1, total_steps=10**9, max_grad_norm=1e9)
    p = {"w": jnp.array([[1.0, 2.0]])}
    g = {"w": jnp.array([[0.5, -0.5]])}
    state = opt.init(p)
    p2, state2, _ = opt.update(g, state, p)
    m = 0.1 * g["w"]
    v = 0.01 * g["w"] ** 2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    lr0 = opt.schedule(jnp.int32(0))
    want = p["w"] - lr0 * mhat / (jnp.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(p2["w"]), np.asarray(want),
                               rtol=1e-6)


def test_grad_clipping():
    opt = AdamW(lr=1e-3, max_grad_norm=1.0)
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    _, _, metrics = opt.update(g, opt.init(p), p)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0, rel=1e-3)


def test_lr_schedule_warmup_and_decay():
    opt = AdamW(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(opt.schedule(jnp.int32(0))) < float(opt.schedule(jnp.int32(9)))
    assert float(opt.schedule(jnp.int32(9))) == pytest.approx(1.0, rel=0.2)
    assert float(opt.schedule(jnp.int32(99))) < 0.2


def test_chunked_ce_matches_direct():
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (2, 32, 100))
    labels = jax.random.randint(key, (2, 32), 0, 100)
    direct = -jnp.take_along_axis(
        jax.nn.log_softmax(logits, -1), labels[..., None], -1).mean()
    chunked = _chunked_ce(logits, labels, n_chunks=4)
    np.testing.assert_allclose(float(chunked), float(direct), rtol=1e-6)
    # and its gradient
    g1 = jax.grad(lambda lg: _chunked_ce(lg, labels, 4))(logits)
    g2 = jax.grad(lambda lg: -jnp.take_along_axis(
        jax.nn.log_softmax(lg, -1), labels[..., None], -1).mean())(logits)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-6)


def test_loss_decreases_50_steps():
    cfg = get_config("olmo-1b").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=2e-3, warmup_steps=5, total_steps=100)
    step = jax.jit(make_train_step(api, opt))
    state = opt.init(params)
    pipe = iter(TokenPipeline(cfg, DataConfig(batch_size=8, seq_len=64)))
    losses = []
    for _ in range(50):
        params, state, m = step(params, state, next(pipe))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5
    assert all(np.isfinite(losses))


def test_checkpoint_roundtrip_nested():
    cfg = get_config("granite-moe").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.msgpack")
        checkpoint.save(path, params)
        loaded = checkpoint.load(path, params)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(loaded)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pipeline_determinism_and_shapes():
    cfg = get_config("qwen2-0.5b").reduced()
    p1 = next(iter(TokenPipeline(cfg, DataConfig(4, 32, seed=11))))
    p2 = next(iter(TokenPipeline(cfg, DataConfig(4, 32, seed=11))))
    np.testing.assert_array_equal(np.asarray(p1["tokens"]),
                                  np.asarray(p2["tokens"]))
    assert p1["tokens"].shape == (4, 32)
    assert p1["labels"].shape == (4, 32)
    assert int(p1["tokens"].max()) < cfg.vocab_size
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(p1["tokens"][:, 1:]),
                                  np.asarray(p1["labels"][:, :-1]))


def test_moe_aux_loss_flows_into_training():
    cfg = get_config("phi3.5-moe").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = next(iter(TokenPipeline(cfg, DataConfig(2, 16))))
    total, metrics = lm_loss(api, params, batch, remat=False, aux_weight=0.5)
    assert float(total) >= float(metrics["loss"])
    assert float(metrics["aux_loss"]) > 0
