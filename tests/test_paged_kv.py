"""Paged KV cache end to end: allocator, paged kernel parity, bit-exact
paged-vs-ring greedy decode across all four model families, page-gated pool
admission with ragged per-request budgets, and the shared event loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ref
from repro.kernels.paged_attention import paged_decode_attention
from repro.models import layers as L
from repro.serving.engine import SamplingParams, make_engine
from repro.serving.kv_cache import (NULL_PAGE, OutOfPages, PageAllocator,
                                    PagedKVCache, pages_for)

KEY = jax.random.PRNGKey(11)


# ------------------------------------------------------------ page allocator
def test_allocator_alloc_free_roundtrip():
    a = PageAllocator(8)
    assert a.free_pages == 8 and a.used_pages == 0
    p1 = a.alloc(3)
    p2 = a.alloc(2)
    assert len(p1) == 3 and len(p2) == 2
    assert a.free_pages == 3
    # pages are distinct, never the null page
    assert len(set(p1) | set(p2)) == 5
    assert NULL_PAGE not in p1 + p2
    a.free(p1)
    assert a.free_pages == 6
    a.free(p2)
    assert a.free_pages == 8 and a.used_pages == 0


def test_allocator_out_of_pages_is_all_or_nothing():
    a = PageAllocator(4)
    a.alloc(3)
    with pytest.raises(OutOfPages):
        a.alloc(2)                      # only 1 free: must not partially grant
    assert a.free_pages == 1            # untouched by the failed alloc
    a.alloc(1)
    with pytest.raises(OutOfPages):
        a.alloc(1)


def test_allocator_fragmentation_is_harmless():
    """Interleaved alloc/free churn: any free page satisfies any request —
    full indirection means there is no contiguity to fragment."""
    a = PageAllocator(6)
    held = [a.alloc(2), a.alloc(2), a.alloc(2)]
    a.free(held[1])                     # free the MIDDLE allocation
    got = a.alloc(2)                    # must succeed from the "hole"
    assert sorted(got) == sorted(held[1])
    a.free(held[0])
    a.free(held[2])
    a.free(got)
    assert a.free_pages == 6


def test_allocator_double_free_and_null_page_rejected():
    a = PageAllocator(4)
    pages = a.alloc(2)
    a.free(pages)
    with pytest.raises(ValueError):
        a.free(pages)                   # double free
    with pytest.raises(ValueError):
        a.free([NULL_PAGE])


def test_paged_kv_cache_append_lazy_growth():
    kv = PagedKVCache(batch=2, page_size=4, max_pages=4, num_pages=6)
    kv.alloc(0, 5)                      # 5 tokens -> 2 pages
    assert kv.used_pages == 2 and kv.length(0) == 5
    assert kv.append(0, 3) == []        # 8 tokens still fit 2 pages
    fresh = kv.append(0, 1)             # 9th token crosses a page boundary
    assert len(fresh) == 1 and kv.used_pages == 3
    # row maximum enforced (4 pages * 4 slots = 16 tokens)
    with pytest.raises(OutOfPages):
        kv.append(0, 100)
    assert kv.length(0) == 9            # failed append left the row intact
    # out-of-pool growth signals too
    kv.alloc(1, 12)                     # 3 pages -> pool exhausted
    with pytest.raises(OutOfPages):
        kv.append(1, 8)
    assert kv.free(0) == 3
    assert kv.free(1) == 3
    assert kv.free_pages == 6
    assert kv.free(0) == 0              # idempotent


def test_pages_for():
    assert pages_for(0, 8) == 1         # live rows always own a page
    assert pages_for(1, 8) == 1
    assert pages_for(8, 8) == 1
    assert pages_for(9, 8) == 2


def test_table_row_fixed_shape():
    kv = PagedKVCache(batch=2, page_size=4, max_pages=4, num_pages=8)
    kv.alloc(0, 6)
    row = kv.table_row(0)
    assert len(row) == 4
    assert row[2:] == [NULL_PAGE, NULL_PAGE]
    assert all(p != NULL_PAGE for p in row[:2])


def test_random_churn_invariants_seeded():
    """Seeded-random alloc/append/free churn (the no-hypothesis sibling of
    tests/test_kv_properties.py): no page aliased by two live rows, page
    conservation, null page never allocated, failed ops all-or-nothing."""
    rng = np.random.default_rng(0)
    for trial in range(20):
        num_pages = int(rng.integers(4, 24))
        kv = PagedKVCache(batch=6, page_size=int(rng.choice([4, 8])),
                          max_pages=6, num_pages=num_pages)
        for _ in range(60):
            kind = int(rng.integers(0, 3))
            row = int(rng.integers(0, 6))
            amount = int(rng.integers(1, 40))
            before = (kv.free_pages, kv.length(row), tuple(kv.pages(row)))
            try:
                if kind == 0 and not kv.pages(row):
                    kv.alloc(row, amount)
                elif kind == 1 and kv.pages(row):
                    kv.append(row, amount)
                elif kind == 2:
                    kv.free(row)
            except OutOfPages:
                assert (kv.free_pages, kv.length(row),
                        tuple(kv.pages(row))) == before
            owned = [p for r in range(6) for p in kv.pages(r)]
            assert len(owned) == len(set(owned))          # no aliasing
            assert NULL_PAGE not in owned
            assert kv.free_pages + len(owned) == num_pages  # conservation
            for r in range(6):
                if kv.pages(r):
                    assert len(kv.pages(r)) == pages_for(kv.length(r),
                                                         kv.page_size)
            # the shipped audit (chaos suite's post-recovery check) must
            # agree with the independent re-derivation above
            kv.check_invariants()
        kv.reset()
        assert kv.free_pages == num_pages
        assert kv.check_invariants()


def test_shared_churn_invariants_seeded():
    """Seeded-random churn with a simulated radix-cache holder in the
    loop (the no-hypothesis sibling of ``test_shared_pages_random_churn``
    in tests/test_kv_properties.py): rows share a page only via the
    cache, refcounts conserve with the cache's holds declared, and a
    failed alias admission changes nothing (pins included)."""
    rng = np.random.default_rng(7)
    for trial in range(20):
        num_pages = int(rng.integers(4, 24))
        ps = int(rng.choice([4, 8]))
        kv = PagedKVCache(batch=6, page_size=ps, max_pages=6,
                          num_pages=num_pages)
        cache = {}             # page -> refs the simulated cache holds
        shared_origin = set()
        for _ in range(80):
            kind = int(rng.integers(0, 6))
            row = int(rng.integers(0, 6))
            amount = int(rng.integers(1, 40))
            before_free = kv.free_pages
            before = {r: (kv.length(r), tuple(kv.pages(r)))
                      for r in range(6)}
            before_cache = dict(cache)
            try:
                if kind == 0 and not kv.pages(row):
                    kv.alloc(row, amount)
                elif kind == 1 and kv.pages(row):
                    kv.append(row, amount)
                elif kind == 2:
                    kv.free(row)
                elif kind == 3 and kv.pages(row):
                    fresh = [p for p in kv.pages(row) if p not in cache]
                    kv.allocator.share(fresh)
                    cache.update({p: 1 for p in fresh})
                elif kind == 4 and not kv.pages(row) and cache:
                    held = sorted(cache)[:max(1, amount % (len(cache) + 1))]
                    tokens = min(len(held) * ps + 1 + amount % ps, 6 * ps)
                    if pages_for(tokens, ps) <= len(held):
                        continue
                    cow = None
                    if amount % 2 and len(cache) > len(held):
                        cow = sorted(cache)[len(held)]
                    kv.allocator.share(held)
                    if cow is not None:
                        kv.allocator.share([cow])
                    try:
                        kv.alloc_alias(row, held, tokens)
                        shared_origin.update(held)
                        if cow is not None:
                            kv.allocator.release([cow])
                    except OutOfPages:
                        kv.allocator.release(held)
                        if cow is not None:
                            kv.allocator.release([cow])
                        raise
                elif kind == 5 and cache:
                    drop = sorted(cache)[:max(1, amount % (len(cache) + 1))]
                    kv.allocator.release(drop)
                    for p in drop:
                        del cache[p]
            except OutOfPages:
                assert kv.free_pages == before_free
                assert cache == before_cache
                for r in range(6):
                    assert (kv.length(r), tuple(kv.pages(r))) == before[r]
            kv.check_invariants(extra_refs=dict(cache))
            owned = [p for r in range(6) for p in kv.pages(r)]
            multi = {p for p in owned if owned.count(p) > 1}
            assert multi <= shared_origin, multi - shared_origin
            assert kv.free_pages + len(set(owned) | set(cache)) == num_pages
        kv.allocator.release(list(cache))
        kv.reset()
        assert kv.free_pages == num_pages
        assert kv.check_invariants()


# --------------------------------------------------- paged kernel parity
PAGED_CASES = [
    # (b, h, kv, d, page_size, max_pages, lengths)
    (4, 8, 2, 64, 64, 4, [0, 77, 256, 130]),    # incl. empty + full rows
    (3, 4, 4, 64, 128, 1, [1, 128, 64]),        # single page
    (2, 14, 2, 64, 32, 8, [100, 3]),            # qwen2-like heads
    (5, 8, 1, 64, 128, 4, [0, 0, 512, 256, 511]),  # MQA, multiple empties
]


def _paged_setup(b, h, kv, d, ps, maxp, lengths, seed=0):
    """Random pages + a scrambled physical layout, and the contiguous
    logical view the oracle sees."""
    n_phys = b * maxp + 1
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, d))
    kp = jax.random.normal(ks[1], (n_phys, ps, kv, d))
    vp = jax.random.normal(ks[2], (n_phys, ps, kv, d))
    rng = np.random.default_rng(seed)
    tables = rng.permutation(np.arange(1, n_phys))[:b * maxp] \
        .reshape(b, maxp).astype(np.int32)
    kc = jnp.asarray(np.asarray(kp)[tables].reshape(b, maxp * ps, kv, d))
    vc = jnp.asarray(np.asarray(vp)[tables].reshape(b, maxp * ps, kv, d))
    return q, kp, vp, jnp.asarray(tables), kc, vc


@pytest.mark.parametrize("b,h,kv,d,ps,maxp,lengths", PAGED_CASES)
def test_paged_kernel_matches_ref(b, h, kv, d, ps, maxp, lengths):
    q, kp, vp, tables, kc, vc = _paged_setup(b, h, kv, d, ps, maxp, lengths)
    lv = jnp.asarray(lengths, jnp.int32)
    out = paged_decode_attention(q, kp, vp, tables, lv, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, lv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("b,h,kv,d,ps,maxp,lengths", PAGED_CASES)
def test_paged_fallback_matches_ragged(b, h, kv, d, ps, maxp, lengths):
    """The jnp gather fallback must agree with the contiguous ragged path
    bit-for-bit — same masked body, same reduction order."""
    q, kp, vp, tables, kc, vc = _paged_setup(b, h, kv, d, ps, maxp, lengths)
    lv = jnp.asarray(lengths, jnp.int32)
    paged = L.paged_decode_attention(q, kp, vp, tables, lv)
    contig = L.decode_attention(q, kc, vc, lv)
    np.testing.assert_array_equal(np.asarray(paged), np.asarray(contig))


def test_paged_null_page_rows_return_zero():
    q, kp, vp, tables, _, _ = _paged_setup(3, 8, 2, 64, 32, 4, [0, 5, 0])
    tables = tables.at[0].set(NULL_PAGE).at[2].set(NULL_PAGE)  # vacant rows
    lv = jnp.asarray([0, 5, 0], jnp.int32)
    out = np.asarray(L.paged_decode_attention(q, kp, vp, tables, lv))
    assert (out[0] == 0).all() and (out[2] == 0).all()
    assert np.abs(out[1]).sum() > 0


# ------------------------------------- paged vs ring engine parity (4 fams)
FAMILIES = ["olmo-1b", "mamba2-1.3b", "zamba2-7b", "whisper-small"]


def _prompt(cfg, i, s=8):
    b = {"tokens": jax.random.randint(jax.random.PRNGKey(100 + i), (1, s),
                                      0, cfg.vocab_size)}
    if cfg.has_encoder:
        from repro.serving import modality
        b["enc_embeds"] = modality.audio_frames(cfg, 1)
    return b


def _serve_stream(eng, cfg, budgets, n_steps=10):
    """Continuous batching with ragged budgets + churn; returns the greedy
    token stream of every slot at every step (active slots only)."""
    out = []
    nxt = 0
    for _ in range(n_steps):
        while nxt < len(budgets) and eng.can_admit(8, budgets[nxt]):
            eng.insert(_prompt(cfg, nxt), n_tokens=budgets[nxt])
            nxt += 1
        active = [s for s in range(eng.n_slots) if eng.slot_active(s)]
        tok, done = eng.step()
        out.append([(s, int(np.asarray(tok)[s])) for s in active])
        for s in done:
            eng.free(s)
    return out


@pytest.mark.parametrize("arch", FAMILIES)
def test_paged_matches_ring_greedy_mixed_lengths(arch):
    """THE acceptance bar: paged decode is bit-exact with ring-slot greedy
    decode on a mixed-length continuous-batching stream, per family."""
    cfg = get_config(arch).reduced()
    budgets = [3, 7, 2, 5, 4, 6]
    ring = make_engine(cfg, cache_len=32).init_slots(3, paged=False)
    pag = make_engine(cfg, cache_len=32).init_slots(3, paged=True,
                                                    page_size=8)
    ring_stream = _serve_stream(ring, cfg, budgets)
    paged_stream = _serve_stream(pag, cfg, budgets)
    assert ring_stream == paged_stream


def test_paged_engine_page_accounting_and_out_of_pages():
    cfg = get_config("olmo-1b").reduced()
    eng = make_engine(cfg, cache_len=32).init_slots(
        4, paged=True, page_size=8, total_pages=6)
    assert eng.total_pages == 6
    # prompt 8 + budget 8 = 16 tokens = 2 pages
    s0 = eng.insert(_prompt(cfg, 0), n_tokens=8)
    assert eng.free_pages == 4
    s1 = eng.insert(_prompt(cfg, 1), n_tokens=24)   # 32 tokens = 4 pages
    assert eng.free_pages == 0
    # free slots remain but NO pages: admission must be refused
    assert eng.free_slots == 2
    assert not eng.can_admit(8, 8)
    with pytest.raises(OutOfPages):
        eng.insert(_prompt(cfg, 2), n_tokens=8)
    assert eng.free_slots == 2          # failed insert left the slot free
    eng.free(s1)
    assert eng.free_pages == 4
    assert eng.can_admit(8, 8)
    eng.free(s0)
    assert eng.free_pages == 6


def test_paged_budget_capped_at_page_capacity():
    """A budget larger than the slot's page capacity is capped (pages are
    never evicted): the slot reports done AT capacity instead of writing
    past its last page, and a neighbor slot's stream is unperturbed —
    regression for the over-capacity corruption path."""
    cfg = get_config("olmo-1b").reduced()
    eng = make_engine(cfg, cache_len=16).init_slots(2, paged=True,
                                                    page_size=8)
    sa = eng.insert(_prompt(cfg, 0), n_tokens=100)   # room is 16 - 8 = 8
    sb = eng.insert(_prompt(cfg, 1), n_tokens=6)
    stream = []
    for i in range(8):
        tok, done = eng.step()
        stream.append(int(np.asarray(tok)[sa]))
        assert (sa in done) == (i >= 7)              # done at capacity
    solo = make_engine(cfg, cache_len=16).init_slots(2, paged=True,
                                                     page_size=8)
    sc = solo.insert(_prompt(cfg, 0), n_tokens=8)
    want = [int(np.asarray(solo.step()[0])[sc]) for _ in range(8)]
    assert stream == want
    # a prompt that leaves no decode room is rejected up front
    with pytest.raises(ValueError):
        make_engine(cfg, cache_len=16).init_slots(1, paged=True,
                                                  page_size=8).insert(
            _prompt(cfg, 0, s=16))


def test_paged_engine_unbudgeted_insert_reserves_full_slot():
    cfg = get_config("olmo-1b").reduced()
    eng = make_engine(cfg, cache_len=32).init_slots(
        2, paged=True, page_size=8)
    eng.insert(_prompt(cfg, 0))                      # no budget: ring-like
    assert eng.total_pages - eng.free_pages == 4     # all 32/8 pages


def test_step_done_flags_honor_budgets():
    cfg = get_config("olmo-1b").reduced()
    eng = make_engine(cfg, cache_len=32).init_slots(2, paged=True,
                                                    page_size=8)
    sa = eng.insert(_prompt(cfg, 0), n_tokens=2)
    sb = eng.insert(_prompt(cfg, 1), n_tokens=4)
    _, d1 = eng.step()
    assert d1 == []
    _, d2 = eng.step()
    assert d2 == [sa]                   # reported until freed
    _, d3 = eng.step()
    assert d3 == [sa]
    eng.free(sa)
    _, d4 = eng.step()
    assert d4 == [sb]


def test_freed_pages_reused_by_new_request_fresh():
    """A new request admitted into recycled pages must decode exactly as
    it would on a fresh engine (no ghost state in reused pages)."""
    cfg = get_config("olmo-1b").reduced()
    eng = make_engine(cfg, cache_len=32).init_slots(2, paged=True,
                                                    page_size=8)
    sa = eng.insert(_prompt(cfg, 0), n_tokens=3)
    sb = eng.insert(_prompt(cfg, 1), n_tokens=10)
    for _ in range(3):
        eng.step()
    eng.free(sa)
    sc = eng.insert(_prompt(cfg, 2), n_tokens=5)
    assert sc == sa
    got = [int(np.asarray(eng.step()[0])[sc]) for _ in range(5)]

    solo = make_engine(cfg, cache_len=32).init_slots(2, paged=True,
                                                     page_size=8)
    sd = solo.insert(_prompt(cfg, 2), n_tokens=5)
    want = [int(np.asarray(solo.step()[0])[sd]) for _ in range(5)]
    assert got == want


# ------------------------------------------------- sampling in slot step
def test_slot_step_sampling_zero_temperature_is_greedy():
    """Satellite regression: SamplingParams(temperature=0) through the
    slot step path must be bit-exact with the greedy slot step."""
    cfg = get_config("olmo-1b").reduced()
    g = make_engine(cfg, cache_len=32).init_slots(2, paged=True, page_size=8)
    s = make_engine(cfg, cache_len=32).init_slots(
        2, paged=True, page_size=8,
        sampling=SamplingParams(temperature=0.0))
    ga = g.insert(_prompt(cfg, 0))
    sa = s.insert(_prompt(cfg, 0))
    for _ in range(6):
        assert int(np.asarray(g.step()[0])[ga]) \
            == int(np.asarray(s.step()[0])[sa])


def test_slot_step_sampling_deterministic_and_in_vocab():
    cfg = get_config("olmo-1b").reduced()
    sp = SamplingParams(temperature=0.9, top_k=8)

    def stream(seed):
        eng = make_engine(cfg, cache_len=32).init_slots(
            2, paged=True, page_size=8, sampling=sp, rng_seed=seed)
        slot = eng.insert(_prompt(cfg, 0))
        return [int(np.asarray(eng.step()[0])[slot]) for _ in range(5)]

    a, b = stream(3), stream(3)
    assert a == b                       # same rng seed -> same stream
    assert all(0 <= t < cfg.padded_vocab for t in a)


# --------------------------------------------------- pool-level admission
def test_pool_admits_against_pages_and_counts_blocked():
    from repro.core.simulator import RunRequest
    from repro.serving.pool import build_pool
    from repro.serving.request import Request

    pool = build_pool(["olmo-1b"], base_slots=4, cache_len=32,
                      pages={"olmo-1b": 6})       # 6 pages < 4 slots * 4
    pool.reset()
    name = sorted(pool.hosts)[0]
    # 3 requests, budgets 8 -> (8 prompt + 8) = 2 pages each; only 3 fit
    # 6 pages, so with budget 24 (4 pages) the second blocks on memory
    pool.push(Request(arrival=0.0, rid=0, model=name, slo=1.0, n_tokens=24))
    pool.push(Request(arrival=0.0, rid=1, model=name, slo=1.0, n_tokens=24))
    pool.push(Request(arrival=0.0, rid=2, model=name, slo=1.0, n_tokens=8))
    run = pool.admit(RunRequest(name, chips=4096, batch=3), 0.0, gen_len=4)
    assert run is not None
    # 24-token budget = 4 pages; second 24-token ask exceeds the pool but
    # the 8-token one (2 pages) still fits behind it
    assert run.batch == 2
    m = pool._metrics[name]
    assert m.blocked_on_memory == 1
    assert len(pool.queues[name]) == 1
    # ragged budgets -> ragged completion: the short request frees first
    while not pool.step_run(run, 0.0):
        pass
    assert pool.queues[name].completed == 2
    pool.reset()


def test_pool_topup_refills_early_freed_slots():
    from repro.core.simulator import RunRequest
    from repro.serving.pool import build_pool
    from repro.serving.request import Request

    pool = build_pool(["olmo-1b"], base_slots=2, cache_len=32)
    pool.reset()
    name = sorted(pool.hosts)[0]
    # distinct arrivals pin the FIFO pop order (2-token, then 6-token)
    pool.push(Request(arrival=0.0, rid=0, model=name, slo=1.0, n_tokens=2))
    pool.push(Request(arrival=1e-4, rid=1, model=name, slo=1.0, n_tokens=6))
    pool.push(Request(arrival=2e-4, rid=2, model=name, slo=1.0, n_tokens=2))
    run = pool.admit(RunRequest(name, chips=4096, batch=2), 0.0, gen_len=4)
    assert run is not None and run.batch == 2
    # nothing to top up yet (no early frees)
    assert pool.topup(run, 0.0, 4) == 0
    pool.step_run(run, 0.0)
    finished = pool.step_run(run, 0.0)    # rid=0 (budget 2) completes here
    assert not finished and run.freed_early
    added = pool.topup(run, 0.0, 4)       # rid=2 refills the freed slot
    assert added == 1
    assert pool._metrics[name].topups == 1
    while not pool.step_run(run, 0.0):
        pass
    assert pool.queues[name].completed == 3
    pool.reset()


def test_ragged_workload_end_to_end_deterministic():
    """Mixed n_tokens stream through the full controller: determinism,
    ragged completions, and page occupancy all reported."""
    from repro.serving.controller import run_policy
    from repro.serving.pool import build_pool

    pool = build_pool(["qwen2-0.5b", "olmo-1b"], base_slots=2, cache_len=32)
    r1 = run_policy(pool, "dstack", rate=1500.0, duration=0.03,
                    gen_len=4, gen_tokens=(1, 8))
    r2 = run_policy(pool, "dstack", rate=1500.0, duration=0.03,
                    gen_len=4, gen_tokens=(1, 8))
    assert r1.total_completed == r2.total_completed > 0
    assert 0.0 <= r1.page_occupancy <= 1.0 + 1e-6
    assert not r1.truncated


# ----------------------------------------------- standby allocation set
def test_default_allocations_includes_midpoint_when_span_is_wide():
    import dataclasses as dc

    from repro.core.profiles import build_profile
    from repro.serving.pool import default_allocations

    prof = build_profile("olmo-1b")
    wide = dc.replace(prof, knee_chips=4, opt_chips=64)
    allocs = default_allocations(wide)
    mids = [a for a in allocs if 4 < a < 64]
    assert len(mids) == 1 and mids[0] == 16    # pow2 geometric mid point
    narrow = dc.replace(prof, knee_chips=8, opt_chips=16)
    assert [a for a in default_allocations(narrow) if 8 < a < 16] == []


def test_build_host_page_knobs():
    from repro.serving.pool import build_host

    host = build_host("olmo-1b", base_slots=3, cache_len=32, page_size=8,
                      total_pages=7)
    for alloc in host.allocations.values():
        assert alloc.engine.total_pages == 7
        assert alloc.engine.n_slots == 3


def test_build_pool_warms_with_oversubscribed_page_pool():
    """Regression: warmup must not reserve a full slot's pages — a pool
    deliberately built with fewer pages than one slot maximum (the
    oversubscription knob) used to crash with OutOfPages while warming."""
    from repro.serving.pool import build_pool

    pool = build_pool(["olmo-1b"], base_slots=4, cache_len=32,
                      pages={"olmo-1b": 3})      # 3 < 32/8 slot maximum
    name = sorted(pool.hosts)[0]
    for alloc in pool.hosts[name].allocations.values():
        assert alloc.engine.free_pages == 3      # warm state fully reset


# --------------------------------------------------- shared event loop
def test_event_loop_shared_by_simulator_and_controller():
    """Both planes implement EventLoopHooks and route run() through the
    one skeleton in repro.core.eventloop (no second copy to drift)."""
    import inspect

    from repro.core import eventloop
    from repro.core.simulator import Simulator
    from repro.serving.controller import Controller

    for plane in (Simulator, Controller):
        for hook in ("deliver", "next_completion", "advance", "fire",
                     "plan", "drained"):
            assert hasattr(plane, hook), (plane, hook)
        assert "run_event_loop" in inspect.getsource(plane.run)
    src = inspect.getsource(eventloop.run_event_loop)
    assert "max_time" in src and "drain" in src


def test_event_loop_truncates_on_max_events():
    from repro.core.eventloop import LoopConfig, run_event_loop

    class Hooks:
        def __init__(self):
            self.fired = 0

        def deliver(self, req):
            pass

        def next_completion(self):
            return self.fired * 0.1 + 0.1

        def next_wakeup(self, now):
            return float("inf")

        def advance(self, t):
            pass

        def fire(self, now, epsilon):
            self.fired += 1
            return 1

        def plan(self, now):
            pass

        def drained(self):
            return False

    out = run_event_loop(LoopConfig(duration=100.0, max_events=3), [],
                         Hooks())
    assert out.truncated and out.events == 3


class _TimedHooks:
    """Fires at a scripted list of times; records what fired and the
    furthest point the accumulators were advanced to."""

    def __init__(self, times):
        self.times = list(times)
        self.fired = []
        self.advanced = 0.0

    def deliver(self, req):
        pass

    def next_completion(self):
        return self.times[0] if self.times else float("inf")

    def next_wakeup(self, now):
        return float("inf")

    def advance(self, t):
        self.advanced = t

    def fire(self, now, epsilon):
        self.fired.append(self.times.pop(0))
        return 1

    def plan(self, now):
        pass

    def drained(self):
        return False


def test_event_loop_max_time_boundary():
    """Regression (ISSUE 6 satellite): the max_time backstop boundary is
    INCLUSIVE — an event exactly AT max_time fires; only events strictly
    past it truncate the run."""
    from repro.core.eventloop import LoopConfig, run_event_loop

    h = _TimedHooks([0.5, 1.0, 1.5])
    out = run_event_loop(
        LoopConfig(duration=100.0, drain=True, arrival_horizon=1e-9,
                   max_time=1.0), [], h)
    assert h.fired == [0.5, 1.0]          # the AT-boundary event fired
    assert out.truncated                  # 1.5 was beyond the backstop
    assert out.now == 1.0


def test_event_loop_max_time_truncation_advances_accumulators():
    """The max_time cutoff advances accumulators to the backstop before
    truncating — exactly like the duration cutoff — so ``out.now``
    always equals the window the partial integrals cover (previously
    they froze at the last fired event)."""
    from repro.core.eventloop import LoopConfig, run_event_loop

    h = _TimedHooks([0.25, 1.7])
    out = run_event_loop(
        LoopConfig(duration=100.0, drain=True, arrival_horizon=1e-9,
                   max_time=1.0), [], h)
    assert h.fired == [0.25]
    assert out.truncated
    assert out.now == 1.0                 # not 0.25: window is [0, 1.0]
    assert h.advanced == 1.0              # integrals cover the window too
