"""MoE dispatch properties: capacity semantics, no-drop equivalence, aux."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import layers as L
from repro.models.moe import apply_moe, capacity_for, moe_plan


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("phi3.5-moe").reduced()       # 4 experts, top-2
    plan = moe_plan(cfg)
    params = L.init_from_plan(jax.random.PRNGKey(3), plan)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 16, cfg.d_model))
    return cfg, params, x


def _dense_reference(p, cfg, x):
    """Dense top-k reference: compute every expert for every token."""
    t = x.reshape(-1, cfg.d_model)
    logits = t.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    w, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    w = w / w.sum(-1, keepdims=True)
    g = jnp.einsum("td,edf->tef", t, p["wi_gate"])
    u = jnp.einsum("td,edf->tef", t, p["wi_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(t.dtype) * u
    all_out = jnp.einsum("tef,efd->ted", h, p["wo"])
    picked = jnp.take_along_axis(all_out, idx[..., None], axis=1)
    return ((picked.astype(jnp.float32) * w[..., None]).sum(1)
            .reshape(x.shape))


def test_no_drop_matches_dense_reference(setup):
    cfg, params, x = setup
    cf_nodrop = cfg.num_experts / cfg.experts_per_token   # guarantees 0 drops
    y, aux = apply_moe(params, cfg, x, capacity_factor=cf_nodrop)
    want = _dense_reference(params, cfg, x)
    assert float(aux["dropped_fraction"]) == 0.0
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_tiny_capacity_drops_tokens(setup):
    cfg, params, x = setup
    y, aux = apply_moe(params, cfg, x, capacity_factor=0.1)
    assert float(aux["dropped_fraction"]) > 0.0
    assert jnp.isfinite(y).all()


def test_dropped_tokens_pass_through_residual(setup):
    """Capacity ~0: MoE output ~0 everywhere (residual carries the token)."""
    cfg, params, x = setup
    y, aux = apply_moe(params, cfg, x, capacity_factor=1e-9)
    # capacity floor is 8 slots, so a few tokens still flow; most are zero
    zero_rows = (jnp.abs(y).max(-1) < 1e-6).mean()
    assert float(zero_rows) > 0.3


def test_load_balance_loss_bounds(setup):
    cfg, params, x = setup
    _, aux = apply_moe(params, cfg, x)
    lb = float(aux["load_balance_loss"])
    assert lb >= 1.0 - 0.5         # ~1 when balanced, > 1 when skewed
    assert lb < cfg.num_experts + 1


def test_capacity_rounding():
    cfg = get_config("phi3.5-moe").reduced()
    c = capacity_for(1000, cfg)
    assert c % 8 == 0
    assert c >= 1000 * cfg.experts_per_token / cfg.num_experts


@pytest.mark.xfail(
    strict=True,
    reason="MoE dispatch is batch-shape DEPENDENT by construction: "
           "expert capacity scales with the total token count of the "
           "dispatch, so co-packed segments compete for expert slots "
           "and the same tokens can drop differently than when run "
           "alone. This is WHY chunk_capable()/spec_capable() exclude "
           "MoE engines (packed prefill, incremental chunk "
           "continuations, and speculative verification all change the "
           "dispatch shape). If this test ever passes, dispatch became "
           "batch-shape independent and those gates can be lifted.")
def test_packed_batch_shape_independence_caveat(setup):
    """Pinned caveat (ISSUE 9): a probe segment co-packed behind an
    expert-overloading segment must match the probe computed alone —
    it does NOT, because the hot segment exhausts expert capacity ahead
    of it. strict xfail so the exclusion can't silently go stale."""
    cfg, params, _ = setup
    probe = jax.random.normal(jax.random.PRNGKey(4), (1, 16, cfg.d_model))
    # 48 copies of one token: all route to the same top-2 experts,
    # exceeding the packed dispatch's capacity before the probe dispatches
    hot = jnp.tile(jax.random.normal(jax.random.PRNGKey(7),
                                     (1, 1, cfg.d_model)), (1, 48, 1))
    y_alone, aux_alone = apply_moe(params, cfg, probe)
    y_packed, aux_packed = apply_moe(
        params, cfg, jnp.concatenate([hot, probe], axis=1))
    assert float(aux_alone["dropped_fraction"]) == 0.0
    assert float(aux_packed["dropped_fraction"]) > 0.0
    np.testing.assert_allclose(np.asarray(y_packed[:, 48:]),
                               np.asarray(y_alone), atol=1e-5, rtol=1e-5)


def test_moe_engine_refuses_incremental_paths():
    """The serving-plane consequence of the caveat above: an MoE engine
    reports chunk_capable() False (no packed chunk continuations) and
    therefore spec_capable() False (no speculative verification) — the
    planner falls back to whole-recompute continuations and plain greedy
    decode for MoE models."""
    from repro.serving.engine import make_engine
    cfg = get_config("phi3.5-moe").reduced()
    eng = make_engine(cfg, cache_len=32).init_slots(2, paged=True,
                                                    page_size=8)
    assert not eng.chunk_capable()
    assert not eng.spec_capable()


def test_batch_invariance_to_token_order(setup):
    """Permuting tokens then unpermuting gives the same result when no
    tokens are dropped (dispatch is order-dependent only under drops)."""
    cfg, params, x = setup
    cf = cfg.num_experts / cfg.experts_per_token
    t = x.reshape(-1, cfg.d_model)
    perm = jax.random.permutation(jax.random.PRNGKey(9), t.shape[0])
    inv = jnp.argsort(perm)
    y1, _ = apply_moe(params, cfg, t[perm], capacity_factor=cf)
    y0, _ = apply_moe(params, cfg, t, capacity_factor=cf)
    np.testing.assert_allclose(np.asarray(y1[inv]), np.asarray(y0),
                               atol=1e-5, rtol=1e-5)
