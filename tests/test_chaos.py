"""Fault-tolerant serving plane: cancellation, deadline aborts, load
shedding, and injected-fault recovery (ISSUE 6).

The load-bearing claim extends PR 5's plan equivalence into the failure
domain: every failure path — client cancels (queued, resident, or
mid-chunked-prefill), deadline aborts, load shedding, transient dispatch
faults absorbed by retry, allocator failures absorbed by requeue, and
full engine resets (retries exhausted / stuck ticks) — terminates or
recompute-requeues requests WITHOUT perturbing the survivors: their
greedy streams stay bit-exact with a fault-free run, no page leaks, and
every offered request lands in exactly one terminal state
(``completed | cancelled | deadline_aborted | shed | dropped``). The
chaos acceptance test drives all fault sites at once from one seeded
``FaultInjector`` and asserts exactly that, plus the zero-recompile
discipline (fault handling reuses warmed executables only).
"""
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.serving.engine import make_engine
from repro.serving.faults import (EngineFault, FaultConfig, FaultInjector,
                                  TransientFault)
from repro.serving.kv_cache import NULL_PAGE, OutOfPages, PageAllocator
from repro.serving.plan import (PlannerConfig, StepPlanner, preemption_key,
                                serve_ticks)
from repro.serving.request import Request, RequestQueue

CACHE_LEN = 32
N_SLOTS = 4
PAGE = 8
MODEL = "olmo-1b"


@pytest.fixture(scope="module")
def engine():
    """One warmed dense engine for the whole module — fault handling
    must reuse its executables, never compile (the acceptance test
    asserts the jit caches stay frozen across the chaos run)."""
    cfg = get_config(MODEL).reduced()
    eng = make_engine(cfg, cache_len=CACHE_LEN).init_slots(
        N_SLOTS, paged=True, page_size=PAGE)
    return cfg, eng


def _make_prompt(cfg, rid: int, length: int):
    rng = np.random.default_rng(1000 + rid)
    return {"tokens": jnp.asarray(
        rng.integers(1, cfg.vocab_size, size=(1, length)).astype(np.int32))}


def _workload(cfg, seed: int, n: int, prompt_range=(3, 12),
              budget_range=(3, 8)):
    rng = np.random.default_rng(seed)
    reqs, prompts = [], {}
    for i in range(n):
        p = int(rng.integers(*prompt_range))
        nt = int(rng.integers(*budget_range))
        reqs.append(Request(arrival=0.0, rid=i, model=cfg.name, slo=1e9,
                            n_tokens=nt, prompt_len=p))
        prompts[i] = _make_prompt(cfg, i, p)
    return reqs, prompts


def _serve(cfg, eng, reqs, prompts, *, chunk_tokens=0, lazy=False,
           faults=None, on_tick=None, max_retries=None, **planner_kw):
    """Serve to drain and ALWAYS leave the module engine clean: faults
    detached, all slots free, page conservation audited."""
    eng.release_all_slots()
    eng.reset_stats()
    q = RequestQueue(cfg.name, slo=1e9)
    planner = StepPlanner(eng, q, PlannerConfig(
        chunk_tokens=chunk_tokens, lazy=lazy, gen_len=4, **planner_kw))
    if faults is not None:
        eng.attach_faults(faults, max_retries=max_retries)
    try:
        srv = serve_ticks(planner, reqs, lambda r: prompts[r.rid],
                          faults=faults, on_tick=on_tick, stall_limit=50)
    finally:
        eng.attach_faults(None, max_retries=2)   # restore engine defaults
    assert not srv.truncated
    # the drain invariant every failure path must preserve: no request
    # left resident, and every page free or held by the radix cache
    # (whose holds the refcount audit verifies page by page)
    held = eng.prefix_cache.held_pages if eng.prefix_cache else 0
    assert eng.free_pages + held == eng.total_pages, "leaked pages"
    assert eng.check_page_invariants()
    if eng.prefix_cache is not None:
        eng.prefix_cache.check_invariants()
    return {r: tuple(t) for r, t in planner.streams.items()}, planner, srv


@pytest.fixture(scope="module")
def baseline(engine):
    cfg, eng = engine
    reqs, prompts = _workload(cfg, seed=7, n=6)
    streams, _, _ = _serve(cfg, eng, reqs, prompts)
    assert streams and all(len(t) for t in streams.values())
    return reqs, prompts, streams


# ---------------------------------------------------------------------------
# fault injector: seeded determinism, independent sites, hard cap
# ---------------------------------------------------------------------------
def test_fault_injector_deterministic_and_capped():
    sched = []
    for _ in range(2):
        inj = FaultInjector(FaultConfig(seed=3, dispatch_rate=0.3,
                                        stuck_rate=0.2, max_faults=5))
        hits = [(site, inj._roll(rate, site))
                for site in ("dispatch", "stuck") * 20
                for rate in (0.3 if site == "dispatch" else 0.2,)]
        sched.append(hits)
        assert inj.total == 5               # hard cap: chaos runs drain
    assert sched[0] == sched[1]             # same seed, same schedule
    # a zero-rate site consumes no randomness: adding it does not shift
    # the other sites' schedules (per-seed fault plans stay independent)
    a = FaultInjector(seed=9, dispatch_rate=0.5)
    b = FaultInjector(seed=9, dispatch_rate=0.5, alloc_rate=0.0)
    plan_a = [a._roll(0.5, "dispatch") for _ in range(30)]
    for _ in range(30):
        b._roll(0.0, "alloc")
    plan_b = [b._roll(0.5, "dispatch") for _ in range(30)]
    assert plan_a == plan_b
    with pytest.raises(TransientFault):
        FaultInjector(dispatch_rate=1.0).maybe_fault("dispatch")
    with pytest.raises(OutOfPages):
        FaultInjector(alloc_rate=1.0).maybe_fault("alloc")


# ---------------------------------------------------------------------------
# lifecycle: cancellation (queued / resident / mid-chunked-prefill)
# ---------------------------------------------------------------------------
def test_cancel_queued_request(engine):
    cfg, eng = engine
    eng.release_all_slots()
    q = RequestQueue(cfg.name, slo=1e9)
    planner = StepPlanner(eng, q, PlannerConfig(gen_len=4))
    req = Request(arrival=0.0, rid=0, model=cfg.name, slo=1e9, n_tokens=4)
    assert planner.submit(req, _make_prompt(cfg, 0, 4))
    assert planner.cancel(0)
    assert len(q) == 0 and q.cancelled == 1
    assert req.state == "cancelled"
    assert 0 not in planner._prompts            # prompt arrays reclaimed
    assert not planner.cancel(0)                # terminal: second is a no-op
    assert not planner.cancel(999)              # unknown rid
    assert q.violated == 0                      # cancel is not an SLO miss


def test_cancel_resident_survivors_bit_exact(engine, baseline):
    """Cancelling a decoding resident frees its slot and pages via the
    plan's Cancel event; every other stream is bit-identical to the
    fault-free run."""
    cfg, eng = engine
    reqs, prompts, base = baseline
    done = []

    def cancel_at(server, now):
        if server.ticks == 3 and not done:
            if server.planner.cancel(2):
                done.append(now)

    got, planner, _ = _serve(cfg, eng, reqs, prompts, chunk_tokens=3,
                             on_tick=cancel_at)
    assert done, "cancel never fired"
    q = planner.queue
    assert q.cancelled == 1 and q.completed == len(reqs) - 1
    assert q.violated == 0
    assert {r: t for r, t in got.items() if r != 2} \
        == {r: t for r, t in base.items() if r != 2}
    assert len(got[2]) < len(base[2])           # actually cut short


def test_cancel_mid_chunked_prefill_frees_all_pages(engine):
    """A request cancelled while still PREFILLING (chunked, multiple
    ticks in) is no special case: its partially-written pages free like
    a decoder's, and concurrent streams are untouched."""
    cfg, eng = engine
    long_req = Request(arrival=0.0, rid=0, model=cfg.name, slo=1e9,
                       n_tokens=4, prompt_len=24)
    side = Request(arrival=0.0, rid=1, model=cfg.name, slo=1e9,
                   n_tokens=6, prompt_len=4)
    prompts = {0: _make_prompt(cfg, 0, 24), 1: _make_prompt(cfg, 1, 4)}
    base, _, _ = _serve(cfg, eng, [side], {1: prompts[1]})
    state = {}

    def cancel_mid_prefill(server, now):
        if state:
            return
        pl = server.planner
        for slot, r in pl._resident.items():
            if r.req.rid == 0 and r.prefilling and r.done > 0:
                # mid-prefill, some chunks already written to pages
                state["pages"] = eng.slot_page_count(slot)
                assert pl.cancel(0)
                return

    got, planner, _ = _serve(cfg, eng, [long_req, side], prompts,
                             chunk_tokens=3, on_tick=cancel_mid_prefill)
    assert state and state["pages"] > 0, "never caught it mid-prefill"
    q = planner.queue
    assert q.cancelled == 1 and q.completed == 1
    assert got[0] == ()                          # never emitted a token
    assert got[1] == base[1]                     # bystander bit-exact
    # _serve's epilogue already asserted free_pages == total_pages


# ---------------------------------------------------------------------------
# lifecycle: deadline aborts + load shedding
# ---------------------------------------------------------------------------
def test_deadline_abort_evicts_resident(engine, baseline):
    """With ``deadline_aborts`` armed, a resident past its SLO deadline
    is evicted (pages freed, counted ``deadline_aborted``) instead of
    burning decode steps on a request nobody is waiting for."""
    cfg, eng = engine
    reqs, prompts, base = baseline
    tight = [Request(arrival=0.0, rid=r.rid, model=r.model,
                     slo=(4e-3 if r.rid == 1 else 1e9), n_tokens=20,
                     prompt_len=r.prompt_len) for r in reqs[:3]]
    got, planner, _ = _serve(cfg, eng, tight, prompts,
                             deadline_aborts=True)
    q = planner.queue
    assert q.deadline_aborted == 1 and q.completed == 2
    assert q.violated == 1                       # an abort IS an SLO miss
    assert tight[1].state == "deadline_aborted"
    assert len(got[1]) < 20                      # stopped early
    # without the knob the same workload decodes rid 1 to completion
    got2, planner2, _ = _serve(cfg, eng, tight, prompts)
    assert planner2.queue.deadline_aborted == 0
    assert len(got2[1]) == 20


def test_load_shedding_watermarks(engine):
    """Crossing either watermark sheds NEW submissions terminally (state
    ``shed``, counted as violated) — accepted requests still complete."""
    cfg, eng = engine
    reqs, prompts = _workload(cfg, seed=21, n=12)
    got, planner, _ = _serve(cfg, eng, reqs, prompts, shed_queue_depth=3)
    q = planner.queue
    assert q.shed > 0 and q.completed > 0
    assert q.shed + q.completed == len(reqs)
    assert q.violated == q.shed
    shed_rids = [r.rid for r in reqs if r.state == "shed"]
    assert len(shed_rids) == q.shed
    assert all(got[r] == () for r in shed_rids)
    # page-occupancy watermark: 0.0 sheds everything the moment the pool
    # holds any page at all; with no residents the gate stays open
    planner2 = StepPlanner(eng, RequestQueue(cfg.name, slo=1e9),
                           PlannerConfig(shed_page_frac=0.5))
    assert not planner2.should_shed(page_frac=0.4)
    assert planner2.should_shed(page_frac=0.5)
    assert planner2.should_shed(queue_len=0, page_frac=1.0)


# ---------------------------------------------------------------------------
# fault tolerance: retry, reset, allocator failure, stuck ticks
# ---------------------------------------------------------------------------
def test_dispatch_fault_retry_is_invisible(engine, baseline):
    """Transient dispatch faults under the retry budget are absorbed by
    ``execute`` — zero resets, streams bit-exact, retries counted."""
    cfg, eng = engine
    reqs, prompts, base = baseline
    inj = FaultInjector(seed=3, dispatch_rate=0.2, max_faults=10)
    got, planner, srv = _serve(cfg, eng, reqs, prompts, chunk_tokens=3,
                               faults=inj, max_retries=2)
    assert inj.injected["dispatch"] > 0
    assert planner.metrics.engine_retries == inj.injected["dispatch"]
    assert planner.metrics.engine_resets == 0 and srv.recoveries == 0
    assert got == base


def test_retry_exhaustion_resets_engine_bit_exact(engine, baseline):
    """retry_limit=0 turns every injected dispatch fault into an
    ``EngineFault`` → full reset: all residents recompute-requeue and
    the final streams STILL match the fault-free run."""
    cfg, eng = engine
    reqs, prompts, base = baseline
    inj = FaultInjector(seed=5, dispatch_rate=0.15, max_faults=4)
    got, planner, srv = _serve(cfg, eng, reqs, prompts, chunk_tokens=3,
                               faults=inj, max_retries=0)
    assert planner.metrics.engine_resets > 0
    assert srv.recoveries == planner.metrics.engine_resets
    assert planner.metrics.requeues > 0
    assert got == base
    assert planner.queue.completed == len(reqs)


def test_alloc_fault_requeues_bit_exact(engine, baseline):
    """Injected ``OutOfPages`` rides the real all-or-nothing rollback
    paths: admissions requeue (``admission_failed``) and lazy grows
    preempt-requeue (``failed_grows``) — no reset, streams bit-exact."""
    cfg, eng = engine
    reqs, prompts, base = baseline
    inj = FaultInjector(seed=11, alloc_rate=0.1, max_faults=5)
    got, planner, srv = _serve(cfg, eng, reqs, prompts, chunk_tokens=3,
                               lazy=True, faults=inj)
    assert inj.injected["alloc"] > 0
    assert planner.metrics.engine_resets == 0
    assert planner.metrics.requeues > 0
    assert got == base
    assert planner.queue.completed == len(reqs)


def test_stuck_tick_recovery_bit_exact(engine, baseline):
    """A watchdog-killed (stuck) tick recovers wholesale — engine reset
    plus recompute-requeue — and leaves no trace in the streams."""
    cfg, eng = engine
    reqs, prompts, base = baseline
    inj = FaultInjector(seed=9, stuck_rate=0.1, max_faults=3)
    got, planner, srv = _serve(cfg, eng, reqs, prompts, chunk_tokens=3,
                               faults=inj)
    assert srv.stuck_ticks == inj.injected["stuck"] > 0
    assert srv.recoveries >= srv.stuck_ticks
    assert got == base
    assert planner.queue.completed == len(reqs)


# ---------------------------------------------------------------------------
# victim selection: slack-aware preemption
# ---------------------------------------------------------------------------
def test_preemption_key_slack_aware():
    """The shared victim rule: most SLO slack per unit of sunk recompute
    work evicts first; nearly-due or deeply-invested residents are
    protected. ``newest`` restores the legacy latest-arrival rule."""
    now = 10.0

    def req(rid, arrival, slo):
        return Request(arrival=arrival, rid=rid, model=MODEL, slo=slo)

    lax = req(0, arrival=0.0, slo=1e6)       # tons of slack
    due = req(1, arrival=0.0, slo=10.5)      # nearly due
    # equal slack: the one with less sunk work is the cheaper recompute
    assert preemption_key(lax, 2, now) > preemption_key(due, 2, now)
    assert preemption_key(lax, 1, now) > preemption_key(lax, 100, now)
    # infinite SLO degrades to least-sunk-first, still discriminating
    inf_a, inf_b = req(2, 0.0, math.inf), req(3, 0.0, math.inf)
    assert preemption_key(inf_a, 1, now) > preemption_key(inf_b, 50, now)
    # legacy mode ignores slack and sunk work entirely
    old = req(4, arrival=5.0, slo=10.1)
    new = req(5, arrival=9.0, slo=1e6)
    assert preemption_key(new, 0, now, "newest") \
        > preemption_key(old, 0, now, "newest")


def test_slack_victim_protects_low_slack_resident(engine):
    """End to end: under page pressure the lazy planner preempts the
    slack-rich resident, not the nearly-due one — the tight-SLO request
    completes without ever being recomputed."""
    cfg, _ = engine
    eng = make_engine(cfg, cache_len=CACHE_LEN).init_slots(
        N_SLOTS, paged=True, page_size=PAGE, total_pages=6)
    reqs = [Request(arrival=0.0, rid=0, model=cfg.name, slo=1e9,
                    n_tokens=16, prompt_len=6),
            Request(arrival=1e-5, rid=1, model=cfg.name, slo=0.5,
                    n_tokens=16, prompt_len=6),
            Request(arrival=2e-5, rid=2, model=cfg.name, slo=1e9,
                    n_tokens=16, prompt_len=6)]
    prompts = {r.rid: _make_prompt(cfg, r.rid, 6) for r in reqs}
    preempted = []

    class Spy(StepPlanner):
        def _preempt(self, slot, plan, now):
            preempted.append(self._resident[slot].req.rid)
            return super()._preempt(slot, plan, now)

    q = RequestQueue(cfg.name, slo=1e9)
    planner = Spy(eng, q, PlannerConfig(lazy=True, gen_len=4))
    srv = serve_ticks(planner, reqs, lambda r: prompts[r.rid])
    assert not srv.truncated and planner.metrics.preemptions > 0
    assert 1 not in preempted, "evicted the nearly-due resident"
    assert q.completed == 3 and q.violated == 0


# ---------------------------------------------------------------------------
# allocator audit (satellite: invariant checker catches corruption)
# ---------------------------------------------------------------------------
def test_allocator_audit_catches_corruption():
    a = PageAllocator(8)
    pages = a.alloc(3)
    assert a.check_invariants()
    # double-free corruption: a page both free and allocated
    a._free.append(pages[0])
    with pytest.raises(AssertionError):
        a.check_invariants()
    a._free.pop()
    # conservation corruption: a page vanishes entirely
    a._allocated.discard(pages[1])
    with pytest.raises(AssertionError):
        a.check_invariants()
    a._allocated.add(pages[1])
    assert a.check_invariants()
    # the null page may never enter circulation
    a._free.append(NULL_PAGE)
    with pytest.raises(AssertionError):
        a.check_invariants()


# ---------------------------------------------------------------------------
# the chaos acceptance run: every failure mode at once, one seed
# ---------------------------------------------------------------------------
def test_chaos_acceptance(engine):
    """ISSUE 6 acceptance: a seeded chaos schedule (dispatch faults,
    allocator failures, stuck ticks, client cancels, deadline aborts,
    load shedding, all concurrently) drains with zero leaked pages, no
    stuck loop, per-cause terminal counters summing exactly to the
    offered load, survivors' streams bit-exact with the fault-free run,
    and ZERO recompiles."""
    cfg, eng = engine
    reqs, prompts = _workload(cfg, seed=31, n=10, budget_range=(4, 10))
    # two requests carry tight SLOs (deadline-abort bait)
    reqs = [Request(arrival=r.arrival, rid=r.rid, model=r.model,
                    slo=(8e-3 if r.rid in (4, 7) else 1e9),
                    n_tokens=r.n_tokens, prompt_len=r.prompt_len)
            for r in reqs]
    base, _, _ = _serve(cfg, eng, reqs, prompts)      # fault-free, no SLO
    jit_before = eng.jit_cache_sizes()

    cancelled_rids = []

    def chaos_script(server, now):
        # scripted client cancels at fixed ticks: one early (likely
        # queued or prefilling), one later (likely decoding)
        for tick, rid in ((2, 3), (6, 8)):
            if server.ticks == tick and rid not in cancelled_rids:
                if server.planner.cancel(rid):
                    cancelled_rids.append(rid)

    inj = FaultInjector(seed=13, dispatch_rate=0.08, alloc_rate=0.05,
                        stuck_rate=0.04, max_faults=12)
    got, planner, srv = _serve(
        cfg, eng, reqs, prompts, chunk_tokens=3, lazy=True, faults=inj,
        on_tick=chaos_script, max_retries=1, deadline_aborts=True,
        shed_queue_depth=8)
    q = planner.queue
    # 1. chaos actually happened
    assert inj.total > 0 and cancelled_rids
    # 2. conservation: every offered request reached exactly ONE
    #    terminal state — nothing lost, nothing double-counted
    terminal = (q.completed + q.cancelled + q.deadline_aborted + q.shed
                + q.dropped)
    assert terminal == len(reqs), (
        q.completed, q.cancelled, q.deadline_aborted, q.shed, q.dropped)
    assert q.cancelled == len(cancelled_rids)
    by_state = {}
    for r in reqs:
        by_state.setdefault(r.state, []).append(r.rid)
    assert len(by_state.get("completed", [])) == q.completed
    # 3. the mirrored metrics agree with the queue (PoolResult surface)
    m = planner.metrics
    assert (m.cancelled, m.deadline_aborted, m.shed) \
        == (q.cancelled, q.deadline_aborted, q.shed)
    assert m.engine_retries + m.engine_resets + srv.stuck_ticks > 0
    # 4. survivors are bit-exact with the fault-free run
    for rid in by_state.get("completed", []):
        assert got[rid] == base[rid], f"survivor rid={rid} diverged"
    # 5. zero leaks / no stuck loop (drain + page audit in _serve) and
    #    the executables are untouched: chaos recovery compiles NOTHING
    assert eng.jit_cache_sizes() == jit_before
    # 6. determinism: the same seed replays the same chaos outcome
    inj2 = FaultInjector(seed=13, dispatch_rate=0.08, alloc_rate=0.05,
                         stuck_rate=0.04, max_faults=12)
    for r in reqs:
        r.state = "pending"
    cancelled_rids.clear()
    got2, planner2, _ = _serve(
        cfg, eng, reqs, prompts, chunk_tokens=3, lazy=True, faults=inj2,
        on_tick=chaos_script, max_retries=1, deadline_aborts=True,
        shed_queue_depth=8)
    assert got2 == got
    assert inj2.injected == inj.injected
    q2 = planner2.queue
    assert (q2.completed, q2.cancelled, q2.deadline_aborted, q2.shed,
            q2.dropped) == (q.completed, q.cancelled, q.deadline_aborted,
                            q.shed, q.dropped)
    # 7. observability (ISSUE 7): the Prometheus exposition preserves
    #    conservation — per-cause terminal counters exported as
    #    dstack_requests_total still sum to the offered load after the
    #    render/parse round trip, and the injector's per-site fault
    #    counts plus engine retries/resets all surface in the snapshot
    from repro.serving.telemetry import (MetricsRegistry,
                                         export_engine_stats,
                                         export_fault_injector,
                                         export_queue, parse_prometheus)
    reg = MetricsRegistry()
    export_queue(reg, q2)
    export_engine_stats(reg, eng.stats, cfg.name)
    export_fault_injector(reg, inj2)
    parsed = parse_prometheus(reg.render())
    exported = sum(v for (name, _), v in parsed.items()
                   if name == "dstack_requests_total")
    assert exported == len(reqs), parsed
    for site, n in inj2.injected.items():
        assert parsed[("dstack_faults_injected_total",
                       (("site", site),))] == n
    retries = sum(v for (name, _), v in parsed.items()
                  if name == "dstack_engine_retries_total")
    resets = sum(v for (name, _), v in parsed.items()
                 if name == "dstack_engine_resets_total")
    assert retries == eng.stats.engine_retries
    assert resets == eng.stats.engine_resets
    assert retries + resets > 0 or srv.stuck_ticks > 0


# ---------------------------------------------------------------------------
# pool plane: cancel + engine reset through EnginePool/Controller
# ---------------------------------------------------------------------------
def test_pool_plane_cancel_and_engine_reset():
    """The pool plane shares the failure semantics: ``EnginePool.cancel``
    frees a resident's slot and pages immediately, and an ``EngineFault``
    mid-run resets the engine and recompute-requeues the whole run —
    the drained pool still completes everything else, leaks nothing,
    and surfaces per-cause counters in ``PoolResult``."""
    from repro.core.simulator import RunRequest
    from repro.serving.controller import run_policy
    from repro.serving.pool import build_pool

    pool = build_pool([MODEL], base_slots=4, cache_len=32,
                      allocations={MODEL: [256]})
    name = sorted(pool.hosts)[0]
    pool.reset()
    q = pool.queues[name]
    for i in range(3):
        pool.push(Request(arrival=0.0, rid=i, model=name, slo=1e9,
                          n_tokens=8))
    # cancel a QUEUED request
    assert pool.cancel(name, 2)
    run = pool.admit(RunRequest(name, chips=4096, batch=4), 0.0, 4)
    assert run is not None and run.batch == 2
    # cancel a RESIDENT request: slot + pages free NOW
    eng = run.engine
    pages_before = eng.free_pages
    assert pool.cancel(name, 0)
    assert eng.free_pages > pages_before
    assert not pool.cancel(name, 0)            # terminal: no double count
    while not pool.step_run(run, 0.0):
        pass
    assert q.cancelled == 2 and q.completed == 1
    eng.check_page_invariants()

    # injected dispatch faults with retries exhausted → engine resets
    # mid-serve; the run requeues and the drain still completes
    inj = FaultInjector(seed=2, dispatch_rate=0.2, max_faults=6)
    for alloc in pool.hosts[name].allocations.values():
        alloc.engine.attach_faults(inj, max_retries=0)
    try:
        res = run_policy(pool, "temporal", rate=800.0, duration=0.05,
                         drain=True)
    finally:
        for alloc in pool.hosts[name].allocations.values():
            alloc.engine.attach_faults(None)
    m = res.per_model[name]
    assert m.engine_resets > 0 and m.requeues > 0
    assert m.completed > 0
    for alloc in pool.hosts[name].allocations.values():
        assert alloc.engine.free_pages == alloc.engine.total_pages
        alloc.engine.check_page_invariants()


def test_pool_shed_watermark():
    """`EnginePool.push` sheds terminally at the queue-depth watermark,
    and the shed count reaches the PoolResult metrics."""
    from repro.serving.pool import build_pool

    pool = build_pool([MODEL], base_slots=2, cache_len=32,
                      allocations={MODEL: [256]},
                      planner_config=PlannerConfig(shed_queue_depth=2))
    name = sorted(pool.hosts)[0]
    pool.reset()
    for i in range(5):
        pool.push(Request(arrival=0.0, rid=i, model=name, slo=1e9))
    q = pool.queues[name]
    assert len(q) == 2 and q.shed == 3
    res = pool.snapshot("none", 1.0, 1.0, 0)
    assert res.per_model[name].shed == 3


# ---------------------------------------------------------------------------
# chaos with the radix prompt cache on: refcounted aliased pages in play
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def prefix_engine():
    """A separate warmed engine with the radix prompt cache attached —
    the chaos invariants must hold with aliased refcounted pages, COW
    copies, and teacher-forced catch-up in the fault domain."""
    cfg = get_config(MODEL).reduced()
    eng = make_engine(cfg, cache_len=CACHE_LEN).init_slots(
        N_SLOTS, paged=True, page_size=PAGE)
    eng.enable_prefix_cache()
    eng.warm_prefix_ops()
    return cfg, eng


def _shared_workload(cfg, seed: int, n: int, template_lens=(20, 8)):
    """Shared-prefix stream (ISSUE 8): two prompt templates plus short
    random tails; template length 20 is not a page multiple, so some
    hits diverge mid-page and exercise the COW copy under faults."""
    rng = np.random.default_rng(seed)
    temps = [rng.integers(1, cfg.vocab_size, size=s).astype(np.int32)
             for s in template_lens]
    reqs, prompts = [], {}
    for i in range(n):
        t = temps[int(rng.integers(0, len(temps)))]
        tail = rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(2, 6))).astype(np.int32)
        toks = np.concatenate([t, tail])
        reqs.append(Request(arrival=0.0, rid=i, model=cfg.name, slo=1e9,
                            n_tokens=int(rng.integers(3, 7)),
                            prompt_len=len(toks)))
        prompts[i] = {"tokens": jnp.asarray(toks[None, :])}
    return reqs, prompts


def test_chaos_with_prefix_cache(prefix_engine):
    """ISSUE 8 chaos bar: the seeded chaos schedule over a shared-prefix
    stream with the cache ON drains with zero leaked pages (cache holds
    audited page by page), survivors bit-exact with BOTH the fault-free
    cache-on run and the cache-off run, an identical seeded replay after
    recovery, and zero recompiles."""
    cfg, eng = prefix_engine
    reqs, prompts = _shared_workload(cfg, seed=23, n=10)

    def reset_states():
        for r in reqs:
            r.state = "pending"

    base_off, _, _ = _serve(cfg, eng, reqs, prompts, lazy=True)
    reset_states()
    base_on, _, _ = _serve(cfg, eng, reqs, prompts, lazy=True,
                           prefix_cache=True)
    # cache-hit admissions are bit-exact with whole-prompt admissions
    assert base_on == base_off
    assert eng.stats.prefix_hits > 0 and eng.stats.cow_copies > 0
    # warm the chunked-admission shapes the chaos run will use
    reset_states()
    _serve(cfg, eng, reqs, prompts, chunk_tokens=3, lazy=True,
           prefix_cache=True)

    def run_chaos():
        reset_states()
        inj = FaultInjector(seed=29, dispatch_rate=0.08, alloc_rate=0.05,
                            stuck_rate=0.04, max_faults=10)
        got, planner, srv = _serve(
            cfg, eng, reqs, prompts, chunk_tokens=3, lazy=True, faults=inj,
            max_retries=1, prefix_cache=True)
        return got, planner, srv, inj

    # chunk continuations ride the incremental chunk-attention path,
    # whose per-tick (tokens, row, segments) bucket depends on how many
    # continuations the interleaving packs together — a fault-perturbed
    # interleaving can legally touch a lattice bucket the fault-free
    # pass never packs. One seeded chaos pass warms those shapes; then
    # freeze the executables: the measured runs may compile NOTHING.
    run_chaos()
    jit_before = eng.jit_cache_sizes()

    got, planner, srv, inj = run_chaos()
    assert inj.total > 0, "fault schedule never fired"
    q = planner.queue
    assert q.completed + q.dropped == len(reqs)
    # survivors bit-exact against the fault-free cache-on (== cache-off)
    for r in reqs:
        if r.state == "completed":
            assert got[r.rid] == base_on[r.rid], f"rid={r.rid} diverged"
    # flushing the cache returns every page to the pool
    eng.prefix_cache.flush()
    assert eng.free_pages == eng.total_pages
    eng.check_page_invariants()
    # chaos recovery with aliasing/COW in play compiled NOTHING
    assert eng.jit_cache_sizes() == jit_before
    # determinism: the same seed replays the same chaos outcome from a
    # cold cache (engine recover() re-sorts the free list; release_all
    # in _serve flushes the cache)
    got2, _, _, inj2 = run_chaos()
    assert got2 == got
    assert inj2.injected == inj.injected
