"""The paper's analytical parallelism model (§4.3) + knee finding."""
import numpy as np
import pytest

from repro.core.knee import AnalyticalDNN, knee_binary_search, knee_of_latency


def test_execution_time_monotone_nonincreasing():
    m = AnalyticalDNN(p=40)
    s = np.arange(1, 81)
    et = m.execution_time(s)
    assert np.all(np.diff(et) <= 1e-9)


def test_latency_explodes_at_low_allocation():
    """Paper Fig. 2: fewer-than-necessary units => sharp latency increase."""
    m = AnalyticalDNN(p=40)
    assert m.execution_time(1) > 5 * m.execution_time(20)


def test_latency_flattens_beyond_parallelism():
    m = AnalyticalDNN(p=20)
    # beyond N_1 = p·b, no kernel can use more units
    assert m.execution_time(20) == pytest.approx(m.execution_time(80))


def test_derivative_maximum_is_interior_and_ordered():
    """Paper Fig. 4b: derivative maxima at ~9/24/31 for N1=20/40/60 —
    larger inherent parallelism => knee at more units."""
    s = np.arange(1, 81)
    maxima = []
    for p in (20, 40, 60):
        m = AnalyticalDNN(p=p, mem_bw_per_unit=50.0, data_per_kernel=100.0)
        d = m.derivative_curve(s)
        maxima.append(int(s[np.argmax(d)]))
    assert maxima[0] < maxima[1] < maxima[2]
    assert all(1 < k < 80 for k in maxima)


def test_utility_knee_below_max_parallelism():
    m = AnalyticalDNN(p=40)
    knee = m.knee(s_max=80)
    assert 1 <= knee <= 40


def test_batch_increases_knee():
    """Paper Fig. 4c/d: bigger batch => knee at larger allocation."""
    knees = [AnalyticalDNN(p=10, b=b).knee(s_max=128) for b in (1, 2, 4)]
    assert knees[0] <= knees[1] <= knees[2]
    assert knees[2] > knees[0]


def test_knee_of_latency_tolerance():
    lat = lambda f: 1.0 / f + 0.1          # saturating curve
    fr = [0.1, 0.2, 0.4, 0.8, 1.0]
    knee = knee_of_latency(lat, fr, rel_tol=10.0)   # huge tol → smallest
    assert knee == 0.1
    knee = knee_of_latency(lat, fr, rel_tol=0.0001)
    assert knee == 1.0


def test_binary_search_matches_linear_scan():
    lat = lambda f: 1.0 / f + 0.5
    fr = [i / 16 for i in range(1, 17)]
    a = knee_of_latency(lat, fr, rel_tol=0.05)
    b = knee_binary_search(lat, fr, rel_tol=0.05)
    assert abs(a - b) <= 1 / 16 + 1e-9
