"""End-to-end behaviour tests: the paper's headline claims on our system."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core.cluster import run_cluster
from repro.core.profiles import build_profile, default_zoo
from repro.core.scheduler import POLICIES
from repro.core.simulator import SimConfig, Simulator
from repro.serving.request import Request, RequestGenerator

C4 = ["qwen2-0.5b", "mamba2-1.3b", "deepseek-7b", "yi-9b"]


def _profiles(names=C4, rate=2000):
    return {n: build_profile(n, request_rate=rate) for n in names}


def _gens(profiles, rate=2000):
    return [RequestGenerator(n, rate, profiles[n].slo, seed=i)
            for i, n in enumerate(profiles)]


def test_paper_claim_dstack_vs_temporal_3x():
    """§7: 3-4x aggregate throughput over temporal sharing under load."""
    p1 = _profiles(rate=6000)
    r_t = Simulator(p1, POLICIES["temporal"](p1), _gens(p1, 6000),
                    SimConfig(duration=2.0)).run()
    p2 = _profiles(rate=6000)
    r_d = Simulator(p2, POLICIES["dstack"](p2), _gens(p2, 6000),
                    SimConfig(duration=2.0)).run()
    assert r_d.throughput() >= 3.0 * r_t.throughput()


def test_paper_claim_utilization_gain():
    """§7: ~1.6x GPU-utilization improvement over temporal sharing."""
    p1 = _profiles(rate=4000)
    r_t = Simulator(p1, POLICIES["temporal"](p1), _gens(p1, 4000),
                    SimConfig(duration=2.0)).run()
    p2 = _profiles(rate=4000)
    r_d = Simulator(p2, POLICIES["dstack"](p2), _gens(p2, 4000),
                    SimConfig(duration=2.0)).run()
    assert r_d.utilization >= 1.6 * r_t.utilization


def test_paper_claim_task_completion_beats_triton():
    """Table 1: fixed-work completion substantially faster than Triton."""
    class Burst:
        def __init__(self, model, n, slo):
            self.reqs = [Request(0.0, i, model, slo) for i in range(n)]

        def until(self, t):
            r, self.reqs = self.reqs, []
            return r

    results = {}
    for pol in ("triton", "dstack"):
        profiles = _profiles()
        gens = [Burst(n, 1000, profiles[n].slo) for n in profiles]
        res = Simulator(profiles, POLICIES[pol](profiles), gens,
                        SimConfig(drain=True, drop_expired=False,
                                  duration=0)).run()
        assert res.total_completed == 4000
        results[pol] = res.makespan
    reduction = 1 - results["dstack"] / results["triton"]
    assert reduction >= 0.30        # paper: 37%


def test_no_slo_violations_at_moderate_load():
    """§7: D-STACK has no violations multiplexing 4 models at sane rates."""
    rates = {"qwen2-0.5b": 2000, "mamba2-1.3b": 1000,
             "deepseek-7b": 500, "yi-9b": 300}
    profiles = {n: build_profile(n, request_rate=r) for n, r in rates.items()}
    gens = [RequestGenerator(n, r, profiles[n].slo, seed=i)
            for i, (n, r) in enumerate(rates.items())]
    res = Simulator(profiles, POLICIES["dstack"](profiles), gens,
                    SimConfig(duration=2.0)).run()
    total = res.total_completed + res.total_violated
    assert res.total_violated / max(total, 1) < 0.01


def test_seven_model_overload_degrades_gracefully():
    """§7 C-7: aggregate knee demand >> 100%: violations happen but D-STACK
    keeps throughput far above temporal's and serves every model."""
    names = C4 + ["olmo-1b", "granite-moe-3b-a800m", "whisper-small"]
    out = {}
    for pol in ("temporal", "dstack"):
        profiles = _profiles(names, rate=3000)
        res = Simulator(profiles, POLICIES[pol](profiles),
                        _gens(profiles, 3000), SimConfig(duration=2.0)).run()
        out[pol] = res
    assert out["dstack"].total_violated < out["temporal"].total_violated
    assert out["dstack"].throughput() > 2 * out["temporal"].throughput()
    for m in out["dstack"].per_model.values():
        assert m.completed > 0


def test_cluster_dstack_beats_exclusive_and_temporal():
    """§7.1 Fig. 12: multi-pod cluster throughput ordering."""
    out = {}
    for mode in ("exclusive", "temporal", "dstack"):
        profiles = _profiles(rate=8000)
        gens = _gens(profiles, 8000)
        out[mode] = run_cluster(profiles, gens, mode=mode, n_pods=4,
                                duration=1.0)
    assert out["dstack"].total_throughput > 1.3 * out["temporal"].total_throughput
    assert out["dstack"].total_throughput > 1.3 * out["exclusive"].total_throughput


def test_default_zoo_builds_all_10():
    zoo = default_zoo()
    assert len(zoo) == 10
    for prof in zoo.values():
        assert prof.knee_chips >= 1
        assert prof.opt_batch >= 1
        assert prof.slo > 0
        assert prof.runtime() < prof.slo        # operating point is feasible


def test_real_engine_end_to_end_two_models():
    """Real jitted data plane: two reduced models generating tokens."""
    from repro.serving.engine import make_engine
    from repro.configs import get_config
    for arch in ("qwen2-0.5b", "mamba2-1.3b"):
        eng = make_engine(get_config(arch).reduced(), cache_len=32)
        out = eng.generate({"tokens": jnp.ones((2, 4), jnp.int32)}, 4)
        assert out.shape == (2, 4)
        assert eng.stats.decode_steps == 4
