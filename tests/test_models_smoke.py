"""Deliverable (f): per-architecture smoke tests.

For each of the 10 assigned architectures, instantiate the REDUCED variant
(2 layers, d_model<=512, <=4 experts) and run one forward + one train step
on CPU, asserting output shapes and no NaNs. Full configs are exercised
only via the dry-run (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.models.registry import build_model
from repro.training.optimizer import AdamW
from repro.training.train_step import make_train_step

ARCH_IDS = sorted(ARCHS)


def _batch(cfg, b=2, s=32, key=jax.random.PRNGKey(7)):
    toks = jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.has_encoder:
        batch["enc_embeds"] = jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_shapes_and_finiteness(arch):
    cfg = ARCHS[arch].reduced()
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = api.forward(params, batch)
    b, s = batch["tokens"].shape
    assert logits.shape == (b, s, cfg.vocab_size)
    assert jnp.isfinite(logits).all()
    assert jnp.isfinite(aux["load_balance_loss"])


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_one_train_step(arch):
    cfg = ARCHS[arch].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3, warmup_steps=1, total_steps=10)
    state = opt.init(params)
    step = jax.jit(make_train_step(api, opt))
    params2, state2, metrics = step(params, state, _batch(cfg))
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    # parameters actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved
    assert int(state2.step) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_serve_path(arch):
    cfg = ARCHS[arch].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, b=2, s=16)
    pre = {k: (v[:, :16] if k == "tokens" else v)
           for k, v in batch.items() if k != "labels"}
    logits, cache = api.prefill(params, pre, 48)
    assert logits.shape == (2, cfg.vocab_size)
    assert jnp.isfinite(logits).all()
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache = api.decode_step(params, tok, cache)
    assert logits2.shape == (2, cfg.vocab_size)
    assert jnp.isfinite(logits2).all()
    # pos is a per-sequence (B,) vector (ragged decode / slot batching)
    assert cache["pos"].shape == (2,)
    assert int(cache["pos"][0]) == 17


def test_param_counts_match_plan():
    """config.param_count() must equal the actual constructed tree."""
    for arch, cfg in ARCHS.items():
        r = cfg.reduced()
        api = build_model(r)
        params = api.init(jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        expect = r.param_count()
        assert abs(actual - expect) / max(expect, 1) < 0.02, (
            arch, actual, expect)
