"""Packed ragged prefill: kernel parity, packed-vs-padded bit-exact greedy
parity across all four families, segment isolation, insert_many-vs-
sequential-insert equivalence on the paged cache, and the compile-count
gate (packed prefill adds O(log max_len) executables, not one per batch
shape)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ref
from repro.kernels.flash_attention import segment_flash_attention
from repro.models import layers as L
from repro.models.registry import build_model
from repro.serving.engine import _packed_bucket, make_engine
from repro.serving.kv_cache import NULL_PAGE, OutOfPages

KEY = jax.random.PRNGKey(7)
FAMILIES = ["olmo-1b", "mamba2-1.3b", "zamba2-7b", "whisper-small"]


def _pack(toks, s_max, t):
    """Host-side packing mirror of InferenceEngine._pack_prompts."""
    tokens = np.zeros((1, t), np.int32)
    seg = np.full((t,), s_max, np.int32)
    starts = np.zeros((s_max,), np.int32)
    lens = np.zeros((s_max,), np.int32)
    off = 0
    for i, tk in enumerate(toks):
        ln = tk.shape[1]
        tokens[0, off:off + ln] = np.asarray(tk)[0]
        seg[off:off + ln] = i
        starts[i] = off
        lens[i] = ln
        off += ln
    return {"tokens": jnp.asarray(tokens), "seg_ids": jnp.asarray(seg),
            "seg_starts": jnp.asarray(starts), "seg_lens": jnp.asarray(lens)}


def _prompt(cfg, i, s):
    b = {"tokens": jax.random.randint(jax.random.PRNGKey(100 + i), (1, s),
                                      0, cfg.vocab_size)}
    if cfg.has_encoder:
        from repro.serving import modality
        b["enc_embeds"] = modality.audio_frames(cfg, 1, seed=i)
    return b


# ------------------------------------------------------------ segment kernel
SEG_CASES = [
    # (T, lens, block, window)
    (256, [40, 17, 80, 3, 60], 64, 0),       # padding tail + tiny segments
    (256, [128, 128], 128, 0),               # exact tile boundaries
    (192, [1, 1, 190], 64, 0),               # single-token segments
    (256, [40, 17, 80, 3, 60], 64, 16),      # sliding window inside segments
    (768, [300, 200, 150, 100], 256, 0),     # half-step bucket, 256 tiles
]


def _seg_vector(t, lens):
    seg = np.full((t,), len(lens), np.int32)
    off = 0
    for i, ln in enumerate(lens):
        seg[off:off + ln] = i
        off += ln
    return jnp.asarray(seg)


@pytest.mark.parametrize("t,lens,block,window", SEG_CASES)
def test_segment_flash_kernel_matches_ref(t, lens, block, window):
    seg = _seg_vector(t, lens)
    ks = jax.random.split(KEY, 3)
    b, h, kv, d = 2, 4, 2, 64
    q = jax.random.normal(ks[0], (b, t, h, d))
    k = jax.random.normal(ks[1], (b, t, kv, d))
    v = jax.random.normal(ks[2], (b, t, kv, d))
    out = segment_flash_attention(q, k, v, seg, window=window,
                                  block_q=block, block_k=block,
                                  interpret=True)
    want = ref.packed_attention_ref(q, k, v, seg, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_packed_attention_ref_accepts_batched_seg_ids():
    """The oracle takes (T,) or (B,T) seg ids — the same contract the
    kernel documents — and a (B,T) input equal per row matches (T,)."""
    t = 64
    seg = _seg_vector(t, [20, 30, 10])
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, t, 4, 32))
    k = jax.random.normal(ks[1], (2, t, 2, 32))
    v = jax.random.normal(ks[2], (2, t, 2, 32))
    one = ref.packed_attention_ref(q, k, v, seg)
    two = ref.packed_attention_ref(q, k, v, jnp.stack([seg, seg]))
    np.testing.assert_array_equal(np.asarray(one), np.asarray(two))


def test_packed_fallback_matches_ref():
    """The rows-gather CPU fallback and the kernel's reference agree on
    every real token (padding tokens are unspecified by contract)."""
    t, lens, row = 128, [40, 17, 33, 3], 64
    seg = _seg_vector(t, lens)
    starts = jnp.asarray(np.cumsum([0] + lens[:-1]), jnp.int32)
    slens = jnp.asarray(lens, jnp.int32)
    pos = L.packed_positions(seg, starts)
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, t, 4, 64))
    k = jax.random.normal(ks[1], (1, t, 2, 64))
    v = jax.random.normal(ks[2], (1, t, 2, 64))
    out = L.packed_prefill_attention(q, k, v, seg, pos, starts, slens,
                                     row_len=row)
    want = ref.packed_attention_ref(q, k, v, seg)
    real = sum(lens)
    np.testing.assert_allclose(np.asarray(out)[0, :real],
                               np.asarray(want)[0, :real], atol=2e-5)


def test_segments_to_rows_roundtrip():
    lens = [5, 0, 9, 2]
    t = 32
    starts = jnp.asarray(np.cumsum([0] + lens[:-1]), jnp.int32)
    slens = jnp.asarray(lens, jnp.int32)
    seg = _seg_vector(t, lens)
    pos = L.packed_positions(seg, starts)
    x = jax.random.normal(KEY, (t, 3))
    rows = L.segments_to_rows(x, starts, slens, 16)
    assert rows.shape == (4, 16, 3)
    # row i holds segment i's tokens then exact zeros (incl. empty seg 1)
    off = 0
    for i, ln in enumerate(lens):
        np.testing.assert_array_equal(np.asarray(rows)[i, :ln],
                                      np.asarray(x)[off:off + ln])
        assert (np.asarray(rows)[i, ln:] == 0).all()
        off += ln
    back = L.rows_to_segments(rows, seg, pos)
    real = sum(lens)
    np.testing.assert_array_equal(np.asarray(back)[:real],
                                  np.asarray(x)[:real])


# ----------------------------------------- packed vs padded prefill parity
@pytest.mark.parametrize("arch", FAMILIES)
def test_packed_prefill_bit_exact_per_family(arch):
    """THE acceptance bar: packed ragged prefill produces bit-identical
    last-token logits (not just the same argmax) for every segment, vs a
    per-request exact-length prefill, in all four families."""
    cfg = get_config(arch).reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    lens = [5, 12, 3, 8]
    prompts = [_prompt(cfg, i, ln) for i, ln in enumerate(lens)]
    packed = _pack([p["tokens"] for p in prompts], s_max=6, t=32)
    if cfg.has_encoder:
        enc = [p["enc_embeds"] for p in prompts]
        packed["enc_embeds"] = jnp.concatenate(
            enc + [jnp.zeros_like(enc[0])] * (6 - len(enc)), axis=0)
    logits, pcache = api.prefill_packed(params, packed, 16)
    assert int(jnp.asarray(pcache["pos"])[0]) == lens[0]
    for i, p in enumerate(prompts):
        want, _ = api.prefill(params, p, 16)
        np.testing.assert_array_equal(np.asarray(want)[0],
                                      np.asarray(logits)[i])


@pytest.mark.parametrize("arch", ["olmo-1b", "mamba2-1.3b"])
def test_segment_isolation(arch):
    """A token in segment A never attends (or scans) across segment B:
    replacing every other segment's content leaves A's logits bit-equal,
    and A packed-alone equals A packed-with-neighbors."""
    cfg = get_config(arch).reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    a = _prompt(cfg, 0, 9)["tokens"]
    b1 = _prompt(cfg, 1, 6)["tokens"]
    b2 = _prompt(cfg, 2, 6)["tokens"]       # different neighbor content
    lg_b1, _ = api.prefill_packed(params, _pack([a, b1], 4, 16), 16)
    lg_b2, _ = api.prefill_packed(params, _pack([a, b2], 4, 16), 16)
    lg_solo, _ = api.prefill_packed(params, _pack([a], 4, 16), 16)
    np.testing.assert_array_equal(np.asarray(lg_b1)[0],
                                  np.asarray(lg_b2)[0])
    np.testing.assert_array_equal(np.asarray(lg_b1)[0],
                                  np.asarray(lg_solo)[0])
    # and the neighbor really did change ITS OWN logits
    assert not np.array_equal(np.asarray(lg_b1)[1], np.asarray(lg_b2)[1])


# ------------------------------------- insert_many vs sequential inserts
@pytest.mark.parametrize("arch", FAMILIES)
def test_insert_many_matches_sequential_inserts(arch):
    """One packed admission dispatch is bit-equivalent to a chain of
    per-request inserts: same slots, same greedy decode stream, same done
    flags — on the PAGED cache (the direct-to-pages path)."""
    cfg = get_config(arch).reduced()
    lens = [5, 12, 3, 8]
    seq = make_engine(cfg, cache_len=32).init_slots(6, paged=True,
                                                    page_size=8)
    pkd = make_engine(cfg, cache_len=32).init_slots(6, paged=True,
                                                    page_size=8)
    s_seq = [seq.insert(_prompt(cfg, i, ln), n_tokens=6)
             for i, ln in enumerate(lens)]
    s_pkd = pkd.insert_many([_prompt(cfg, i, ln)
                             for i, ln in enumerate(lens)],
                            n_tokens=[6] * len(lens))
    assert s_seq == s_pkd
    assert pkd.stats.prefills == 1 and pkd.stats.packed_prefills == 1
    assert seq.stats.prefills == len(lens)
    for _ in range(6):
        ta, da = seq.step()
        tb, db = pkd.step()
        assert da == db
        np.testing.assert_array_equal(np.asarray(ta)[s_seq],
                                      np.asarray(tb)[s_pkd])


def test_insert_many_writes_identical_paged_cache():
    """Beyond token parity: the page pool CONTENTS after insert_many match
    sequential inserts leaf for leaf (the direct-to-pages scatter writes
    exactly what the per-request dense scatter wrote)."""
    cfg = get_config("olmo-1b").reduced()
    lens = [5, 12, 3]
    seq = make_engine(cfg, cache_len=32).init_slots(4, paged=True,
                                                    page_size=8)
    pkd = make_engine(cfg, cache_len=32).init_slots(4, paged=True,
                                                    page_size=8)
    for i, ln in enumerate(lens):
        seq.insert(_prompt(cfg, i, ln), n_tokens=4)
    pkd.insert_many([_prompt(cfg, i, ln) for i, ln in enumerate(lens)],
                    n_tokens=[4] * len(lens))
    a, b = seq._slot_cache, pkd._slot_cache
    assert set(a) == set(b)
    np.testing.assert_array_equal(np.asarray(a["block_tables"]),
                                  np.asarray(b["block_tables"]))
    np.testing.assert_array_equal(np.asarray(a["pos"]), np.asarray(b["pos"]))
    for key in ("k", "v"):
        av, bv = np.asarray(a[key]), np.asarray(b[key])
        # compare only pages owned by live slots: the sequential path
        # zero-fills the rest of each slot's pages via its dense scatter,
        # the packed path never touches them (both are dead by the
        # lengths contract)
        for slot in range(3):
            for page in seq._kv.pages(slot):
                np.testing.assert_array_equal(av[:, page], bv[:, page])


def test_insert_many_out_of_pages_is_atomic():
    """If the batch cannot be fully paged, NOTHING is claimed: no pages,
    no slots, engine serves the next smaller batch untouched."""
    cfg = get_config("olmo-1b").reduced()
    eng = make_engine(cfg, cache_len=32).init_slots(4, paged=True,
                                                    page_size=8,
                                                    total_pages=5)
    free0 = eng.free_pages
    with pytest.raises(OutOfPages):
        # 2 pages + 4 pages > 5
        eng.insert_many([_prompt(cfg, 0, 8), _prompt(cfg, 1, 8)],
                        n_tokens=[8, 24])
    assert eng.free_pages == free0
    assert eng.free_slots == 4
    slots = eng.insert_many([_prompt(cfg, 0, 8)], n_tokens=[8])
    assert len(slots) == 1 and eng.free_pages == free0 - 2


def test_insert_many_rejects_oversized_prompts():
    cfg = get_config("olmo-1b").reduced()
    eng = make_engine(cfg, cache_len=16).init_slots(2, paged=True,
                                                    page_size=8)
    with pytest.raises(ValueError):
        eng.insert_many([_prompt(cfg, 0, 16)])    # no decode room
    assert eng.free_slots == 2 and eng.free_pages == eng.total_pages


def test_insert_many_then_free_then_reuse_is_fresh():
    """Recycled slots/pages after a packed admission decode exactly like a
    fresh engine — no ghost state from the packed scatter."""
    cfg = get_config("olmo-1b").reduced()
    eng = make_engine(cfg, cache_len=32).init_slots(2, paged=True,
                                                    page_size=8)
    slots = eng.insert_many([_prompt(cfg, 0, 5), _prompt(cfg, 1, 9)],
                            n_tokens=[3, 8])
    for _ in range(3):
        eng.step()
    eng.free(slots[0])
    (sc,) = eng.insert_many([_prompt(cfg, 2, 7)], n_tokens=[5])
    got = [int(np.asarray(eng.step()[0])[sc]) for _ in range(5)]
    solo = make_engine(cfg, cache_len=32).init_slots(2, paged=True,
                                                     page_size=8)
    (sd,) = solo.insert_many([_prompt(cfg, 2, 7)], n_tokens=[5])
    want = [int(np.asarray(solo.step()[0])[sd]) for _ in range(5)]
    assert got == want


# ------------------------------------------------------ compile-count gate
def test_packed_bucket_is_log_spaced():
    assert _packed_bucket(1) == 1
    assert _packed_bucket(5) == 6        # 3·2^1
    assert _packed_bucket(7) == 8
    assert _packed_bucket(96) == 96      # half-steps are exact
    assert _packed_bucket(97) == 128
    assert _packed_bucket(513) == 768
    # a whole octave maps onto two buckets
    assert {_packed_bucket(n) for n in range(65, 129)} == {96, 128}


def test_packed_prefill_compile_count_gate():
    """CI gate: a stream of admission batches with MANY distinct shapes
    (batch size × per-prompt lengths) must compile O(log max_len) packed
    executables — two per octave of total tokens, not one per batch."""
    cfg = get_config("olmo-1b").reduced()
    eng = make_engine(cfg, cache_len=32).init_slots(8, paged=True,
                                                    page_size=8)
    rng = np.random.default_rng(0)
    max_total = max_len = max_batch = 0
    for _ in range(12):
        n = int(rng.integers(1, 9))
        lens = rng.integers(2, 16, size=n).tolist()
        max_total = max(max_total, sum(lens))
        max_len = max(max_len, max(lens))
        max_batch = max(max_batch, n)
        slots = eng.insert_many([_prompt(cfg, i, ln)
                                 for i, ln in enumerate(lens)],
                                n_tokens=[1] * n)
        eng.step()
        for slot in slots:
            eng.free(slot)
    # executables key on (total-token bucket, row bucket, segment
    # bucket): two token buckets per octave, one row bucket per octave
    # of the longest prompt, one segment bucket per octave of the batch
    # size -> log + log + log, never one per batch shape
    bound = (2 * int(np.ceil(np.log2(max(2, max_total))))
             + int(np.ceil(np.log2(max(2, max_len))))
             + int(np.ceil(np.log2(max(2, max_batch)))) + 3)
    n_exec = len(eng._packed_prefill_jit)
    assert n_exec <= bound, (n_exec, bound)
    assert eng.jit_cache_sizes()["packed_prefill"] == n_exec
    # and the insert-side scatter retraces per bucket, never per batch
    assert eng.jit_cache_sizes()["write_segments"] <= bound


def test_engine_prefill_token_stats():
    """prefill_tokens counts REAL prompt tokens: the packed path is
    charged sum(lens), not the bucket."""
    cfg = get_config("olmo-1b").reduced()
    eng = make_engine(cfg, cache_len=32).init_slots(4, paged=True,
                                                    page_size=8)
    eng.insert_many([_prompt(cfg, 0, 5), _prompt(cfg, 1, 9)],
                    n_tokens=[1, 1])
    assert eng.stats.prefill_tokens == 14
    assert eng.stats.inserts == 2
