"""Efficacy metric + optimal (batch, chips) search (paper §5, Eqs. 7-12)."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.efficacy import (BATCH_LEVELS, OperatingPoint, efficacy,
                                 efficacy_surface, feasible, optimize)
from repro.core.latency_model import CHIP_LEVELS, LatencyModel


@pytest.fixture(scope="module")
def lm():
    return LatencyModel(get_config("olmo-1b"), mode="prefill", seq=128)


def test_efficacy_formula():
    assert efficacy(16, 0.01, 0.25) == pytest.approx(16 / (0.01 ** 2 * 0.25))
    assert efficacy(1, 0.0, 0.5) == 0.0


def test_feasibility_constraints():
    # Eq. 12: latency must be <= SLO/2
    assert not feasible(latency=0.03, batch=1, slo=0.05, request_rate=1e9)
    # Eq. 11: assembly + latency <= SLO
    assert not feasible(latency=0.01, batch=100, slo=0.05, request_rate=1000)
    assert feasible(latency=0.01, batch=10, slo=0.05, request_rate=1000)


def test_optimize_respects_constraints(lm):
    pt = optimize(lm, slo=0.05, request_rate=500)
    assert pt.feasible
    assert pt.latency <= 0.025 + 1e-12
    assert pt.latency + pt.batch / 500 <= 0.05 + 1e-12


def test_optimize_is_exhaustive_maximum(lm):
    """Brute-force over the same lattice must agree."""
    slo, rate = 0.05, 500
    pt = optimize(lm, slo=slo, request_rate=rate)
    best = 0.0
    for b in BATCH_LEVELS:
        for c in CHIP_LEVELS:
            lat = lm.latency(c, b)
            if not np.isfinite(lat):
                continue
            if feasible(lat, b, slo, rate) and b / lat >= rate:
                best = max(best, efficacy(b, lat, c / 256))
    assert pt.efficacy == pytest.approx(best)


def test_optimize_infeasible_falls_back():
    lmc = LatencyModel(get_config("chameleon-34b"), mode="prefill", seq=128)
    pt = optimize(lmc, slo=0.0005, request_rate=100)   # 0.5ms SLO: impossible
    assert not pt.feasible


def test_efficacy_surface_interior_maximum(lm):
    """Paper Fig. 7: very low batch and very high batch are both worse than
    the middle at a fixed moderate allocation."""
    grid = efficacy_surface(lm)
    j = CHIP_LEVELS.index(64)
    col = grid[:, j]
    peak = int(np.argmax(col))
    assert col[peak] > col[0] or col[peak] > col[-1]


def test_sustainability_preference():
    lmq = LatencyModel(get_config("qwen2-0.5b"), mode="prefill", seq=128)
    hi = optimize(lmq, slo=0.025, request_rate=8000)
    lo = optimize(lmq, slo=0.025, request_rate=50)
    # at high rate the chosen point must actually sustain the load
    assert hi.batch / hi.latency >= 8000 * 0.99
    assert hi.chips >= lo.chips or hi.batch >= lo.batch
