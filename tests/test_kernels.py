"""Per-kernel validation: Pallas (interpret mode) and jnp production paths
vs the pure-jnp oracles in kernels/ref.py, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_vjp import flash_attention_vjp

KEY = jax.random.PRNGKey(42)


def _mk_qkv(b, s, h, kv, d, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d), jnp.float32).astype(dtype)
    return q, k, v


FLASH_SHAPES = [
    # (b, s, h, kv, d, block)
    (1, 128, 2, 2, 64, 64),
    (2, 256, 4, 2, 64, 128),
    (1, 256, 4, 1, 128, 64),       # MQA, wide head
    (2, 512, 8, 8, 64, 256),       # MHA
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,kv,d,blk", FLASH_SHAPES)
def test_flash_attention_interpret_matches_ref(b, s, h, kv, d, blk, dtype):
    q, k, v = _mk_qkv(b, s, h, kv, d, dtype)
    out = ops.flash_attention(q, k, v, causal=True, block_q=blk, block_k=blk,
                              backend="interpret")
    want = ref.attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_window(window):
    q, k, v = _mk_qkv(2, 256, 4, 2, 64, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=64, block_k=64, backend="interpret")
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_flash_attention_jnp_matches_ref():
    q, k, v = _mk_qkv(2, 384, 6, 3, 64, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                              backend="jnp")
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_flash_block_size_invariance():
    q, k, v = _mk_qkv(1, 256, 2, 2, 64, jnp.float32)
    outs = [ops.flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                                backend="interpret")
            for bq, bk in [(64, 64), (128, 64), (256, 128)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   atol=2e-5)


def test_flash_vjp_grads_match_ref():
    b, s, h, kv, d = 2, 128, 4, 2, 64
    q, k, v = _mk_qkv(b, s, h, kv, d, jnp.float32)
    ct = jax.random.normal(KEY, (b, s, h, d))

    def f_ref(q, k, v):
        kr = jnp.repeat(k, h // kv, 2)
        vr = jnp.repeat(v, h // kv, 2)
        return (ref.attention_ref(q, kr, vr, causal=True) * ct).sum()

    def f_new(q, k, v):
        return (flash_attention_vjp(q, k, v, causal=True,
                                    chunk_q=64, chunk_k=64) * ct).sum()

    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    g_new = jax.grad(f_new, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g_ref, g_new):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-4)


# ---------------------------------------------------------------- SSD ----
SSD_SHAPES = [
    # (b, l, h, p, n, chunk)
    (1, 64, 2, 16, 8, 16),
    (2, 128, 4, 32, 16, 32),
    (1, 256, 2, 64, 64, 64),
    (2, 96, 3, 32, 16, 32),        # l not a multiple of chunk → padding path
]


@pytest.mark.parametrize("b,l,h,p,n,chunk", SSD_SHAPES)
def test_ssd_jnp_matches_sequential_oracle(b, l, h, p, n, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = -jnp.exp(0.5 * jax.random.normal(ks[2], (h,)))
    bb = jax.random.normal(ks[3], (b, l, n))
    cc = jax.random.normal(ks[4], (b, l, n))
    y_ref, s_ref = ref.ssd_ref(x, dt, a, bb, cc)
    y, s = ops.ssd(x, dt, a, bb, cc, chunk=chunk, backend="jnp")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("b,l,h,p,n,chunk", SSD_SHAPES[:3])
def test_ssd_pallas_interpret_matches_oracle(b, l, h, p, n, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = -jnp.exp(0.5 * jax.random.normal(ks[2], (h,)))
    bb = jax.random.normal(ks[3], (b, l, n))
    cc = jax.random.normal(ks[4], (b, l, n))
    y_ref, s_ref = ref.ssd_ref(x, dt, a, bb, cc)
    y, s = ops.ssd(x, dt, a, bb, cc, chunk=chunk, backend="interpret")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               atol=2e-3, rtol=2e-3)


def test_ssd_chunk_size_invariance():
    ks = jax.random.split(KEY, 5)
    b, l, h, p, n = 1, 128, 2, 16, 8
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = -jnp.exp(0.5 * jax.random.normal(ks[2], (h,)))
    bb = jax.random.normal(ks[3], (b, l, n))
    cc = jax.random.normal(ks[4], (b, l, n))
    outs = [ops.ssd(x, dt, a, bb, cc, chunk=c, backend="jnp")[0]
            for c in (16, 32, 64, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   atol=2e-3, rtol=2e-3)


def test_ssd_decode_matches_sequential():
    """Running ssd_decode token by token == the full-sequence oracle."""
    ks = jax.random.split(KEY, 5)
    b, l, h, p, n = 2, 32, 2, 16, 8
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = -jnp.exp(0.5 * jax.random.normal(ks[2], (h,)))
    bb = jax.random.normal(ks[3], (b, l, n))
    cc = jax.random.normal(ks[4], (b, l, n))
    y_ref, s_ref = ref.ssd_ref(x, dt, a, bb, cc)
    state = jnp.zeros((b, h, n, p))
    ys = []
    for t in range(l):
        y, state = ops.ssd_decode(x[:, t], dt[:, t], a, bb[:, t], cc[:, t],
                                  state)
        ys.append(y)
    y_dec = jnp.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_ref),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(state), np.asarray(s_ref),
                               atol=2e-3, rtol=2e-3)


# ------------------------------------------------------- decode attention
DECODE_SHAPES = [
    # (b, h, kv, d, cache, valid, block)
    (2, 8, 2, 64, 256, 200, 64),
    (1, 4, 4, 128, 512, 512, 128),
    (2, 14, 2, 64, 256, 100, 64),      # qwen2-like non-divisible heads
    (3, 8, 1, 64, 128, 77, 64),        # MQA
]


@pytest.mark.parametrize("b,h,kv,d,c,valid,blk", DECODE_SHAPES)
def test_decode_attention_kernel_matches_ref(b, h, kv, d, c, valid, blk):
    from repro.kernels.decode_attention import decode_attention
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, d))
    kc = jax.random.normal(ks[1], (b, c, kv, d))
    vc = jax.random.normal(ks[2], (b, c, kv, d))
    out = decode_attention(q, kc, vc, valid, block_k=blk, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_kernel_dtypes(dtype):
    from repro.kernels.decode_attention import decode_attention
    ks = jax.random.split(KEY, 3)
    b, h, kv, d, c = 2, 8, 2, 64, 256
    q = jax.random.normal(ks[0], (b, h, d)).astype(dtype)
    kc = jax.random.normal(ks[1], (b, c, kv, d)).astype(dtype)
    vc = jax.random.normal(ks[2], (b, c, kv, d)).astype(dtype)
    out = decode_attention(q, kc, vc, 256, block_k=64, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, 256)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_vjp_q_offset_matches_sliced_full():
    """Context-parallel building block: a q slice with q_offset must equal
    the same rows of full attention."""
    from repro.kernels.flash_vjp import flash_attention_vjp
    ks = jax.random.split(KEY, 3)
    b, s, h, d = 1, 256, 2, 64
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    full = ref.attention_ref(q, k, v, causal=True)
    for lo in (0, 64, 128):
        part = flash_attention_vjp(q[:, lo:], k, v, causal=True,
                                   chunk_q=64, chunk_k=64, q_offset=lo)
        np.testing.assert_allclose(np.asarray(part),
                                   np.asarray(full[:, lo:]), atol=2e-5)


# ---------------------------------------------------------------------------
# incremental chunk attention (paged history + new chunk rows)
# ---------------------------------------------------------------------------
def _mk_chunk_case(seed, s, r, h, kv, d, page_size, max_pages, hists, slens):
    """One paged chunk-attention case plus its dense-ref twin.

    Pages are permuted non-contiguously across segments (each segment's
    block table scatters through the shared pool) so any confusion of
    physical/logical pages or cross-segment leakage shows up as a
    numeric mismatch, not a silent pass."""
    rng = np.random.default_rng(seed)
    n_pages = s * max_pages + 1              # +1: a never-referenced page
    q = rng.standard_normal((s, r, h, d), np.float32)
    kc = rng.standard_normal((s, r, kv, d), np.float32)
    vc = rng.standard_normal((s, r, kv, d), np.float32)
    k_pages = rng.standard_normal((n_pages, page_size, kv, d), np.float32)
    v_pages = rng.standard_normal((n_pages, page_size, kv, d), np.float32)
    perm = rng.permutation(n_pages - 1) + 1  # page 0 never used: catches
    tables = perm[:s * max_pages].reshape(s, max_pages)  # accidental zeros
    cap = max_pages * page_size
    k_hist = k_pages[tables].reshape(s, cap, kv, d)
    v_hist = v_pages[tables].reshape(s, cap, kv, d)
    return (jnp.asarray(q), jnp.asarray(k_pages), jnp.asarray(v_pages),
            jnp.asarray(kc), jnp.asarray(vc), jnp.asarray(tables),
            jnp.asarray(hists, jnp.int32), jnp.asarray(slens, jnp.int32),
            jnp.asarray(k_hist), jnp.asarray(v_hist))


CHUNK_SHAPES = [
    # (s, r, h, kv, d, page_size, max_pages, hists, slens)
    (2, 4, 4, 2, 32, 8, 3, (8, 16), (4, 4)),     # page-aligned histories
    (3, 8, 4, 4, 32, 8, 4, (5, 13, 0), (8, 3, 6)),  # mid-page + fresh seq
    (1, 16, 8, 2, 64, 16, 2, (13,), (16,)),      # chunk crosses a page edge
    (2, 8, 2, 1, 32, 8, 2, (1, 7), (1, 8)),      # MQA, ragged seg lens
]


@pytest.mark.parametrize("s,r,h,kv,d,ps,mp,hists,slens", CHUNK_SHAPES)
def test_chunk_attention_interpret_matches_ref(s, r, h, kv, d, ps, mp,
                                               hists, slens):
    from repro.kernels.chunk_attention import paged_chunk_attention
    case = _mk_chunk_case(0, s, r, h, kv, d, ps, mp, hists, slens)
    q, kp, vp, kc, vc, tbl, hist, slen, kh, vh = case
    out = paged_chunk_attention(q, kp, vp, kc, vc, tbl, hist, slen,
                                interpret=True)
    want = ref.chunk_attention_ref(q, kh, vh, kc, vc, hist)
    for i in range(s):
        n = int(slen[i])
        np.testing.assert_allclose(
            np.asarray(out)[i, :n], np.asarray(want)[i, :n],
            atol=2e-5, rtol=2e-5, err_msg=f"segment {i}")


@pytest.mark.parametrize("window", [4, 16])
def test_chunk_attention_interpret_window(window):
    from repro.kernels.chunk_attention import paged_chunk_attention
    case = _mk_chunk_case(1, 2, 8, 4, 2, 32, 8, 3, (19, 7), (8, 8))
    q, kp, vp, kc, vc, tbl, hist, slen, kh, vh = case
    out = paged_chunk_attention(q, kp, vp, kc, vc, tbl, hist, slen,
                                window=window, interpret=True)
    want = ref.chunk_attention_ref(q, kh, vh, kc, vc, hist, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("s,r,h,kv,d,ps,mp,hists,slens", CHUNK_SHAPES)
def test_chunk_attention_fallback_matches_ref(s, r, h, kv, d, ps, mp,
                                              hists, slens):
    """The jnp gather/scatter fallback (the path CPU serving runs) against
    the same oracle — both backends share one contract."""
    from repro.models import layers as L
    case = _mk_chunk_case(2, s, r, h, kv, d, ps, mp, hists, slens)
    q, kp, vp, kc, vc, tbl, hist, slen, kh, vh = case
    out = L.paged_chunk_attention(q, kp, vp, kc, vc, tbl, hist, slen)
    want = ref.chunk_attention_ref(q, kh, vh, kc, vc, hist)
    for i in range(s):
        n = int(slen[i])
        np.testing.assert_allclose(
            np.asarray(out)[i, :n], np.asarray(want)[i, :n],
            atol=2e-5, rtol=2e-5, err_msg=f"segment {i}")


def test_chunk_attention_segment_isolation():
    """Perturbing one segment's history pages must not move any other
    segment's output (the packed verify dispatch mixes many requests)."""
    from repro.kernels.chunk_attention import paged_chunk_attention
    case = _mk_chunk_case(3, 3, 4, 4, 2, 32, 8, 3, (11, 8, 20), (4, 4, 4))
    q, kp, vp, kc, vc, tbl, hist, slen, _, _ = case
    base = np.asarray(paged_chunk_attention(q, kp, vp, kc, vc, tbl, hist,
                                            slen, interpret=True))
    victim_pages = np.asarray(tbl)[1]            # segment 1's pages
    kp2 = jnp.asarray(np.asarray(kp)).at[jnp.asarray(victim_pages)].set(7.0)
    out = np.asarray(paged_chunk_attention(q, kp2, vp, kc, vc, tbl, hist,
                                           slen, interpret=True))
    assert not np.allclose(base[1], out[1])      # victim did change
    np.testing.assert_array_equal(base[0], out[0])
    np.testing.assert_array_equal(base[2], out[2])
