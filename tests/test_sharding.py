"""Logical-axis sharding rules: divisibility fallback + plan/spec parity."""
import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ARCHS
from repro.models.layers import ParamDef
from repro.models.registry import build_model
from repro.utils.sharding import resolve_spec, tree_specs


def _mesh(shape=(2, 4), axes=("data", "model")):
    devs = np.array(jax.devices() * int(np.prod(shape)))[: int(np.prod(shape))]
    return Mesh(devs.reshape(shape), axes)


def test_divisible_dim_shards():
    mesh = _mesh()
    spec = resolve_spec(("vocab", "embed"), (64_000, 512), mesh)
    assert spec == P("model")


def test_non_divisible_dim_replicates():
    mesh = _mesh()
    spec = resolve_spec(("vocab", "embed"), (51_865, 512), mesh)
    assert spec == P()


def test_head_dim_fallback():
    mesh = _mesh()
    # 14 heads don't divide 4-way model axis; head_dim 64 does
    spec = resolve_spec(("embed", "heads", "head_dim"), (896, 14, 64), mesh)
    assert spec == P(None, None, "model")
    # 16 heads divide: heads take the axis, head_dim must NOT reuse it
    spec = resolve_spec(("embed", "heads", "head_dim"), (896, 16, 64), mesh)
    assert spec == P(None, "model")


def test_batch_axes_multi_pod():
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    spec = resolve_spec(("batch", None), (16, 128), mesh)
    assert spec == P(("pod", "data"))
    # batch=1 cannot shard over 4 ways
    spec = resolve_spec(("batch", None), (1, 128), mesh)
    assert spec == P()


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_specs_structure_matches_params(arch):
    cfg = ARCHS[arch].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    mesh = _mesh((1, 1))
    specs = api.param_specs(mesh)
    t1 = jax.tree_util.tree_structure(params)
    t2 = jax.tree_util.tree_structure(specs)
    assert t1 == t2


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_cache_specs_structure_matches_cache(arch):
    cfg = ARCHS[arch].reduced()
    api = build_model(cfg)
    cache = api.init_cache(2, 32)
    mesh = _mesh((1, 1))
    specs = api.cache_specs(mesh, 2, 32)
    assert (jax.tree_util.tree_structure(cache)
            == jax.tree_util.tree_structure(specs))


@settings(max_examples=50, deadline=None)
@given(dim=st.integers(min_value=1, max_value=4096),
       axis=st.sampled_from([2, 4, 8]))
def test_property_resolve_never_invalid(dim, axis):
    mesh = _mesh((1, axis))
    spec = resolve_spec(("mlp",), (dim,), mesh)
    if dim % axis == 0:
        assert spec == P("model")
    else:
        assert spec == P()
