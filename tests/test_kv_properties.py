"""Property tests for the page allocator / paged KV bookkeeping.

``test_paged_kv.py`` covers hand-picked sequences; these drive RANDOM
alloc/free/append (topup) interleavings and assert the safety invariants
the serving engine's correctness rests on:

  * no physical page is ever owned by two live rows (aliasing would let
    one sequence overwrite another's KV),
  * page conservation: free + owned == pool size, always,
  * the reserved null page is never handed out,
  * a failed (OutOfPages) operation leaves every row and the free count
    exactly as they were (all-or-nothing).

With the radix prompt cache (ISSUE 8) pages are refcounted and the rules
generalize; the sharing churn below drives register/alias/evict/COW
interleavings on top and asserts:

  * rows share a page ONLY when it was aliased through the cache — no
    aliasing across unrelated requests,
  * refcount conservation: every allocated page's refcount equals its
    row holders plus the cache's holds (``check_invariants(extra_refs)``),
  * a failed alias admission (OutOfPages) changes nothing and leaves the
    caller's match-time pins intact (all-or-nothing, COW pin included).

Seeded-random siblings that need no hypothesis install live in
``test_paged_kv.py`` (``test_random_churn_invariants_seeded`` /
``test_shared_churn_invariants_seeded``); this file skips cleanly where
hypothesis is absent (CI installs it).
"""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.serving.kv_cache import (NULL_PAGE, OutOfPages, PageAllocator,
                                    PagedKVCache, pages_for)


def _check_invariants(kv: PagedKVCache):
    # the shipped audit the chaos suite runs after every fault recovery
    # (ISSUE 6 satellite) — must agree with this suite's independent
    # re-derivation below on every random interleaving
    kv.check_invariants()
    owned = []
    for row in range(kv.batch):
        pages = kv.pages(row)
        # every live row's table is consistent with its length
        if pages:
            assert len(pages) == pages_for(kv.length(row), kv.page_size)
        assert NULL_PAGE not in pages
        owned.extend(pages)
    # no page aliased by two live rows
    assert len(owned) == len(set(owned))
    # conservation: free + owned == pool
    assert kv.free_pages + len(owned) == kv.allocator.num_pages
    assert all(1 <= p <= kv.allocator.num_pages for p in owned)


# op encoding: (kind, row, amount) — kind 0=alloc, 1=append, 2=free
_ops = st.lists(st.tuples(st.integers(0, 2), st.integers(0, 5),
                          st.integers(1, 40)), max_size=60)


@settings(max_examples=60, deadline=None)
@given(ops=_ops, page_size=st.sampled_from([4, 8]),
       num_pages=st.integers(4, 24))
def test_paged_kv_random_churn_invariants(ops, page_size, num_pages):
    kv = PagedKVCache(batch=6, page_size=page_size, max_pages=6,
                      num_pages=num_pages)
    for kind, row, amount in ops:
        before = (kv.free_pages, kv.length(row), tuple(kv.pages(row)))
        try:
            if kind == 0 and not kv.pages(row):
                kv.alloc(row, amount)
            elif kind == 1 and kv.pages(row):
                kv.append(row, amount)
            elif kind == 2:
                kv.free(row)
        except OutOfPages:
            # all-or-nothing: the failed op changed NOTHING
            assert kv.free_pages == before[0]
            assert kv.length(row) == before[1]
            assert tuple(kv.pages(row)) == before[2]
        _check_invariants(kv)
    kv.reset()
    assert kv.free_pages == kv.allocator.num_pages


@settings(max_examples=60, deadline=None)
@given(sizes=st.lists(st.integers(1, 6), min_size=1, max_size=20),
       num_pages=st.integers(1, 16))
def test_allocator_never_hands_out_null_or_duplicate(sizes, num_pages):
    a = PageAllocator(num_pages)
    live = []
    for i, n in enumerate(sizes):
        try:
            got = a.alloc(n)
        except OutOfPages:
            assert n > a.free_pages
            continue
        assert NULL_PAGE not in got
        assert not set(got) & set(p for ps in live for p in ps)
        live.append(got)
        if i % 3 == 2 and live:           # interleave frees
            a.free(live.pop(0))
        a.check_invariants()              # the shipped conservation audit
    assert a.free_pages + sum(len(ps) for ps in live) == num_pages
    assert a.check_invariants()


# sharing churn op encoding: (kind, row, amount) — kind 0=alloc,
# 1=append, 2=free, 3=register (cache takes refs on a live row's pages),
# 4=alias-admit (pin cached pages + optional COW pin, adopt via
# alloc_alias), 5=evict (cache drops refs)
_share_ops = st.lists(st.tuples(st.integers(0, 5), st.integers(0, 5),
                                st.integers(1, 40)), max_size=80)


@settings(max_examples=60, deadline=None)
@given(ops=_share_ops, page_size=st.sampled_from([4, 8]),
       num_pages=st.integers(4, 24))
def test_shared_pages_random_churn(ops, page_size, num_pages):
    """Random churn with an external cache holder in the loop — the
    refcounted generalization of the churn above."""
    kv = PagedKVCache(batch=6, page_size=page_size, max_pages=6,
                      num_pages=num_pages)
    cache = {}                 # page -> refs the simulated radix tree holds
    shared_origin = set()      # pages that were ever aliased via the cache
    for kind, row, amount in ops:
        before_free = kv.free_pages
        before = {r: (kv.length(r), tuple(kv.pages(r))) for r in range(6)}
        before_cache = dict(cache)
        try:
            if kind == 0 and not kv.pages(row):
                kv.alloc(row, amount)
            elif kind == 1 and kv.pages(row):
                kv.append(row, amount)
            elif kind == 2:
                kv.free(row)
            elif kind == 3 and kv.pages(row):
                # register: one cache ref per page, deduped like the tree
                fresh = [p for p in kv.pages(row) if p not in cache]
                kv.allocator.share(fresh)
                cache.update({p: 1 for p in fresh})
            elif kind == 4 and not kv.pages(row) and cache:
                # alias-admit: pin a prefix of the cached pages (plus an
                # optional COW source pin), adopt the prefix pins into the
                # row, and return the COW pin once the "copy" lands
                held = sorted(cache)[:max(1, amount % (len(cache) + 1))]
                tokens = min(len(held) * page_size + 1 + amount % page_size,
                             6 * page_size)
                if pages_for(tokens, page_size) <= len(held):
                    continue               # alias would cover everything
                cow = None
                if amount % 2 and len(cache) > len(held):
                    cow = sorted(cache)[len(held)]
                kv.allocator.share(held)             # match-time pins
                if cow is not None:
                    kv.allocator.share([cow])
                try:
                    kv.alloc_alias(row, held, tokens)
                    shared_origin.update(held)
                    if cow is not None:              # copy landed
                        kv.allocator.release([cow])
                except OutOfPages:
                    # pins stay valid on failure; return them like the
                    # engine's release_hit
                    assert all(kv.allocator.refcount(p) > 0 for p in held)
                    kv.allocator.release(held)
                    if cow is not None:
                        kv.allocator.release([cow])
                    raise
            elif kind == 5 and cache:
                drop = sorted(cache)[:max(1, amount % (len(cache) + 1))]
                kv.allocator.release(drop)
                for p in drop:
                    del cache[p]
        except OutOfPages:
            # all-or-nothing: rows, free count, and cache holds unchanged
            assert kv.free_pages == before_free
            assert cache == before_cache
            for r in range(6):
                assert (kv.length(r), tuple(kv.pages(r))) == before[r]
        # the shipped audit with the cache's holds declared
        kv.check_invariants(extra_refs=dict(cache))
        owned = [p for r in range(6) for p in kv.pages(r)]
        # no aliasing across unrelated requests: a page in two rows'
        # tables must have been shared through the cache
        multi = {p for p in owned if owned.count(p) > 1}
        assert multi <= shared_origin, multi - shared_origin
        # conservation under sharing: distinct held pages + free == pool
        assert kv.free_pages + len(set(owned) | set(cache)) == num_pages
    # teardown drains every reference — nothing leaks
    kv.allocator.release(list(cache))
    kv.reset()
    assert kv.free_pages == num_pages
    assert kv.check_invariants()


@settings(max_examples=40, deadline=None)
@given(tokens=st.integers(0, 100), page_size=st.sampled_from([1, 4, 8, 16]))
def test_pages_for_bounds(tokens, page_size):
    n = pages_for(tokens, page_size)
    assert n >= 1                          # live rows always own a page
    assert n * page_size >= tokens         # enough room
    assert (n - 1) * page_size < max(1, tokens) or n == 1   # no surplus page
