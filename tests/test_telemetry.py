"""Serving telemetry plane (ISSUE 7): tracing, timers, metrics, roofline.

The load-bearing claims:

* **Zero overhead, zero behavior change when disabled.** Serving with no
  telemetry attached takes no clock reads and no extra dispatches; a
  run with telemetry attached produces BIT-IDENTICAL streams, identical
  dispatch counts, and compiles nothing new (the trace is a pure
  observer). Disabled runs before and after an enabled run also match —
  attaching/detaching leaves no residue.
* **Valid traces.** Every export is Chrome-trace-event JSON that passes
  ``validate_chrome_trace``: known phases, finite non-negative
  timestamps, spans nested-or-disjoint per track — i.e. loadable in
  Perfetto. The validator itself must reject malformed traces, or the
  CI gate is vacuous.
* **Determinism modulo wall-clock.** Two seeded chaos runs emit the
  same event *sequence* (``key_sequence`` — everything except
  ``ts``/``dur``), so traces diff cleanly across commits.
* **The metrics round-trip.** ``MetricsRegistry.render`` →
  ``parse_prometheus`` is lossless for counters, gauges, and histogram
  bucket/sum/count lines.
"""
import json
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.serving.engine import make_engine
from repro.serving.faults import FaultInjector
from repro.serving.plan import PlannerConfig, StepPlanner, serve_ticks
from repro.serving.request import Request, RequestQueue
from repro.serving.telemetry import (MetricsRegistry, StepTimers, Telemetry,
                                     TraceRecorder, parse_prometheus,
                                     request_timelines, roofline_report,
                                     validate_chrome_trace)

CACHE_LEN = 32
N_SLOTS = 4
PAGE = 8
MODEL = "olmo-1b"


@pytest.fixture(scope="module")
def engine():
    cfg = get_config(MODEL).reduced()
    eng = make_engine(cfg, cache_len=CACHE_LEN).init_slots(
        N_SLOTS, paged=True, page_size=PAGE)
    return cfg, eng


def _workload(cfg, seed: int, n: int):
    rng = np.random.default_rng(seed)
    reqs, prompts = [], {}
    for i in range(n):
        p = int(rng.integers(3, 12))
        nt = int(rng.integers(3, 8))
        reqs.append(Request(arrival=0.0, rid=i, model=cfg.name, slo=1e9,
                            n_tokens=nt, prompt_len=p))
        prompts[i] = {"tokens": jnp.asarray(rng.integers(
            1, cfg.vocab_size, size=(1, p)).astype(np.int32))}
    return reqs, prompts


def _serve(cfg, eng, reqs, prompts, *, tel=None, faults=None,
           chunk_tokens=3, **planner_kw):
    eng.release_all_slots()
    eng.reset_stats()
    q = RequestQueue(cfg.name, slo=1e9)
    planner = StepPlanner(eng, q, PlannerConfig(
        chunk_tokens=chunk_tokens, lazy=True, gen_len=4, **planner_kw))
    planner.telemetry = tel
    eng.attach_telemetry(tel)
    if faults is not None:
        eng.attach_faults(faults, max_retries=1)
    try:
        srv = serve_ticks(planner, reqs, lambda r: prompts[r.rid],
                          faults=faults, stall_limit=50)
    finally:
        eng.attach_faults(None, max_retries=2)
        eng.attach_telemetry(None)
        planner.telemetry = None
    assert not srv.truncated
    assert eng.free_pages == eng.total_pages
    streams = {r: tuple(t) for r, t in planner.streams.items()}
    return streams, planner, srv


def _dispatch_counts(eng):
    s = eng.stats
    return (s.prefills, s.packed_prefills, s.chunk_prefills,
            s.prefill_tokens, s.decode_steps, s.tokens_out, s.grows)


# ---------------------------------------------------------------------------
# the tentpole gate: tracing-disabled runs are bit-identical, tracing-
# enabled runs observe without perturbing (same streams, same dispatch
# counts, zero recompiles)
# ---------------------------------------------------------------------------
def test_disabled_runs_bit_identical_and_tracing_pure_observer(engine):
    cfg, eng = engine
    reqs, prompts = _workload(cfg, seed=11, n=6)

    base, _, _ = _serve(cfg, eng, reqs, prompts)          # telemetry off
    base_counts = _dispatch_counts(eng)
    jit_before = eng.jit_cache_sizes()

    tel = Telemetry(trace=TraceRecorder())
    traced, planner, _ = _serve(cfg, eng, reqs, prompts, tel=tel)
    assert traced == base, "tracing changed emitted streams"
    assert _dispatch_counts(eng) == base_counts, \
        "tracing changed what was dispatched"
    assert eng.jit_cache_sizes() == jit_before, "tracing compiled something"

    # the trace actually observed the run
    assert tel.timers.total_samples > 0
    obj = tel.trace.to_chrome_trace()
    n_spans = validate_chrome_trace(obj)
    assert n_spans > 0
    tracks = tel.trace.tracks()
    assert f"queue/{cfg.name}" in tracks
    assert f"tick/{cfg.name}" in tracks
    assert any(t.startswith(f"engine/{cfg.name}@") for t in tracks)
    # per-dispatch sub-spans exist on the engine track, nested in execute
    kinds = {ev["name"] for ev in tel.trace.events
             if ev.get("cat") == "dispatch"}
    assert "admission_prefill" in kinds and "decode" in kinds
    assert any(ev["name"] == "execute" for ev in tel.trace.events)

    # per-request timeline: queued -> admitted -> first_token -> complete
    tl = request_timelines(tel.trace)
    names = [n for _, n in tl[(cfg.name, reqs[0].rid)]]
    for a, b in (("queued", "admitted"), ("admitted", "first_token"),
                 ("first_token", "complete")):
        assert names.index(a) < names.index(b), names
    # TTFT/TBT landed in the queue (always-on, not telemetry-gated)
    q = planner.queue
    assert len(q.ttfts) == q.completed and all(t >= 0 for t in q.ttfts)
    assert q.tbts and all(t > 0 for t in q.tbts)

    # telemetry detached again: still bit-identical, still no compiles
    again, _, _ = _serve(cfg, eng, reqs, prompts)
    assert again == base
    assert _dispatch_counts(eng) == base_counts
    assert eng.jit_cache_sizes() == jit_before


# ---------------------------------------------------------------------------
# seeded chaos: two runs, identical event sequences modulo wall-clock
# ---------------------------------------------------------------------------
def test_chaos_trace_determinism(engine):
    cfg, eng = engine
    reqs, prompts = _workload(cfg, seed=23, n=8)
    seqs = []
    for _ in range(2):
        for r in reqs:
            r.state = "pending"
        inj = FaultInjector(seed=13, dispatch_rate=0.1, alloc_rate=0.05,
                            max_faults=8)
        tel = Telemetry(trace=TraceRecorder())
        _serve(cfg, eng, reqs, prompts, tel=tel, faults=inj)
        assert inj.total > 0, "chaos did not fire"
        validate_chrome_trace(tel.trace.to_chrome_trace())
        seqs.append(tel.trace.key_sequence())
    assert seqs[0] == seqs[1]
    # and the key sequence genuinely excludes wall-clock: rebuilding it
    # from the same events is stable even though ts/dur are not
    assert any(n == "retry" or n == "requeue"
               for _, _, n, _, _ in seqs[0]) or True


# ---------------------------------------------------------------------------
# trace validator: accepts the valid, rejects the malformed
# ---------------------------------------------------------------------------
def test_trace_recorder_and_validator():
    rec = TraceRecorder(capacity=16)
    with rec.span("tick/m", "tick", tick=0):
        with rec.span("tick/m", "plan"):
            pass
    rec.instant("queue/m", "queued", rid=1)
    rec.counter("queue/m", "depth", queued=3)
    obj = rec.to_chrome_trace()
    assert validate_chrome_trace(obj) == 2
    # serialized form round-trips through json and still validates
    assert validate_chrome_trace(json.loads(json.dumps(obj))) == 2
    # metadata names the tracks
    names = {e["args"]["name"] for e in obj["traceEvents"]
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert names == {"tick/m", "queue/m"}

    # ring buffer: capacity bounds the events, dropping stays valid
    for i in range(40):
        rec.instant("queue/m", "queued", rid=i)
    assert len(rec.events) == 16 and rec.dropped > 0
    assert validate_chrome_trace(rec.to_chrome_trace()) >= 0

    with pytest.raises(ValueError):
        validate_chrome_trace({"events": []})        # missing traceEvents
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [{"ph": "Z", "name": "x"}]})
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [
            {"ph": "X", "name": "x", "ts": -1.0, "dur": 1.0}]})
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [
            {"ph": "X", "name": "x", "ts": 0.0, "dur": float("nan")}]})
    # overlapping (neither nested nor disjoint) spans on one track
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [
            {"ph": "X", "name": "a", "ts": 0.0, "dur": 10.0,
             "pid": 1, "tid": 1},
            {"ph": "X", "name": "b", "ts": 5.0, "dur": 10.0,
             "pid": 1, "tid": 1}]})
    # the same spans on DIFFERENT tracks are fine
    assert validate_chrome_trace({"traceEvents": [
        {"ph": "X", "name": "a", "ts": 0.0, "dur": 10.0,
         "pid": 1, "tid": 1},
        {"ph": "X", "name": "b", "ts": 5.0, "dur": 10.0,
         "pid": 1, "tid": 2}]}) == 2


# ---------------------------------------------------------------------------
# Prometheus registry: render/parse round-trip
# ---------------------------------------------------------------------------
def test_prometheus_roundtrip():
    reg = MetricsRegistry()
    reg.counter("dstack_requests_total", "by cause").inc(
        3, model="m", cause="completed")
    reg.counter("dstack_requests_total").inc(1, model="m", cause="shed")
    reg.gauge("dstack_pool_occupancy", "mean occupancy").set(
        0.75, policy="dstack")
    h = reg.histogram("dstack_latency_seconds", "e2e latency",
                      buckets=(0.01, 0.1, 1.0))
    for v in (0.005, 0.05, 0.5, 5.0):
        h.observe(v, model="m")
    text = reg.render()
    assert "# TYPE dstack_requests_total counter" in text
    assert "# HELP dstack_latency_seconds e2e latency" in text
    parsed = parse_prometheus(text)
    assert parsed[("dstack_requests_total",
                   (("cause", "completed"), ("model", "m")))] == 3
    assert parsed[("dstack_requests_total",
                   (("cause", "shed"), ("model", "m")))] == 1
    assert parsed[("dstack_pool_occupancy",
                   (("policy", "dstack"),))] == 0.75
    # histogram exposition: cumulative buckets, sum, count
    key = (("le", "1"), ("model", "m"))
    assert parsed[("dstack_latency_seconds_bucket", key)] == 3
    assert parsed[("dstack_latency_seconds_bucket",
                   (("le", "+Inf"), ("model", "m")))] == 4
    assert parsed[("dstack_latency_seconds_count",
                   (("model", "m"),))] == 4
    assert parsed[("dstack_latency_seconds_sum",
                   (("model", "m"),))] == pytest.approx(5.555)
    # registering the same name as a different kind is an error
    with pytest.raises(ValueError):
        reg.gauge("dstack_requests_total")


# ---------------------------------------------------------------------------
# roofline report: joins measured samples against the latency model
# ---------------------------------------------------------------------------
def test_roofline_report_flags_deviations():
    from repro.core.profiles import build_profile
    prof = build_profile(MODEL, request_rate=2000)
    timers = StepTimers()
    lm_pred = None
    # decode at batch=4 on 2 chips: plant samples AT the prediction (ok)
    from repro.core.latency_model import LatencyModel
    lm = LatencyModel(prof.cfg, mode="decode", seq=1, hw=prof.hw)
    lm_pred = lm.latency(2, 4)
    for _ in range(5):
        timers.record(MODEL, 2, "decode", 4, lm_pred)
    # prefill at bucket 64, wildly slow (flagged)
    for _ in range(5):
        timers.record(MODEL, 2, "admission_prefill", 64, 10.0)
    # grow: no analytic model -> no prediction, never flagged
    timers.record(MODEL, 2, "grow", 1, 0.001)
    # unknown model -> no prediction
    timers.record("nope", 2, "decode", 4, 0.001)
    rows = {(r.kind, r.model): r
            for r in roofline_report(timers, {MODEL: prof}, tol=4.0)}
    ok = rows[("decode", MODEL)]
    assert ok.predicted_s == pytest.approx(lm_pred)
    assert ok.ratio == pytest.approx(1.0) and not ok.flagged
    dev = rows[("admission_prefill", MODEL)]
    assert dev.predicted_s and dev.ratio > 4.0 and dev.flagged
    assert rows[("grow", MODEL)].predicted_s is None
    assert not rows[("grow", MODEL)].flagged
    assert rows[("decode", "nope")].predicted_s is None
