"""Scheduler invariants + policy behavior, incl. hypothesis property tests."""
import math

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.profiles import build_profile
from repro.core.scheduler import POLICIES, IdealSimulator
from repro.core.simulator import SimConfig, Simulator
from repro.serving.request import Request, RequestGenerator

NAMES = ["qwen2-0.5b", "mamba2-1.3b", "deepseek-7b", "yi-9b"]


def _profiles(rate=2000):
    return {n: build_profile(n, request_rate=rate) for n in NAMES}


def _gens(profiles, rate=2000, seed0=0):
    return [RequestGenerator(n, rate, profiles[n].slo, seed=seed0 + i)
            for i, n in enumerate(profiles)]


class _InvariantSim(Simulator):
    """Simulator that records the oversubscription invariant."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.max_alloc = 0.0
        self.oversubscribed = False

    def _start_runs(self, now, reqs):
        super()._start_runs(now, reqs)
        alloc = sum(r.frac for r in self.running)
        self.max_alloc = max(self.max_alloc, alloc)
        if any(not rr.oversubscribe for rr in reqs) and alloc > 1.0 + 1e-6:
            self.oversubscribed = True


@pytest.mark.parametrize("policy", ["temporal", "gslice", "triton",
                                    "maxmin", "max_throughput", "dstack"])
def test_no_oversubscription(policy):
    profiles = _profiles()
    sim = _InvariantSim(profiles, POLICIES[policy](profiles),
                        _gens(profiles), SimConfig(duration=1.0))
    sim.run()
    assert not sim.oversubscribed, f"{policy} oversubscribed the pod"
    assert sim.max_alloc <= 1.0 + 1e-6


def test_temporal_runs_one_at_a_time():
    profiles = _profiles()

    class Watch(Simulator):
        max_conc = 0

        def _start_runs(self, now, reqs):
            super()._start_runs(now, reqs)
            Watch.max_conc = max(Watch.max_conc, len(self.running))

    sim = Watch(profiles, POLICIES["temporal"](profiles), _gens(profiles),
                SimConfig(duration=0.5))
    sim.run()
    assert Watch.max_conc == 1


def test_dstack_beats_temporal_throughput():
    # rate high enough that temporal saturates (else D-STACK is merely
    # arrival-bound and the ratio reflects the offered load, not capacity)
    p1 = _profiles(rate=4000)
    r_t = Simulator(p1, POLICIES["temporal"](p1), _gens(p1, rate=4000),
                    SimConfig(duration=2.0)).run()
    p2 = _profiles(rate=4000)
    r_d = Simulator(p2, POLICIES["dstack"](p2), _gens(p2, rate=4000),
                    SimConfig(duration=2.0)).run()
    assert r_d.throughput() > 1.5 * r_t.throughput()
    assert r_d.utilization > r_t.utilization


def test_dstack_fairness_all_models_served():
    profiles = _profiles(rate=4000)
    res = Simulator(profiles, POLICIES["dstack"](profiles),
                    _gens(profiles, rate=4000),
                    SimConfig(duration=2.0)).run()
    for n, m in res.per_model.items():
        assert m.completed > 0, f"{n} starved under dstack"
        assert m.runtime > 0


def test_maxmin_favors_smallest_demand():
    """Paper Fig. 10b: max-min gives the low-demand model at least as much
    opportunity as D-STACK gives it."""
    p1 = _profiles(rate=6000)
    small = min(p1, key=lambda n: p1[n].knee_chips)
    r_mm = Simulator(p1, POLICIES["maxmin"](p1), _gens(p1, 6000),
                     SimConfig(duration=1.0)).run()
    assert r_mm.per_model[small].completed > 0


def test_drain_mode_completes_everything():
    profiles = _profiles()

    class Burst:
        def __init__(self, model, n, slo):
            self.reqs = [Request(0.0, i, model, slo) for i in range(n)]

        def until(self, t):
            r, self.reqs = self.reqs, []
            return r

    gens = [Burst(n, 100, profiles[n].slo) for n in profiles]
    res = Simulator(profiles, POLICIES["dstack"](profiles), gens,
                    SimConfig(drain=True, drop_expired=False,
                              duration=0)).run()
    assert res.total_completed == 400
    assert res.makespan > 0


def test_ideal_utilization_high_and_bounded():
    profiles = _profiles(rate=2000)
    res = IdealSimulator(profiles, _gens(profiles), duration=1.0).run()
    assert 0.0 < res.utilization <= 1.0 + 1e-9
    assert res.total_completed > 0


def test_dstack_within_ideal_envelope():
    """Paper Fig. 9d: D-STACK >= 90% of the ideal scheduler's throughput
    (at the shared knee/batch operating point, near-capacity load)."""
    import dataclasses
    rate = 1000

    def mk():
        out = {}
        for n in NAMES:
            p = build_profile(n, request_rate=rate)
            out[n] = dataclasses.replace(p, opt_chips=p.knee_chips,
                                         opt_batch=16)
        return out

    p1 = mk()
    ideal = IdealSimulator(p1, _gens(p1, rate), duration=1.5).run()
    p2 = mk()
    ds = Simulator(p2, POLICIES["dstack"](p2), _gens(p2, rate),
                   SimConfig(duration=1.5)).run()
    assert ds.throughput() >= 0.9 * ideal.throughput()
    assert ds.utilization >= 0.85 * ideal.utilization


# ------------------------------------------------------------ hypothesis
@settings(max_examples=15, deadline=None)
@given(
    rates=st.lists(st.integers(min_value=50, max_value=5000),
                   min_size=2, max_size=4),
    duration=st.floats(min_value=0.2, max_value=1.0),
    policy=st.sampled_from(["dstack", "maxmin", "gslice", "temporal"]),
)
def test_property_invariants_random_workloads(rates, duration, policy):
    names = NAMES[: len(rates)]
    profiles = {n: build_profile(n, request_rate=r)
                for n, r in zip(names, rates)}
    gens = [RequestGenerator(n, r, profiles[n].slo, seed=i)
            for i, (n, r) in enumerate(zip(names, rates))]
    sim = _InvariantSim(profiles, POLICIES[policy](profiles), gens,
                        SimConfig(duration=duration))
    res = sim.run()
    # invariants: no oversubscription; completed+violated sane; util in [0,1]
    assert not sim.oversubscribed
    assert 0.0 <= res.utilization <= 1.0 + 1e-9
    for n, m in res.per_model.items():
        assert m.completed >= 0
        assert m.runtime <= duration * 1.5 + 1.0
