"""Collective-bytes HLO parser — synthetic lines + a real lowered module."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.hlo_analysis import (collective_stats, cost_summary,
                                       memory_summary)

SYNTHETIC = """
  %ar = bf16[8,2048]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = f32[16,512]{1,0} all-gather(%y), dimensions={0}
  %rs = f32[4,128]{1,0} reduce-scatter(%z), dimensions={0}
  %a2a = (bf16[2,64]{1,0}, bf16[2,64]{1,0}) all-to-all(%p, %q)
  %cp = u32[32]{0} collective-permute(%w)
  %ard = bf16[8,2048]{1,0} all-reduce-done(%h)
"""


def test_synthetic_parse():
    st = collective_stats(SYNTHETIC)
    assert st.bytes_by_kind["all-reduce"] == 8 * 2048 * 2
    assert st.bytes_by_kind["all-gather"] == 16 * 512 * 4
    assert st.bytes_by_kind["reduce-scatter"] == 4 * 128 * 4
    assert st.bytes_by_kind["all-to-all"] == 2 * (2 * 64 * 2)
    assert st.bytes_by_kind["collective-permute"] == 32 * 4
    assert st.count_by_kind["all-reduce"] == 1   # -done not double counted


def test_compiled_hlo_format_variants():
    """Formats that appear in real compiled.as_text() output (post-SPMD):
    ROOT prefix, typed operands, channel ids, async -start/-done pairs."""
    real = """
  ROOT %all-reduce.77 = bf16[16,896]{1,0} all-reduce(bf16[16,896]{1,0} %add.3), channel_id=5, replica_groups=[16,16]<=[256], use_global_device_ids=true, to_apply=%region_1.2
  %all-gather-start.2 = f32[304,896]{1,0} all-gather-start(f32[19,896]{1,0} %p), channel_id=7, dimensions={0}
  %all-gather-done.2 = f32[304,896]{1,0} all-gather-done(f32[304,896]{1,0} %all-gather-start.2)
  %all-to-all.9 = (bf16[8,64]{1,0}, bf16[8,64]{1,0}) all-to-all(bf16[8,64]{1,0} %a, bf16[8,64]{1,0} %b), replica_groups={}
"""
    st = collective_stats(real)
    assert st.count_by_kind["all-reduce"] == 1
    assert st.bytes_by_kind["all-reduce"] == 16 * 896 * 2
    assert st.count_by_kind["all-gather"] == 1          # -done skipped
    assert st.bytes_by_kind["all-gather"] == 304 * 896 * 4
    assert st.bytes_by_kind["all-to-all"] == 2 * 8 * 64 * 2


def test_cost_and_memory_summaries():
    f = jax.jit(lambda a, b: a @ b)
    compiled = f.lower(jnp.ones((64, 64)), jnp.ones((64, 64))).compile()
    cost = cost_summary(compiled)
    assert cost["flops"] >= 2 * 64 ** 3 * 0.9
    mem = memory_summary(compiled)
    assert mem["argument_size_in_bytes"] >= 2 * 64 * 64 * 4
    assert mem["output_size_in_bytes"] >= 64 * 64 * 4
