"""Train a reduced model a few hundred steps on CPU and watch the loss drop.

    PYTHONPATH=src python examples/train_smoke.py --arch mamba2-1.3b --steps 200
"""
import argparse
import time

import jax

from repro.configs import get_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.registry import build_model
from repro.training.optimizer import AdamW
from repro.training.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    print(f"training reduced {cfg.name}: "
          f"{sum(x.size for x in jax.tree.leaves(params))/1e6:.1f}M params")
    opt = AdamW(lr=2e-3, warmup_steps=20, total_steps=args.steps)
    step = jax.jit(make_train_step(api, opt))
    state = opt.init(params)
    pipe = iter(TokenPipeline(cfg, DataConfig(batch_size=8, seq_len=128)))
    t0 = time.time()
    first = None
    for i in range(args.steps):
        params, state, m = step(params, state, next(pipe))
        loss = float(m["loss"])
        first = first if first is not None else loss
        if i % 20 == 0 or i == args.steps - 1:
            print(f"  step {i:4d}  loss {loss:.4f}  "
                  f"({(time.time()-t0)/(i+1)*1e3:.0f} ms/step)")
    print(f"loss: {first:.3f} -> {loss:.3f} "
          f"({'OK: decreased' if loss < first else 'WARN: did not decrease'})")


if __name__ == "__main__":
    main()
