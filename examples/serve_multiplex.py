"""END-TO-END DRIVER: D-STACK multiplexing real models with batched requests.

Thin wrapper over the serving control plane (``repro.serving.pool`` +
``repro.serving.controller``): the SAME faithful policy objects that drive
the analytic simulator (``repro.core.scheduler``) here drive a pool of
real jitted slot engines — arriving requests are prefilled and inserted
into free KV-cache slots mid-stream, every engine step decodes one token
for all of that engine's active slots in a single dispatch, and a policy's
chip-fraction decision selects a standby engine compiled up front for that
allocation (no per-request recompilation).

Virtual time comes from the profile rooflines (so the spatial-packing
advantage D-STACK banks on is visible even though this host is one CPU
core — a purely temporal device); every decode step is still a real
dispatch, and the wall clock that took is printed alongside.

    PYTHONPATH=src python examples/serve_multiplex.py [--duration 0.05]
"""
import argparse

from repro.serving.controller import run_policy
from repro.serving.pool import build_pool

MODELS = ["qwen2-0.5b", "mamba2-1.3b", "olmo-1b", "whisper-small"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=0.05,
                    help="virtual seconds of offered load per policy")
    ap.add_argument("--rate", type=float, default=2000.0,
                    help="arrivals/s per model (virtual time)")
    ap.add_argument("--gen-len", type=int, default=4)
    ap.add_argument("--lazy-kv", action="store_true",
                    help="lazy page reservation: admission claims prompt-"
                         "only pages, decode grows them, and OutOfPages "
                         "preempts-and-requeues the newest resident "
                         "(preempt/requeue counters in the table)")
    args = ap.parse_args()

    print(f"building engine pool: {len(MODELS)} real reduced models, "
          "standby engines per allocation (compiled once, up front) ...")
    pool = build_pool(MODELS, request_rate=args.rate, base_slots=4,
                      cache_len=32, lazy_kv=args.lazy_kv)
    results = {}
    for pol in ("temporal", "dstack"):
        res = run_policy(pool, pol, rate=args.rate, duration=args.duration,
                         gen_len=args.gen_len)
        results[pol] = res
        for line in res.table_rows():
            print(line)
    ratio = results["dstack"].throughput() / max(
        results["temporal"].throughput(), 1e-9)
    print(f"  dstack/temporal virtual-throughput ratio: {ratio:.2f}x "
          f"(same engines, same arrivals; spatial packing is the paper's "
          f"§6 win)")


if __name__ == "__main__":
    main()
