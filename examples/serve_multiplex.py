"""END-TO-END DRIVER: D-STACK multiplexing real models with batched requests.

Four reduced-config models share one "pod" (this host). Requests arrive on
a Poisson-ish process; D-STACK decides, at every completion/arrival event,
which model runs next, with what batch and chip allocation — and the chosen
runs execute REAL jitted prefill+decode through the InferenceEngine. Wall
-clock latencies feed back into the scheduler's accounting.

    PYTHONPATH=src python examples/serve_multiplex.py [--duration 10]
"""
import argparse
import time

import jax.numpy as jnp

from repro.configs import get_config
from repro.core.profiles import build_profile
from repro.core.scheduler import DStackPolicy, TemporalPolicy
from repro.serving import frontend
from repro.serving.engine import make_engine
from repro.serving.request import RequestGenerator, RequestQueue

MODELS = ["qwen2-0.5b", "mamba2-1.3b", "olmo-1b", "whisper-small"]


def run(policy_name: str, duration: float, rate: float, gen_len: int = 4):
    engines, profiles, queues, gens = {}, {}, {}, []
    for i, name in enumerate(MODELS):
        cfg = get_config(name).reduced()
        engines[cfg.name] = make_engine(cfg, cache_len=32)
        prof = build_profile(name, request_rate=rate)
        profiles[prof.name] = prof
        queues[prof.name] = RequestQueue(prof.name, prof.slo)
        gens.append(RequestGenerator(prof.name, rate, slo=10.0, seed=i))

    # warm up the jit caches so the measured loop is execution only
    for name, eng in engines.items():
        batch = {"tokens": jnp.ones((4, 8), jnp.int32)}
        if eng.cfg.has_encoder:
            batch["enc_embeds"] = frontend.audio_frames(eng.cfg, 4)
        eng.generate(batch, gen_len)

    arrivals = []
    for g in gens:
        arrivals.extend(g.until(duration * 20))   # over-generate; clock gates
    arrivals.sort(key=lambda r: r.arrival)

    served = {n: 0 for n in engines}
    t0 = time.time()
    ai = 0
    order = sorted(engines)
    rr = 0
    while time.time() - t0 < duration:
        now = time.time() - t0
        while ai < len(arrivals) and arrivals[ai].arrival <= now:
            queues[arrivals[ai].model].push(arrivals[ai])
            ai += 1
        # pick next model: D-STACK = least-served fairness + queue pressure;
        # temporal = round robin
        if policy_name == "dstack":
            cands = [(served[n] * profiles[n].runtime(), n)
                     for n in order if len(queues[n]) > 0]
            if not cands:
                time.sleep(0.002)
                continue
            _, name = min(cands)
        else:
            nonempty = [n for n in order if len(queues[n]) > 0]
            if not nonempty:
                time.sleep(0.002)
                continue
            name = nonempty[rr % len(nonempty)]
            rr += 1
        batch_reqs = queues[name].pop_batch(4, now, drop_expired=False)
        eng = engines[name]
        b = len(batch_reqs)
        batch = {"tokens": jnp.ones((b, 8), jnp.int32)}
        if eng.cfg.has_encoder:
            batch["enc_embeds"] = frontend.audio_frames(eng.cfg, b)
        eng.generate(batch, gen_len)
        queues[name].complete(batch_reqs, time.time() - t0)
        served[name] += b

    total = sum(served.values())
    wall = time.time() - t0
    print(f"  policy={policy_name:8s} served={total:5d} "
          f"({total/wall:7.1f} req/s) per-model=" +
          " ".join(f"{n.split('-')[0]}:{c}" for n, c in served.items()))
    return total / wall


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=8.0)
    ap.add_argument("--rate", type=float, default=200.0)
    args = ap.parse_args()
    print(f"serving {len(MODELS)} real reduced models for "
          f"{args.duration:.0f}s each policy ...")
    print("NOTE: this host is ONE CPU core — a purely temporal device, so "
          "D-STACK's spatial-packing advantage cannot show in wall clock "
          "here; what this driver demonstrates is the real jitted data "
          "plane under scheduler control + fairness across models. The "
          "spatial win is quantified in the pod simulator "
          "(python -m repro.launch.serve --mode sim).")
    thr_t = run("temporal", args.duration, args.rate)
    thr_d = run("dstack", args.duration, args.rate)
    print(f"  dstack/temporal wall-clock ratio on 1 core: {thr_d/thr_t:.2f}x")


if __name__ == "__main__":
    main()
