"""END-TO-END DRIVER: D-STACK multiplexing real models with batched requests.

Four reduced-config models share one "pod" (this host). Requests arrive on
a Poisson-ish process; D-STACK decides, at every step, which model runs
next — and the chosen model executes a REAL jitted decode step through the
InferenceEngine's slot-based continuous batching: arriving requests are
prefilled and inserted into free KV-cache slots MID-STREAM (no repadding,
no recompiling, no disturbing in-flight sequences), every engine step
decodes one token for all of that model's active slots in a single
dispatch, and finished requests free their slot for the next arrival.

    PYTHONPATH=src python examples/serve_multiplex.py [--duration 10]
"""
import argparse
import time

import jax.numpy as jnp

from repro.configs import get_config
from repro.core.profiles import build_profile
from repro.serving import frontend
from repro.serving.engine import make_engine
from repro.serving.request import RequestGenerator, RequestQueue

MODELS = ["qwen2-0.5b", "mamba2-1.3b", "olmo-1b", "whisper-small"]
N_SLOTS = 4
PROMPT_LEN = 8


def _prompt_batch(cfg, b=1):
    batch = {"tokens": jnp.ones((b, PROMPT_LEN), jnp.int32)}
    if cfg.has_encoder:
        batch["enc_embeds"] = frontend.audio_frames(cfg, b)
    return batch


def run(policy_name: str, duration: float, rate: float, gen_len: int = 4):
    engines, profiles, queues, gens = {}, {}, {}, []
    for i, name in enumerate(MODELS):
        cfg = get_config(name).reduced()
        engines[cfg.name] = make_engine(cfg, cache_len=32).init_slots(N_SLOTS)
        prof = build_profile(name, request_rate=rate)
        profiles[prof.name] = prof
        queues[prof.name] = RequestQueue(prof.name, prof.slo)
        gens.append(RequestGenerator(prof.name, rate, slo=10.0, seed=i))

    # warm up the jit caches (insert prefill + slot decode) so the measured
    # loop is execution only
    for name, eng in engines.items():
        s = eng.insert(_prompt_batch(eng.cfg))
        eng.step()
        eng.free(s)

    arrivals = []
    for g in gens:
        arrivals.extend(g.until(duration * 20))   # over-generate; clock gates
    arrivals.sort(key=lambda r: r.arrival)

    served = {n: 0 for n in engines}
    # slot -> (request, tokens generated so far), per engine
    in_flight = {n: {} for n in engines}
    t0 = time.time()
    ai = 0
    order = sorted(engines)
    rr = 0
    while time.time() - t0 < duration:
        now = time.time() - t0
        while ai < len(arrivals) and arrivals[ai].arrival <= now:
            queues[arrivals[ai].model].push(arrivals[ai])
            ai += 1
        # admit queued requests into free slots mid-stream (continuous
        # batching: in-flight sequences in other slots are untouched)
        for n in order:
            eng = engines[n]
            while eng.free_slots and len(queues[n]) > 0:
                (req,) = queues[n].pop_batch(1, now, drop_expired=False)
                slot = eng.insert(_prompt_batch(eng.cfg))
                in_flight[n][slot] = (req, 0)
        # pick next model to step: D-STACK = least-served fairness + queue
        # pressure; temporal = round robin
        busy = [n for n in order if in_flight[n]]
        if not busy:
            time.sleep(0.002)
            continue
        if policy_name == "dstack":
            _, name = min((served[n] * profiles[n].runtime(), n) for n in busy)
        else:
            name = busy[rr % len(busy)]
            rr += 1
        eng = engines[name]
        eng.step()                                # ONE dispatch, all slots
        now = time.time() - t0
        for slot in list(in_flight[name]):
            req, done = in_flight[name][slot]
            done += 1
            if done >= gen_len:
                queues[name].complete([req], now)
                eng.free(slot)
                del in_flight[name][slot]
                served[name] += 1
            else:
                in_flight[name][slot] = (req, done)

    total = sum(served.values())
    wall = time.time() - t0
    print(f"  policy={policy_name:8s} served={total:5d} "
          f"({total/wall:7.1f} req/s) per-model=" +
          " ".join(f"{n.split('-')[0]}:{c}" for n, c in served.items()))
    return total / wall


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=8.0)
    ap.add_argument("--rate", type=float, default=200.0)
    args = ap.parse_args()
    print(f"serving {len(MODELS)} real reduced models for "
          f"{args.duration:.0f}s each policy "
          f"(slot-based continuous batching, {N_SLOTS} slots/model) ...")
    print("NOTE: this host is ONE CPU core — a purely temporal device, so "
          "D-STACK's spatial-packing advantage cannot show in wall clock "
          "here; what this driver demonstrates is the real jitted data "
          "plane (slot insert/free continuous batching, ragged decode) "
          "under scheduler control + fairness across models. The spatial "
          "win is quantified in the pod simulator "
          "(python -m repro.launch.serve --mode sim).")
    thr_t = run("temporal", args.duration, args.rate)
    thr_d = run("dstack", args.duration, args.rate)
    print(f"  dstack/temporal wall-clock ratio on 1 core: {thr_d/thr_t:.2f}x")


if __name__ == "__main__":
    main()
