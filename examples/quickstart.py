"""Quickstart: build a model, run prefill + decode, inspect its knee.

    PYTHONPATH=src python examples/quickstart.py [--arch qwen2-0.5b]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.efficacy import optimize
from repro.core.latency_model import CHIP_LEVELS, LatencyModel
from repro.serving.engine import make_engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    args = ap.parse_args()

    # ---- 1. data plane: reduced model, real prefill + greedy decode -----
    cfg = get_config(args.arch).reduced()
    print(f"[1] building reduced {cfg.name} "
          f"({cfg.param_count()/1e6:.1f}M params) ...")
    eng = make_engine(cfg, cache_len=64)
    prompt = jnp.array([[5, 17, 3, 99, 4, 21, 8, 2]], jnp.int32)
    batch = {"tokens": prompt}
    if cfg.has_encoder:
        from repro.serving import modality
        batch["enc_embeds"] = modality.audio_frames(cfg, 1)
    out = eng.generate(batch, max_new_tokens=12)
    print(f"    generated tokens: {out[0].tolist()}")

    # ---- 2. control plane: the paper's knee + efficacy analysis ---------
    full = get_config(args.arch)
    lm = LatencyModel(full, mode="prefill", seq=128)
    print(f"[2] {full.name} latency vs chips (batch=16, prefill-128):")
    for c in CHIP_LEVELS:
        lat = lm.latency(c, 16)
        bar = "#" * int(min(lat * 2e3, 60))
        print(f"    {c:4d} chips: {lat*1e3:8.2f} ms {bar}")
    knee = lm.knee_chips(16)
    print(f"    knee = {knee} chips ({knee/256:.1%} of the pod)")

    pt = optimize(lm, slo=0.05, request_rate=1000)
    print(f"[3] efficacy-optimal operating point @SLO=50ms, 1000 req/s: "
          f"batch={pt.batch}, chips={pt.chips}, "
          f"latency={pt.latency*1e3:.2f} ms, feasible={pt.feasible}")


if __name__ == "__main__":
    main()
