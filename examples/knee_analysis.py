"""Knee + efficacy analysis across the full 10-arch zoo (paper §3-§5).

Prints the Table-6 analogue: per-model knee fraction, SLO, efficacy-optimal
(batch, chips), runtime at the operating point — plus the analytic-model
curves from §4.

    PYTHONPATH=src python examples/knee_analysis.py
"""
import numpy as np

from repro.configs import ARCHS
from repro.core.knee import AnalyticalDNN
from repro.core.profiles import build_profile


def main():
    print("== paper §4: analytic DNN model — knee vs inherent parallelism ==")
    s = np.arange(1, 81)
    for n1 in (20, 40, 60):
        m = AnalyticalDNN(p=n1, mem_bw_per_unit=50.0, data_per_kernel=100.0)
        d = m.derivative_curve(s)
        knee = int(s[np.argmax(d)])
        ratio = float(np.asarray(m.execution_time(np.array([1]))
                                 / m.execution_time(np.array([knee])))[0])
        print(f"  N1={n1:3d}: derivative max at S={knee} "
              f"(latency 1 unit vs knee: {ratio:.1f}x)")

    print("\n== Table 6 analogue: the 10-arch zoo on a v5e-256 pod ==")
    print(f"{'model':26s} {'knee':>6s} {'SLO':>6s} {'opt batch':>9s} "
          f"{'opt chips':>9s} {'runtime':>9s}")
    for name in ARCHS:
        p = build_profile(name, request_rate=2000)
        print(f"{p.name:26s} {p.knee_frac:5.1%} {p.slo*1e3:5.0f}ms "
              f"{p.opt_batch:9d} {p.opt_chips:9d} {p.runtime()*1e3:7.2f}ms")

    total = sum(build_profile(n, request_rate=2000).knee_frac for n in ARCHS)
    print(f"\naggregate knee demand: {total:.2f} pods -> spatial multiplexing"
          f" pressure exists (the D-STACK scenario)")


if __name__ == "__main__":
    main()
