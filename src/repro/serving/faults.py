"""Deterministic fault injection for the serving plane.

At millions-of-users scale the dominant serving events are not the happy
path: clients disconnect, deadlines blow, pools overload, and the runtime
throws transient dispatch/allocation errors. The failure half of the
serving plane (``repro.serving.plan`` / ``repro.serving.pool``) exists to
absorb those events without leaking KV pages or stalling the tick loop —
and the only way to trust that is to inject the events on a seeded,
reproducible schedule and assert the invariants afterwards (the chaos
suite, ``tests/test_chaos.py``, and ``bench_pool --faults``).

``FaultInjector`` is that schedule. It is attached at three sites:

* **dispatch** (``InferenceEngine.execute``): raises ``TransientFault``
  before the plan mutates anything, modeling a transient runtime error a
  retry can absorb. The engine retries up to ``retry_limit`` times with
  exponential backoff (``EngineStats.engine_retries``); exhausted retries
  raise ``EngineFault`` — the control planes' engine-reset signal.
* **alloc** (``PageAllocator.alloc``): raises ``OutOfPages`` spuriously,
  modeling transient allocator failure. Every caller already treats
  ``OutOfPages`` as an all-or-nothing admission/growth signal, so an
  injected one degrades to a deferred admission or a preemption — never
  a partial allocation.
* **stuck** (``TickServer.fire``): the tick's dispatch "hangs" and the
  watchdog kills it — engine slot state must be treated as lost. The
  server runs the engine-reset path: every resident recompute-requeues
  (riding the PR 5 preemption machinery, so surviving greedy streams are
  unchanged) and the page-pool conservation audit runs before serving
  resumes.

The rng is consumed once per armed site per roll, so a fixed seed plus a
fixed workload reproduces the exact fault schedule; ``max_faults`` bounds
the total so chaos runs provably drain.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.serving.kv_cache import OutOfPages


class TransientFault(RuntimeError):
    """An injected fault the dispatch site is expected to retry."""


class EngineFault(RuntimeError):
    """Retries exhausted (or the dispatch was killed mid-flight): engine
    slot state must be considered lost. Control planes recover by engine
    reset — free every slot, audit page conservation, and recompute-
    requeue the residents."""


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Seeded fault schedule. Rates are per-roll probabilities at each
    site; ``max_faults`` caps the total injected across all sites so a
    chaos run is guaranteed to drain once the schedule is spent."""
    seed: int = 0
    dispatch_rate: float = 0.0     # P(TransientFault) per execute attempt
    alloc_rate: float = 0.0        # P(spurious OutOfPages) per page alloc
    stuck_rate: float = 0.0        # P(watchdog-killed tick) per tick
    max_faults: Optional[int] = None


class FaultInjector:
    """One seeded rng driving every armed site. Sites with a zero rate
    never consume the rng, so enabling one fault class does not perturb
    another's schedule for the same seed."""

    def __init__(self, cfg: Optional[FaultConfig] = None, **kw):
        self.cfg = cfg or FaultConfig(**kw)
        self._rng = np.random.default_rng(self.cfg.seed)
        self.injected: Dict[str, int] = {"dispatch": 0, "alloc": 0,
                                         "stuck": 0}

    @property
    def total(self) -> int:
        return sum(self.injected.values())

    def _roll(self, rate: float, site: str) -> bool:
        if rate <= 0.0:
            return False
        if (self.cfg.max_faults is not None
                and self.total >= self.cfg.max_faults):
            return False
        if float(self._rng.random()) >= rate:
            return False
        self.injected[site] += 1
        return True

    def maybe_fault(self, site: str) -> None:
        """Raise the site's fault type if the schedule says so.
        ``dispatch`` raises ``TransientFault`` (retryable); ``alloc``
        raises ``OutOfPages`` (the signal every allocation path already
        handles all-or-nothing)."""
        if site == "dispatch" and self._roll(self.cfg.dispatch_rate,
                                             "dispatch"):
            raise TransientFault(
                f"injected dispatch fault #{self.injected['dispatch']}")
        if site == "alloc" and self._roll(self.cfg.alloc_rate, "alloc"):
            raise OutOfPages(
                f"injected allocator fault #{self.injected['alloc']}")

    def stuck(self) -> bool:
        """True when this tick's dispatch should be treated as hung
        (killed by the watchdog — the caller runs the reset path)."""
        return self._roll(self.cfg.stuck_rate, "stuck")
