"""Radix prompt cache: prefix sharing over paged KV with copy-on-write.

At serving scale most prompts share a system prefix and chat turns share
conversation history, yet a plain admission re-prefills every token — the
largest avoidable FLOP cost in the plane. The paged layout (PR 3) makes
sharing a *table-aliasing* exercise: K/V for a token prefix lives in whole
pages, so a new request whose prompt starts with an already-resident
prefix can point its leading block-table entries at those pages and skip
the covered tokens' prefill entirely.

This module is the host-side index that makes any admission able to hit
any cached prefix (the SGLang RadixAttention idea): a radix tree over
token sequences, keyed at **page granularity**.

* Node keys are token runs whose length is a multiple of ``page_size``;
  each node carries the physical page per key page. An edge is indexed by
  its first page of tokens, so lookup walks whole pages.
* ``match`` returns the longest cached prefix of a prompt: fully matched
  pages are aliased read-only into the new row (refcount++ per holder),
  and a *partially* matched page becomes a copy-on-write source — the
  engine copies it into a fresh page with one static-shape dispatch and
  the row diverges there.
* ``insert`` registers a finished prefill's full prompt pages, splitting
  nodes at page boundaries where prompts diverge. The cache holds ONE
  reference per held page (``PageAllocator.share``), so registered pages
  survive the registering row's free — that persistence is the cache.
* ``evict`` releases cold leaves (LRU by a deterministic logical clock)
  until enough pages actually return to the pool; the planner calls it
  before preempting live residents, which is how cold cache competes
  with running work for the page budget.

Everything here is host-side Python over ``PageAllocator`` refcounts —
no device state. Determinism: the logical clock ticks once per cache
operation, dict iteration is insertion-ordered, and ties break on node
creation order, so a seeded replay (a pool reset — engine
``release_all_slots`` — flushes the cache and re-sorts the free list)
reproduces identical page placement. Engine ``recover`` is gentler: it
keeps the HOT subtree (``retain_recent``) so a mid-run fault does not
forfeit the warmed working set, and the recovery audit accounts the
survivors (free + held == total).

Safety argument for read-only aliasing: a hit row starts at
``pos = covered``, so every subsequent write — decode, teacher-forced
catch-up, or a masked-off row's dead write — lands at positions
``>= covered``, i.e. in the row's own COW/fresh pages, never in an
aliased page. Stale K/V beyond ``covered`` inside a COW'd page is never
read (attention masks by ``pos``) and is overwritten in order by the
forced catch-up steps.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.kv_cache import PageAllocator


@dataclasses.dataclass(frozen=True)
class PrefixHit:
    """One match result. The caller owns one PINNED reference per page in
    ``pages`` and (when set) on ``cow_src`` — either consume them by
    adopting the pages into a row (``PagedKVCache.alloc_alias`` plus the
    engine's page copy) or return them via ``release_hit``."""
    covered: int                    # prompt tokens covered (full + partial)
    pages: Tuple[int, ...]          # fully matched pages, aliased read-only
    cow_src: Optional[int] = None   # partially matched page to copy, if any


@dataclasses.dataclass
class PrefixCacheStats:
    hits: int = 0
    misses: int = 0
    hit_tokens: int = 0             # prompt tokens covered by hits
    cow_hits: int = 0               # hits that ended on a partial page
    inserts: int = 0
    inserted_pages: int = 0         # new pages retained by the tree
    evictions: int = 0              # nodes evicted
    evicted_pages: int = 0          # pages that actually returned to pool


class _Node:
    """One radix edge: a token run (multiple of page_size) + its pages."""
    __slots__ = ("tokens", "pages", "children", "last_used", "order")

    def __init__(self, tokens: Tuple[int, ...], pages: List[int],
                 clock: int, order: int):
        self.tokens = tokens
        self.pages = pages
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.last_used = clock
        self.order = order          # creation order: deterministic LRU ties

    @property
    def n_pages(self) -> int:
        return len(self.pages)


def _lcp(a: Sequence[int], b: Sequence[int]) -> int:
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


class PrefixCache:
    """Host-side radix tree over token prefixes at page granularity."""

    def __init__(self, allocator: PageAllocator, page_size: int):
        self.allocator = allocator
        self.page_size = int(page_size)
        self.stats = PrefixCacheStats()
        self._root = _Node((), [], clock=0, order=0)
        self._clock = 0
        self._order = 0
        self.held_pages = 0         # pages the tree holds one reference on

    # -------------------------------------------------------------- lookup
    def match(self, tokens: Sequence[int],
              max_covered: Optional[int] = None,
              min_covered: int = 1) -> Optional[PrefixHit]:
        """Longest cached prefix of ``tokens``, capped at ``max_covered``
        (admissions cap at prompt_len - 1 so at least one real token is
        left to re-derive the first sampled token). A match shorter than
        ``min_covered`` counts as a miss and pins nothing — the planner's
        hit-quality floor (a short alias saves little prefill but still
        serializes its tail through teacher-forced catch-up). Pins every
        returned page — see ``PrefixHit``. Returns None on a miss."""
        toks = [int(t) for t in tokens]
        limit = len(toks) if max_covered is None else min(len(toks),
                                                          int(max_covered))
        ps = self.page_size
        self._clock += 1
        node = self._root
        shared: List[int] = []
        covered = 0
        cow: Optional[int] = None
        while cow is None:
            rem = limit - covered
            if rem < 1:
                break
            first = tuple(toks[covered:covered + ps]) if rem >= ps else None
            child = node.children.get(first) if first is not None else None
            if child is None:
                # no whole-page edge: the best we can do is a partial match
                # against some child's first page — the COW candidate
                best_len, best_child = 0, None
                for key, cand in node.children.items():
                    j = _lcp(toks[covered:covered + min(rem, ps)], key)
                    if j > best_len:
                        best_len, best_child = j, cand
                if best_child is not None:
                    cow = best_child.pages[0]
                    covered += best_len
                    best_child.last_used = self._clock
                break
            child.last_used = self._clock
            descended = True
            for i in range(child.n_pages):
                rem = limit - covered
                page_toks = child.tokens[i * ps:(i + 1) * ps]
                if rem >= ps and tuple(toks[covered:covered + ps]) == \
                        page_toks:
                    shared.append(child.pages[i])
                    covered += ps
                    continue
                j = _lcp(toks[covered:covered + min(rem, ps)], page_toks)
                if j > 0:
                    cow = child.pages[i]
                    covered += j
                descended = False
                break
            if not descended:
                break
            node = child
        if covered < max(1, int(min_covered)):
            self.stats.misses += 1
            return None
        self.allocator.share(shared)
        if cow is not None:
            self.allocator.share([cow])
            self.stats.cow_hits += 1
        self.stats.hits += 1
        self.stats.hit_tokens += covered
        return PrefixHit(covered=covered, pages=tuple(shared), cow_src=cow)

    def peek(self, tokens: Sequence[int],
             max_covered: Optional[int] = None) -> int:
        """How many leading tokens of ``tokens`` the cache could cover,
        WITHOUT acting on it: no clock tick, no LRU touch, no stats, no
        pins. The planner's hit-aware admission ordering probes every
        queued candidate with this — a probe that mutated recency would
        let the act of *considering* a request keep its prefix warm, and
        a probe that pinned would leak references for requests that are
        then not admitted. Whole-page walk only (partial COW pages count
        toward ``match`` coverage but not here): the ordering heuristic
        cares about pages it can alias for free."""
        toks = [int(t) for t in tokens]
        limit = len(toks) if max_covered is None else min(len(toks),
                                                          int(max_covered))
        ps = self.page_size
        node = self._root
        covered = 0
        while limit - covered >= ps:
            child = node.children.get(tuple(toks[covered:covered + ps]))
            if child is None:
                break
            matched = 0
            for i in range(child.n_pages):
                if (limit - covered >= ps
                        and tuple(toks[covered:covered + ps])
                        == child.tokens[i * ps:(i + 1) * ps]):
                    covered += ps
                    matched += 1
                else:
                    break
            if matched < child.n_pages:
                break
            node = child
        return covered

    def canonical_pages(self, tokens: Sequence[int]) -> List[int]:
        """Physical pages the tree holds for the whole-page prefix of
        ``tokens`` — strictly read-only, like ``peek`` (no clock tick,
        no LRU touch, no stats, no pins). Right after an ``insert``
        these are the CANONICAL pages for that prefix: existing nodes
        keep their original pages on duplicate inserts, so a row that
        just registered can compare its own pages against this walk and
        repoint at the originals (cross-request dedup — see
        ``InferenceEngine.dedup_slot_prefix``)."""
        toks = [int(t) for t in tokens]
        ps = self.page_size
        node = self._root
        out: List[int] = []
        covered = 0
        while len(toks) - covered >= ps:
            child = node.children.get(tuple(toks[covered:covered + ps]))
            if child is None:
                break
            matched = 0
            for i in range(child.n_pages):
                if (len(toks) - covered >= ps
                        and tuple(toks[covered:covered + ps])
                        == child.tokens[i * ps:(i + 1) * ps]):
                    out.append(child.pages[i])
                    covered += ps
                    matched += 1
                else:
                    break
            if matched < child.n_pages:
                break
            node = child
        return out

    def release_hit(self, hit: PrefixHit) -> None:
        """Return an unconsumed hit's pins (admission failed or was
        abandoned before the alias landed)."""
        self.allocator.release(list(hit.pages))
        if hit.cow_src is not None:
            self.allocator.release([hit.cow_src])

    # ---------------------------------------------------------- registration
    def insert(self, tokens: Sequence[int], pages: Sequence[int]) -> int:
        """Register a finished prefill: ``tokens`` must be a whole number
        of pages and ``pages`` their physical locations (the registering
        row keeps its own references; the tree takes one more per page it
        retains). Existing matching nodes keep their pages — duplicate
        prefixes cost nothing. Returns how many new pages the tree
        retained."""
        toks = tuple(int(t) for t in tokens)
        ps = self.page_size
        if len(toks) % ps != 0 or len(toks) // ps != len(pages):
            raise ValueError(
                f"insert needs whole pages: {len(toks)} tokens, "
                f"{len(pages)} pages at page_size {ps}")
        if not pages:
            return 0
        self._clock += 1
        self.stats.inserts += 1
        node = self._root
        i = 0                        # page index into toks/pages
        n = len(pages)
        retained = 0
        while i < n:
            first = tuple(toks[i * ps:(i + 1) * ps])
            child = node.children.get(first)
            if child is None:
                tail_toks = toks[i * ps:n * ps]
                tail_pages = list(pages[i:])
                self.allocator.share(tail_pages)
                self._order += 1
                node.children[first] = _Node(tail_toks, tail_pages,
                                             self._clock, self._order)
                self.held_pages += len(tail_pages)
                retained += len(tail_pages)
                break
            child.last_used = self._clock
            k = 0
            while (k < child.n_pages and i < n
                   and tuple(toks[i * ps:(i + 1) * ps])
                   == child.tokens[k * ps:(k + 1) * ps]):
                k += 1
                i += 1
            if k == child.n_pages:
                node = child         # fully traversed: descend
                continue
            if i == n:
                break                # child extends past the new prompt
            # divergence inside the edge: split at the page boundary k
            node.children[first] = self._split(child, k)
            node = node.children[first]
        self.stats.inserted_pages += retained
        return retained

    def _split(self, child: _Node, k: int) -> _Node:
        """Split an edge after its k-th page: prefix node keeps pages[:k],
        the suffix node inherits the rest plus the children. Reference
        counts are untouched — the same pages, new bookkeeping."""
        ps = self.page_size
        assert 0 < k < child.n_pages
        self._order += 1
        prefix = _Node(child.tokens[:k * ps], child.pages[:k],
                       self._clock, self._order)
        suffix_first = tuple(child.tokens[k * ps:(k + 1) * ps])
        child.tokens = child.tokens[k * ps:]
        child.pages = child.pages[k:]
        prefix.children[suffix_first] = child
        prefix.last_used = max(prefix.last_used, child.last_used)
        return prefix

    # -------------------------------------------------------------- eviction
    def evict(self, need_pages: int) -> int:
        """Release cold leaves (LRU, ties by creation order) until at
        least ``need_pages`` pages have actually returned to the pool or
        nothing evictable remains. Leaves whose pages are ALL still
        row-shared are never victims: releasing them would free nothing
        (the rows hold their own references) yet forfeit every future
        hit on that prefix — those pages rejoin the evictable set when
        their rows free. Returns pages actually freed."""
        freed = 0
        while freed < need_pages:
            victim = self._coldest_leaf()
            if victim is None:
                break
            parent, key, node = victim
            freed += self.allocator.release(node.pages)
            self.held_pages -= len(node.pages)
            self.stats.evictions += 1
            del parent.children[key]
        self.stats.evicted_pages += freed
        return freed

    def _coldest_leaf(self):
        coldest = None
        stack = [self._root]
        while stack:
            node = stack.pop()
            for key, child in node.children.items():
                if child.children:
                    stack.append(child)
                    continue
                # skip leaves that would free nothing: every page is
                # still referenced by a live row or a pinned hit
                if all(self.allocator.refcount(p) > 1
                       for p in child.pages):
                    continue
                if (coldest is None
                        or (child.last_used, child.order)
                        < (coldest[2].last_used, coldest[2].order)):
                    coldest = (node, key, child)
        return coldest

    def retain_recent(self, window: int) -> int:
        """Prune every node colder than ``window`` cache operations
        (``last_used < clock - window``), bottom-up: a node survives if
        it is recent OR any descendant is — an ancestor's pages back its
        descendants' prefixes, so keeping a child keeps its spine. The
        engine's ``recover`` path calls this INSTEAD of ``flush``: a
        mid-run fault drops slot state (recompute-requeue) but not the
        warmed radix working set, so post-recovery admissions keep
        hitting. Returns pages whose references were released (counted
        as evictions)."""
        cutoff = self._clock - max(0, int(window))
        released = 0

        def _prune(node: _Node) -> bool:
            nonlocal released
            keep = node.last_used >= cutoff
            for key in list(node.children):
                child = node.children[key]
                if _prune(child):
                    keep = True
                else:
                    # child and (already-pruned) descendants are cold
                    released += self.allocator.release(child.pages)
                    self.held_pages -= len(child.pages)
                    self.stats.evictions += 1
                    del node.children[key]
            return keep

        _prune(self._root)
        self.stats.evicted_pages += released
        return released

    def flush(self) -> int:
        """Drop every node and release every held reference (pool reset
        between policy runs: replayed seeded runs start from a cold
        cache). Returns pages actually freed."""
        freed = 0
        stack = list(self._root.children.values())
        while stack:
            node = stack.pop()
            freed += self.allocator.release(node.pages)
            stack.extend(node.children.values())
        self._root = _Node((), [], clock=self._clock, order=0)
        self.held_pages = 0
        return freed

    # --------------------------------------------------------------- queries
    def page_refs(self) -> Dict[int, int]:
        """page -> number of references the tree holds (always 1 per node
        page) — the ``extra_refs`` argument for
        ``PagedKVCache.check_invariants``."""
        refs: Dict[int, int] = {}
        stack = list(self._root.children.values())
        while stack:
            node = stack.pop()
            for p in node.pages:
                refs[p] = refs.get(p, 0) + 1
            stack.extend(node.children.values())
        return refs

    def evictable_pages(self) -> int:
        """Pages that would actually free if the whole tree were evicted
        right now (held pages nobody else references)."""
        return sum(1 for p, _ in self.page_refs().items()
                   if self.allocator.refcount(p) == 1)

    def check_invariants(self) -> bool:
        """Tree-side audit: held-page accounting matches the tree, every
        held page is allocated with refcount covering the tree's hold,
        node keys are whole pages and children are keyed consistently."""
        refs = self.page_refs()
        assert sum(refs.values()) == self.held_pages, (
            f"held_pages {self.held_pages} != tree pages "
            f"{sum(refs.values())}")
        for p, n in refs.items():
            assert self.allocator.refcount(p) >= n, (
                f"page {p}: tree holds {n} refs, allocator has "
                f"{self.allocator.refcount(p)}")
        stack = [self._root]
        ps = self.page_size
        while stack:
            node = stack.pop()
            assert len(node.tokens) == len(node.pages) * ps, (
                "node key is not a whole number of pages")
            for key, child in node.children.items():
                assert key == tuple(child.tokens[:ps]), \
                    "child keyed by a token run it does not start with"
                stack.append(child)
        return True
