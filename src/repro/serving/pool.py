"""Policy-driven engine pool: D-STACK's control plane over real engines.

This module is the serving control plane the paper builds in §6, realized
over the real jitted data plane of ``repro.serving.engine`` instead of the
analytic simulator. Component → paper map:

* **StandbyAllocation / ModelHost** — §3.2 + §6.1.2. On GPUs, one model at
  one GPU% is a CUDA-MPS process with a fixed thread percentage; here it is
  one ``InferenceEngine`` whose executables are compiled for one sub-mesh
  chip count. A host keeps one *standby* engine per candidate allocation
  (all sharing one set of weights), compiled once up front — so a policy's
  chip-fraction decision *selects a pre-built executable*; re-allocation is
  an engine switch, never a recompile (the paper's fast re-allocation
  story, and this repo's acceptance bar of zero per-request compilation).

* **EnginePool (a SchedView)** — the policy↔data-plane adapter. The same
  ``plan(now, view)`` that drives ``repro.core.simulator.Simulator`` drives
  this pool: it exposes ``profiles`` / ``queues`` / ``running`` /
  ``free_frac`` / ``sim.total_chips``, and enforces the §6 invariant that
  aggregate allocated chip fraction never exceeds 1.0 (except for policies
  that explicitly model uncontrolled sharing, e.g. Fixed-Batch MPS).

* **Admission (``admit``)** — §6.1 + Eq. 11/12. The policy sizes each run's
  batch with ``ModelProfile.feasible_batch_for`` (largest batch whose
  assembly + inference fits the SLO budget); admission additionally caps it
  to the chosen engine's free KV-cache slots, prefills each request into a
  slot mid-stream (continuous batching), and charges the model's runtime
  scoreboard — the quantity D-STACK's fair opportunistic pass (§6.1.1)
  equalizes.

* **PoolMetrics** (``repro.serving.metrics``) — §7/Fig. 10 reporting:
  per-model throughput, completion-latency p50/p99, SLO violations
  (dropped *and* late-but-served), runtime shares and their Jain fairness
  index, and allocation occupancy.

Time is virtual (discrete-event, from the profile's roofline latency at
the *granted* allocation) while every decode step is a real jitted
dispatch — so policy comparisons are deterministic and SLO-meaningful on a
one-core host, yet exercise the true engine hot path end to end. The
driver loop lives in ``repro.serving.controller``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.profiles import ModelProfile, build_profile
from repro.core.simulator import RunRequest
from repro.serving.engine import InferenceEngine
from repro.serving.faults import EngineFault
from repro.serving.kv_cache import OutOfPages
from repro.serving.metrics import ModelPoolMetrics, PoolResult
from repro.serving.plan import (PlannerConfig, StepPlanner, preemption_key)
from repro.serving.request import Request, RequestQueue


@dataclasses.dataclass(frozen=True)
class PoolCaps:
    """Capacity config — the ``view.sim`` leg of the SchedView protocol."""
    total_chips: int
    dispatch_gap: float = 100e-6


@dataclasses.dataclass
class StandbyAllocation:
    """One pre-built (sub-mesh, executable) pair for a hosted model."""
    chips: int
    n_slots: int
    engine: InferenceEngine


class ModelHost:
    """One hosted model: shared weights + standby engines keyed by chips."""

    def __init__(self, cfg, api, params, profile: ModelProfile,
                 allocations: Dict[int, StandbyAllocation],
                 prompt_len: int = 8):
        self.cfg = cfg
        self.api = api
        self.params = params
        self.profile = profile
        self.allocations = allocations
        self.prompt_len = prompt_len
        self._prompt = None

    def prompt_batch(self) -> Dict[str, jax.Array]:
        """Deterministic single-request prompt (fixed shape: one traced
        prefill signature per engine for the whole workload)."""
        if self._prompt is None:
            b = {"tokens": jnp.ones((1, self.prompt_len), jnp.int32)}
            if self.cfg.has_encoder:
                from repro.serving import modality
                b["enc_embeds"] = modality.audio_frames(self.cfg, 1)
            self._prompt = b
        return self._prompt

    def engines(self) -> List[InferenceEngine]:
        return [a.engine for a in self.allocations.values()]


@dataclasses.dataclass
class PoolRun:
    """One in-flight (model, allocation, batch) run — the pool analogue of
    ``simulator.Run``; policies see ``.model`` and ``.frac``."""
    seq: int
    model: str
    req_chips: int             # what the policy asked for
    chips: int                 # granted (largest standby allocation <= ask)
    frac: float
    batch: int
    engine: InferenceEngine
    slots: Dict[int, Request]
    remaining: Dict[int, int]  # decode tokens left per slot (ragged budgets)
    latency: float             # modeled total run latency at granted chips
    step_cost: float           # latency / max budget — virtual cost per step
    start: float
    next_time: float
    # a slot finished before the run did (ragged per-request n_tokens) —
    # the gate for mid-run re-admission (``topup``): uniform-budget runs
    # never trip it, so they behave exactly as before paging
    freed_early: bool = False


class EnginePool:
    """A pool of slot engines that any ``Policy`` can drive (SchedView)."""

    def __init__(self, hosts: Dict[str, ModelHost],
                 caps: Optional[PoolCaps] = None, lazy_kv: bool = False,
                 planner_config: Optional[PlannerConfig] = None,
                 prefix_cache: bool = False):
        self.hosts = hosts
        self.profiles: Dict[str, ModelProfile] = {
            n: h.profile for n, h in hosts.items()}
        total = max(p.hw.chips_per_pod for p in self.profiles.values())
        self.sim = caps or PoolCaps(total_chips=total)
        # lazy KV reservation: admission claims pages for the prompt only
        # (not the whole prompt+budget horizon) and decode grows
        # page-by-page; when the pool runs dry mid-run a resident chosen
        # by the slack-aware victim rule is preempted and requeued
        # (counters in ModelPoolMetrics). The default keeps the
        # deadlock-free up-front reservation.
        self.lazy_kv = lazy_kv
        # base PlannerConfig for every per-model planner (load-shed
        # watermarks, victim rule, ...); `lazy` is overridden by lazy_kv
        # and `prefix_cache` by the pool-level knob below
        self._planner_config = planner_config or PlannerConfig()
        # radix prompt cache: attach one PrefixCache per CAPABLE standby
        # engine (dense transformers; families whose per-row state
        # exceeds pages + pos — SSM/hybrid/enc-dec — skip gracefully and
        # serve exactly as before). Admissions then alias cached
        # prefixes and complete their tails via eager teacher-forced
        # catch-up (``admission_plan``/``catchup_prefill``).
        self.prefix_cache = prefix_cache
        if prefix_cache:
            for host in hosts.values():
                for eng in host.engines():
                    if eng.prefix_cache_capable():
                        eng.enable_prefix_cache()
        self.queues: Dict[str, RequestQueue] = {}
        self._runs: Dict[int, PoolRun] = {}
        self._metrics: Dict[str, ModelPoolMetrics] = {}
        self._planners: Dict[str, StepPlanner] = {}
        self._seq = 0
        self._alloc_frac = 0.0
        self._occ_area = 0.0
        self._page_area = 0.0
        self._last_t = 0.0
        # telemetry plane (attach_telemetry): shared across every engine
        # and per-model planner; reset() re-propagates it to the fresh
        # planners. None = disabled (zero-cost attribute checks).
        self.telemetry = None
        self.reset()

    # ------------------------------------------------- SchedView protocol
    @property
    def running(self) -> List[PoolRun]:
        return list(self._runs.values())

    def free_frac(self, now: float) -> float:
        return 1.0 - self._alloc_frac

    # --------------------------------------------------------- lifecycle
    def reset(self) -> None:
        """Fresh queues/metrics/clock; engines keep their compiled
        executables (reuse the pool across policies without re-warming)."""
        self.queues = {n: RequestQueue(n, p.slo)
                       for n, p in self.profiles.items()}
        self._metrics = {n: ModelPoolMetrics() for n in self.profiles}
        # one StepPlanner per hosted model: the single admission gate
        # (page horizon, SLO expiry, blocked-on-memory accounting, head
        # reservation/aging) admit AND topup route through
        self._planners = {
            n: StepPlanner(config=dataclasses.replace(
                self._planner_config, lazy=self.lazy_kv,
                prefix_cache=self.prefix_cache),
                metrics=self._metrics[n])
            for n in self.profiles}
        self._runs.clear()
        self._seq = 0
        self._alloc_frac = 0.0
        self._occ_area = 0.0
        self._page_area = 0.0
        self._last_t = 0.0
        for p in self._planners.values():
            p.telemetry = self.telemetry
        for host in self.hosts.values():
            for eng in host.engines():
                eng.release_all_slots()     # frees draft twins too
                eng.reset_stats()
                if eng._draft is not None:
                    eng._draft.reset_stats()

    def attach_telemetry(self, tel) -> None:
        """Arm (or with None, disarm) one shared ``Telemetry`` plane
        across the pool: every standby engine (timed, traced dispatches)
        and every per-model planner (lifecycle instants). Survives
        ``reset()`` — run_policy's reset re-propagates it — so attach
        once, serve many policies. Attach AFTER warmup, like
        ``attach_faults``."""
        self.telemetry = tel
        for p in self._planners.values():
            p.telemetry = tel
        for host in self.hosts.values():
            for eng in host.engines():
                eng.attach_telemetry(tel)
                if eng._draft is not None:
                    eng._draft.attach_telemetry(tel)

    def warmup(self) -> None:
        """Compile every standby engine's admission-prefill + slot-step
        path once, up front — after this, serving recompiles nothing.
        Admission goes through ``insert_many`` (one packed prefill per
        admission batch), whose executables key on the packed-token
        bucket: every batch size the engine can page is warmed, covering
        each pow2 bucket a serve-time admission can produce. The warm
        inserts use a 1-token budget: the executables are identical for
        every budget, and 1 is the smallest page footprint — a pool
        deliberately built with fewer pages than one slot maximum (the
        oversubscription knob) warms exactly the batch sizes it can ever
        admit."""
        from repro.serving.engine import _packed_bucket, _pow2_at_least
        for host in self.hosts.values():
            for eng in host.engines():
                min_pages = eng.pages_needed(host.prompt_len, 1)
                warmed = set()
                for k in range(1, eng.n_slots + 1):
                    if eng.paged and k * min_pages > eng.total_pages:
                        break
                    # executables key on the (packed-token bucket, segment
                    # bucket) pair, not the batch size: k values sharing
                    # both compile nothing new, so only O(log) of them run
                    bucket = (_packed_bucket(k * host.prompt_len),
                              _pow2_at_least(k))
                    if bucket in warmed:
                        continue
                    warmed.add(bucket)
                    slots = eng.insert_many(
                        [host.prompt_batch()] * k, n_tokens=[1] * k)
                    eng.step()
                    for slot in slots:
                        eng.free(slot)
                if eng.paged and self.lazy_kv:
                    # lazy pools also dispatch page growth (block-table
                    # row updates) while serving — cross a page boundary
                    # once here so that executable is compiled up front
                    need = eng.pages_needed(host.prompt_len,
                                            eng.page_size + 1)
                    if need <= eng.total_pages:
                        slot = eng.insert(host.prompt_batch(), n_tokens=1,
                                          reserve_tokens=host.prompt_len + 1)
                        eng.grow_slot(
                            slot, host.prompt_len + eng.page_size + 1)
                        eng.free(slot)
                # prefix-cache hit admissions dispatch two more
                # static-shape executables (COW page copy, table-row
                # alias write) — warm them on dead state up front
                eng.warm_prefix_ops()
        self.reset()

    def enable_speculation(self, target: str, draft: str,
                           spec_k: int = 4) -> int:
        """Cross-model speculative decoding over the pool: pair every
        spec-capable standby engine of ``target`` with a fresh ring-slot
        draft engine built from ``draft``'s weights (one per standby —
        drafts are small, and identity slot pairing needs a twin per
        engine). Raises if the vocabularies differ (token ids must mean
        the same thing to drafter and verifier); incapable standbys
        (non-dense families, sampling engines) are skipped. ``step_run``
        then speculates automatically on eligible slots. Returns how
        many standby engines were paired."""
        t_host, d_host = self.hosts[target], self.hosts[draft]
        paired = 0
        for alloc in t_host.allocations.values():
            eng = alloc.engine
            if not eng.spec_capable():
                continue
            d_eng = InferenceEngine(
                d_host.api, d_host.params, cache_len=eng.slot_len,
                alloc_chips=alloc.chips).init_slots(
                    eng.n_slots, paged=False)
            eng.attach_draft(d_eng, spec_k)
            if self.telemetry is not None:
                d_eng.attach_telemetry(self.telemetry)
            paired += 1
        return paired

    def jit_cache_sizes(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for n, host in self.hosts.items():
            for alloc in host.allocations.values():
                for k, v in alloc.engine.jit_cache_sizes().items():
                    out[f"{n}/{alloc.chips}ch/{k}"] = v
        return out

    # ----------------------------------------------------------- serving
    def push(self, req: Request) -> None:
        """Accept one arrival — or shed it (terminal, fail fast) when the
        model's load-shed watermarks are crossed: queue depth against
        ``shed_queue_depth``, pool-wide page occupancy against
        ``shed_page_frac`` (both None by default — no shedding)."""
        q = self.queues[req.model]
        planner = self._planners[req.model]
        used, total = self.page_usage()
        frac = used / total if total else 0.0
        if planner.should_shed(queue_len=len(q), page_frac=frac):
            q.shed_request(req)
            if self.telemetry is not None:
                self.telemetry.request_event(req.model, "shed", rid=req.rid)
            return
        q.push(req)
        if self.telemetry is not None:
            self.telemetry.request_event(req.model, "queued", rid=req.rid)

    def cancel(self, model: str, rid: int, now: float = 0.0) -> bool:
        """Client cancellation at the pool plane: a queued request is
        removed immediately; a resident one frees its slot and pages NOW
        (the Cancel event) and its run continues with the remaining
        slots. Returns False for unknown/terminal rids."""
        del now
        q = self.queues.get(model)
        if q is None:
            return False
        if q.cancel(rid) is not None:
            if self.telemetry is not None:
                self.telemetry.request_event(model, "cancel", rid=rid)
            return True
        for run in self._runs.values():
            if run.model != model:
                continue
            for slot, req in list(run.slots.items()):
                if req.rid == rid:
                    run.slots.pop(slot)
                    run.remaining.pop(slot, None)
                    run.engine.free(slot)
                    run.freed_early = True    # topup may refill the slot
                    q.mark_cancelled(req)
                    if self.telemetry is not None:
                        self.telemetry.request_event(model, "cancel",
                                                     rid=rid, slot=slot)
                    return True
        return False

    def page_usage(self) -> tuple:
        """(pages in use, servable pages) — the KV-memory analogue of
        allocation occupancy. Pages in use sum over every standby engine,
        but the denominator counts each model's LARGEST standby pool only:
        at most one standby per model serves at a time, so summing all of
        them would cap the reported occupancy at 1/n_standbys even with
        the active pool fully allocated."""
        used = total = 0
        for host in self.hosts.values():
            total += max((e.total_pages for e in host.engines()), default=0)
            used += sum(e.total_pages - e.free_pages for e in host.engines())
        return used, total

    def advance_time(self, t: float) -> None:
        """Accumulate allocation + page occupancy up to ``t`` (controller
        owns the clock and calls this before moving ``now`` forward)."""
        dt = t - self._last_t
        self._occ_area += min(self._alloc_frac, 1.0) * dt
        used, total = self.page_usage()
        if total:
            self._page_area += (used / total) * dt
        self._last_t = t

    def _pop_admissible(self, model: str, eng: InferenceEngine,
                        max_batch: int, now: float, gen_len: int,
                        drop_expired: bool) -> List:
        """Pop up to ``max_batch`` requests the engine can actually back —
        a thin shim over the model's ``StepPlanner.select_admissible``,
        the single admission gate ``admit`` AND ``topup`` share: a free
        slot plus pages for each request's reserved horizon (whole prompt
        + n_tokens budget, or prompt-only under ``lazy_kv``), requests
        the pool cannot back re-queued and counted in
        ``blocked_on_memory`` once over their lifetime, and a
        page-blocked FIFO head accruing an aging page reservation that
        bypassing smaller requests cannot spend (the ROADMAP
        anti-starvation follow-on; the SLO-expiry bound on a bypassed
        request is unchanged and still regression-tested). Returns
        [(request, token budget)], in queue order."""
        return self._planners[model].select_admissible(
            eng, self.queues[model], self.hosts[model].prompt_len,
            max_batch, now, gen_len, drop_expired)

    def admit(self, rr: RunRequest, now: float, gen_len: int,
              drop_expired: bool = True) -> Optional[PoolRun]:
        """Translate one policy ``RunRequest`` into an engine run.

        Grants the largest standby allocation <= the requested chips (the
        paper's power-of-two sub-mesh quantization; the latency cost of the
        rounding is charged to the run), caps the batch to the engine's
        free slots, prefills each admitted request into a slot, and books
        the allocation. When the ask is below every standby engine, the
        smallest pre-built one runs instead IF it fits free capacity — a
        real system can only run allocations it has executables for
        (GSLICE's over-committed partitions depend on this). The granted
        chips are what is booked, and every divergence from the policy's
        own ledger stays visible: ``alloc_upgrades`` counts fallbacks to a
        bigger-than-asked engine, ``alloc_downgrades`` counts runs granted
        fewer chips than asked (quantization between standby points, or
        capacity pressure) whose latency exceeds what the policy budgeted.
        Returns None when nothing could start (model already running, no
        queue, no slots, or no capacity)."""
        host = self.hosts.get(rr.model)
        if host is None:
            return None
        if any(r.model == rr.model for r in self._runs.values()):
            # one run per model at a time. Also load-bearing for budget
            # accounting: engines belong to one model, so this guarantees
            # at most one run per ENGINE — engine.step() advances every
            # active slot's generated counter, which is only correct while
            # all of an engine's slots belong to the same run (+ topups).
            return None
        q = self.queues[rr.model]
        if len(q) == 0:
            return None
        total = self.sim.total_chips
        free = self.free_frac(now)
        fitting = sorted((c for c in host.allocations if c <= rr.chips),
                         reverse=True)
        upgraded = not fitting
        cands = fitting or [min(host.allocations)]
        alloc = None
        for c in cands:
            if rr.oversubscribe or c / total <= free + 1e-9:
                alloc = host.allocations[c]
                break
        downgraded = (alloc is not None and not upgraded
                      and alloc.chips < min(rr.chips, total))
        if alloc is None or alloc.engine.free_slots == 0:
            return None
        eng = alloc.engine
        kept = self._pop_admissible(rr.model, eng, rr.batch, now, gen_len,
                                    drop_expired)
        if not kept:
            return None
        prof = self.profiles[rr.model]
        lat = prof.latency(alloc.chips, len(kept)) * rr.dilation
        gen_max = max(b for _, b in kept)
        run = PoolRun(
            seq=self._seq, model=rr.model, req_chips=rr.chips,
            chips=alloc.chips, frac=alloc.chips / total,
            batch=len(kept), engine=eng, slots={}, remaining={},
            latency=lat, step_cost=lat / gen_max, start=now,
            next_time=now + self.sim.dispatch_gap + lat / gen_max)
        # the admission is a StepPlan of whole-prompt first chunks: the
        # engine executes it as ONE packed prefill dispatch with each
        # segment's K/V scattered straight into its slot's pages
        plan = self._planners[rr.model].admission_plan(
            [host.prompt_batch()] * len(kept), kept, eng=eng)
        try:
            sres = eng.execute(plan)
        except EngineFault:
            # the fault fired BEFORE the plan mutated anything, so any
            # alias chunks still hold their match-time pins — return
            # them or recover()'s page-conservation audit trips
            self._release_plan_pins(eng, plan)
            self._engine_reset(rr.model, eng, kept)
            return None
        if sres.admission_failed:
            # transient/injected allocator failure: insert_many rolled
            # back all-or-nothing — alias admissions that DID land roll
            # back here too (all-or-nothing at the pool grain), then
            # requeue and let a later plan retry
            for slot in sres.admitted.values():
                eng.free(slot)
            for req, _ in kept:
                q.push(req)
            return None
        self._finish_aliases(host, eng, plan, sres)
        for req, budget in kept:
            slot = sres.admitted.get(req.rid)
            if slot is None:
                # an individual alias admission ran out of fresh tail
                # pages (its pins already went back to the cache):
                # requeue just that request
                q.push(req)
                continue
            run.slots[slot] = req
            run.remaining[slot] = budget
            if self.telemetry is not None:
                self.telemetry.request_event(rr.model, "admitted",
                                             rid=req.rid, slot=slot,
                                             chips=alloc.chips)
        if not run.slots:
            return None
        run.batch = len(run.slots)
        m = self._metrics[rr.model]
        self._seq += 1
        self._runs[run.seq] = run
        self._alloc_frac += run.frac
        m.runs += 1
        m.alloc_upgrades += int(upgraded)
        m.alloc_downgrades += int(downgraded)
        m.runtime += lat
        m.chip_seconds += alloc.chips * lat
        return run

    def topup(self, run: PoolRun, now: float, gen_len: int,
              drop_expired: bool = True) -> int:
        """Mid-run re-admission: refill slots that ragged budgets freed
        early, without waiting for the run (or the policy) — continuous
        batching at the pool level. Refills never grow the run past its
        admit-time batch: that batch is what the policy sized against the
        SLO (Eq. 11/12) and what ``step_cost`` was derived from, so the
        run's concurrency — and its modeled per-step latency — stay
        honest. The span the new requests add is what is charged to the
        model's runtime/chip-seconds ledger (the paper's fairness
        currency) — concurrent tokens are not double-billed."""
        if not run.freed_early or run.model not in self.queues:
            return 0
        host = self.hosts[run.model]
        eng = run.engine
        refill = min(eng.free_slots, run.batch - len(run.remaining))
        if len(self.queues[run.model]) == 0 or refill <= 0:
            return 0
        before = max(run.remaining.values(), default=0)
        kept = self._pop_admissible(run.model, eng, refill, now,
                                    gen_len, drop_expired)
        if kept:
            plan = self._planners[run.model].admission_plan(
                [host.prompt_batch()] * len(kept), kept, eng=eng)
            try:
                sres = eng.execute(plan)
            except EngineFault:
                self._release_plan_pins(eng, plan)
                self._engine_reset(run.model, eng, kept)
                return 0
            if sres.admission_failed:
                for slot in sres.admitted.values():
                    eng.free(slot)
                for req, _ in kept:
                    self.queues[run.model].push(req)
                return 0
            self._finish_aliases(host, eng, plan, sres)
            admitted = 0
            for req, budget in kept:
                slot = sres.admitted.get(req.rid)
                if slot is None:
                    self.queues[run.model].push(req)
                    continue
                admitted += 1
                run.slots[slot] = req
                run.remaining[slot] = budget
                if self.telemetry is not None:
                    self.telemetry.request_event(run.model, "admitted",
                                                 rid=req.rid, slot=slot,
                                                 chips=run.chips)
            if not admitted:
                return 0
            m = self._metrics[run.model]
            extension = max(0, max(run.remaining.values()) - before)
            m.topups += admitted
            m.runtime += extension * run.step_cost
            m.chip_seconds += run.chips * extension * run.step_cost
            run.latency += extension * run.step_cost
        return len(kept)

    def _preempt_victim(self, run: PoolRun, now: float) -> None:
        """Evict one of this run's residents: its pages free, its request
        requeues (prompt re-prefills from scratch on re-admission — the
        vLLM recompute-preemption discipline; greedy decode keeps the
        restarted stream identical). The victim is chosen by the shared
        ``preemption_key`` — most SLO slack per unit of sunk recompute
        work (``PlannerConfig.victim="newest"`` restores the legacy
        latest-arrival rule), the same rule the tick plane's
        ``StepPlanner._pick_victim`` applies."""
        eng = run.engine
        mode = self._planner_config.victim
        victim = max(
            run.slots.items(),
            key=lambda kv: preemption_key(kv[1], eng.slot_pos(kv[0]), now,
                                          mode) + (kv[0],))[0]
        req = run.slots.pop(victim)
        run.remaining.pop(victim, None)
        run.engine.free(victim)
        run.freed_early = True           # topup may refill the freed slot
        req.reset_stream()               # recompute restarts the stream
        self.queues[run.model].push(req)
        m = self._metrics[run.model]
        m.preemptions += 1
        m.requeues += 1
        if self.telemetry is not None:
            self.telemetry.request_event(run.model, "preempt",
                                         rid=req.rid, slot=victim)

    @staticmethod
    def _release_plan_pins(eng: InferenceEngine, plan) -> None:
        """Return every alias chunk's match-time pins after an execute
        that never ran (``EngineFault`` fires before the plan mutates
        anything) — without this the reset's page-conservation audit
        (free == total after the cache flush) trips."""
        if eng.prefix_cache is None:
            return
        for c in plan.admissions:
            if getattr(c, "alias", None) is not None:
                eng.prefix_cache.release_hit(c.alias)

    def _finish_aliases(self, host: ModelHost, eng: InferenceEngine,
                        plan, sres) -> None:
        """Pool-plane completion of prefix-cache admissions: aliased
        slots catch up their uncovered prompt tail eagerly (teacher-
        forced through the warm decode executable — the pool has no
        per-tick forced phase to spread them over), then every admitted
        slot registers its full prompt pages in the cache (``insert``
        dedupes shared prefixes, so repeats retain nothing new)."""
        cache = eng.prefix_cache
        if cache is None:
            return
        import numpy as np
        toks = [int(t) for t in
                np.asarray(host.prompt_batch()["tokens"])[0]]
        hits = {c.rid: c.alias for c in plan.admissions
                if getattr(c, "alias", None) is not None}
        n_full = host.prompt_len // eng.page_size
        for rid, slot in sres.admitted.items():
            hit = hits.get(rid)
            if hit is not None:
                eng.catchup_prefill(slot, toks, hit.covered)
            if n_full >= 1:
                cache.insert(toks[:n_full * eng.page_size],
                             eng.slot_pages(slot)[:n_full])

    def _engine_reset(self, model: str, eng: InferenceEngine,
                      kept=None) -> None:
        """Pool half of the engine-reset path (``EngineFault``: retries
        exhausted). Device slot state is unknown, so every request that
        was in flight on the engine — the batch being admitted (``kept``)
        and any resident run — recompute-requeues, the run's allocation
        releases, and the engine resets (all slots freed, page-
        conservation audit). Stale controller heap entries for dropped
        runs are ignored by ``Controller.fire`` (missing seq)."""
        q = self.queues[model]
        m = self._metrics[model]
        for req, _ in kept or []:
            req.reset_stream()
            q.push(req)
            m.requeues += 1
        for seq, run in list(self._runs.items()):
            if run.engine is eng:
                for req in run.slots.values():
                    req.reset_stream()
                    q.push(req)
                    m.requeues += 1
                del self._runs[seq]
                self._alloc_frac -= run.frac
        if not self._runs:
            self._alloc_frac = 0.0
        eng.recover()

    def step_run(self, run: PoolRun, now: float) -> bool:
        """One REAL decode dispatch for all of this run's slots (executed
        as a StepPlan, like every other data-plane entry). The engine's
        done flags (per-request token budgets) say which slots finished:
        their requests complete NOW — mid-run, at ragged times — and
        their pages return to the pool immediately. Under ``lazy_kv``
        the decode first grows each slot's page horizon to cover its
        next write; an ``OutOfPages`` there preempts the slack-aware
        victim (pages freed, request requeued) and retries. An
        ``EngineFault`` from the dispatch (transient-fault retries
        exhausted) resets the engine: the whole run recompute-requeues
        and the allocation releases. True when the run finished and its
        allocation was released."""
        from repro.serving.plan import StepPlan
        eng = run.engine
        if self.lazy_kv and eng.paged:
            while run.remaining:
                try:
                    eng.ensure_decode_room(sorted(run.remaining))
                    break
                except OutOfPages:
                    self._preempt_victim(run, now)
            if not run.remaining:
                del self._runs[run.seq]
                self._alloc_frac -= run.frac
                if not self._runs:
                    self._alloc_frac = 0.0
                return True
        decode_slots = sorted(run.remaining)
        spec_entries: List = []
        if eng._draft is not None and eng.spec_k > 0:
            # pool-plane speculation: a slot speculates while its draft
            # twin is in lockstep, or — right after admission, before any
            # decode — by initializing the twin from the model's (shared)
            # prompt. Mid-stream desync cannot re-init here (the pool does
            # not record per-slot token streams), so such slots just
            # decode plainly.
            import numpy as np
            host = self.hosts[run.model]
            prompt = None
            for slot in list(decode_slots):
                rem = run.remaining[slot]
                pos = eng.slot_pos(slot)
                k = min(eng.spec_k, rem - 1, eng.slot_len - 1 - pos)
                if k < 1:
                    continue
                init = None
                if not eng.draft_synced(slot):
                    if pos != host.prompt_len:
                        continue
                    if prompt is None:
                        prompt = [int(t) for t in np.asarray(
                            host.prompt_batch()["tokens"])[0]]
                    init = prompt
                if self.lazy_kv and eng.paged:
                    while k >= 1:       # degrade k on page pressure,
                        try:            # never preempt for speculation
                            eng.grow_slot(slot, pos + k + 1)
                            break
                        except OutOfPages:
                            k -= 1
                    if k < 1:
                        continue
                spec_entries.append((slot, k, init))
                decode_slots.remove(slot)
        try:
            res = eng.execute(StepPlan(decodes=decode_slots,
                                       spec=spec_entries))
        except EngineFault:
            self._engine_reset(run.model, eng)
            return True
        emitted = dict(res.spec_tokens)
        for slot in res.tokens:
            emitted.setdefault(slot, []).append(res.tokens[slot])
        for slot, toks in emitted.items():
            req = run.slots.get(slot)
            if req is not None:
                if req.first_token < 0:
                    req.first_token = now
                    if self.telemetry is not None:
                        self.telemetry.request_event(
                            run.model, "first_token", rid=req.rid)
                req.tokens_out += len(toks)
        owned_emit = sum(len(t) for s, t in emitted.items()
                         if s in run.slots)
        done = res.done
        completed: List[Request] = []
        for slot in done:
            req = run.slots.pop(slot, None)
            if req is None:
                continue                  # not this run's slot (warm state)
            run.engine.free(slot)
            run.remaining.pop(slot, None)
            completed.append(req)
        for slot in run.remaining:
            run.remaining[slot] -= len(emitted.get(slot, (None,)))
        self._metrics[run.model].tokens += owned_emit
        if completed:
            self.queues[run.model].complete(completed, now)
            if self.telemetry is not None:
                for req in completed:
                    self.telemetry.request_event(run.model, "complete",
                                                 rid=req.rid)
            if run.remaining:
                run.freed_early = True
        if not run.remaining:
            del self._runs[run.seq]
            self._alloc_frac -= run.frac
            if not self._runs:        # re-zero: no float-drift build-up
                self._alloc_frac = 0.0
            return True
        run.next_time = now + run.step_cost
        return False

    def snapshot(self, policy: str, duration: float, wall_s: float,
                 steps: int) -> PoolResult:
        """Fold queue-level SLO accounting into the per-model metrics.
        Requests still queued at the end count as violations, and requests
        still decoding in KV slots are reported as ``abandoned`` — both
        mirror the simulator's accounting (which likewise neither
        completes nor violates in-flight work at the cutoff), but nothing
        disappears without a trace."""
        in_flight: Dict[str, int] = {n: 0 for n in self.queues}
        for run in self._runs.values():
            in_flight[run.model] += len(run.slots)
        per: Dict[str, ModelPoolMetrics] = {}
        for n, q in self.queues.items():
            m = self._metrics[n]
            m.completed = q.completed
            m.violated = q.violated + len(q)
            m.dropped = q.dropped
            m.late = q.late
            m.abandoned = in_flight[n]
            m.cancelled = q.cancelled
            m.deadline_aborted = q.deadline_aborted
            m.shed = q.shed
            m.engine_retries = sum(e.stats.engine_retries
                                   for e in self.hosts[n].engines())
            m.engine_resets = sum(e.stats.engine_resets
                                  for e in self.hosts[n].engines())
            m.prefix_hits = sum(e.stats.prefix_hits
                                for e in self.hosts[n].engines())
            m.prefix_hit_tokens = sum(e.stats.prefix_hit_tokens
                                      for e in self.hosts[n].engines())
            m.cow_copies = sum(e.stats.cow_copies
                               for e in self.hosts[n].engines())
            m.draft_tokens = sum(e.stats.draft_tokens
                                 for e in self.hosts[n].engines())
            m.accepted_tokens = sum(e.stats.accepted_tokens
                                    for e in self.hosts[n].engines())
            m.spec_rounds = sum(e.stats.spec_rounds
                                for e in self.hosts[n].engines())
            m.rollbacks = sum(e.stats.rollbacks
                              for e in self.hosts[n].engines())
            m.latencies = list(q.latencies)
            m.ttfts = list(q.ttfts)
            m.tbts = list(q.tbts)
            per[n] = m
        duration = duration or 1e-9
        return PoolResult(policy=policy, duration=duration, wall_s=wall_s,
                          per_model=per, occupancy=self._occ_area / duration,
                          page_occupancy=self._page_area / duration,
                          steps=steps)


# --------------------------------------------------------------------------
# construction
# --------------------------------------------------------------------------
def default_allocations(profile: ModelProfile) -> List[int]:
    """Standby allocation candidates for one model: its efficacy-optimal
    chips and its knee (§5) — the two operating points D-STACK's dynamic
    adaptation moves between — plus, when knee and opt sit far apart, a
    geometric mid point between them (§6.1.2: the dynamic fair pass then
    has a standby to *partially* shrink onto instead of jumping the whole
    way to the knee), plus the full pod, because temporal / Triton-style
    baselines schedule whole-accelerator runs and must get the latency
    they budgeted for, not a silently-downgraded sub-mesh."""
    lo, hi = sorted((max(1, profile.opt_chips), max(1, profile.knee_chips)))
    allocs = {lo, hi, profile.hw.chips_per_pod}
    if hi >= 4 * lo:
        # pow2 geometric mid point of the knee..opt span
        mid = 1 << ((lo.bit_length() - 1 + hi.bit_length() - 1 + 1) // 2)
        allocs.add(min(hi, max(lo, mid)))
    return sorted(allocs)


def build_host(name: str, *, profile: Optional[ModelProfile] = None,
               allocations: Optional[Sequence[int]] = None,
               base_slots: int = 4, cache_len: int = 32,
               prompt_len: int = 8, seed: int = 0,
               request_rate: float = 500.0, reduced: bool = True,
               paged: bool = True, page_size: int = 8,
               total_pages: Optional[int] = None) -> ModelHost:
    """Build one hosted model: weights once, one standby engine per
    allocation. Every standby hosts the same ``base_slots`` KV slots so
    batch capacity is identical across allocations — what the policy's
    chip choice changes is the run's (modeled) latency, not how much it
    can batch, which isolates the spatial-allocation effect the paper
    studies.

    ``base_slots`` / ``page_size`` / ``total_pages`` are the per-model
    capacity knobs: ``total_pages`` defaults to ``base_slots * cache_len /
    page_size`` (ring-equivalent bytes); passing fewer pages than that —
    or more slots over the same pages — is how a host oversubscribes KV
    memory and lets the page pool, not the slot count, gate admission."""
    from repro.configs import get_config
    from repro.models.registry import build_model

    profile = profile or build_profile(name, request_rate=request_rate)
    cfg = get_config(name)
    if reduced:
        cfg = cfg.reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(seed))
    if paged and api.paged_keys and prompt_len >= cache_len:
        raise ValueError(
            f"{name}: prompt_len {prompt_len} leaves no decode room in a "
            f"{cache_len}-token paged slot — every admission would be "
            f"refused (paged slots never evict; raise cache_len)")
    chip_opts = sorted(set(allocations or default_allocations(profile)))
    standby: Dict[int, StandbyAllocation] = {}
    for chips in chip_opts:
        eng = InferenceEngine(api, params, cache_len=cache_len,
                              alloc_chips=chips).init_slots(
            base_slots, paged=paged, page_size=page_size,
            total_pages=total_pages)
        standby[chips] = StandbyAllocation(chips, base_slots, eng)
    return ModelHost(cfg, api, params, profile, standby,
                     prompt_len=prompt_len)


def build_pool(names: Sequence[str], *, request_rate: float = 500.0,
               base_slots: int = 4, cache_len: int = 32, prompt_len: int = 8,
               allocations: Optional[Dict[str, Sequence[int]]] = None,
               caps: Optional[PoolCaps] = None, warm: bool = True,
               reduced: bool = True, paged: bool = True, page_size: int = 8,
               slots: Optional[Dict[str, int]] = None,
               pages: Optional[Dict[str, int]] = None,
               lazy_kv: bool = False,
               planner_config: Optional[PlannerConfig] = None,
               prefix_cache: bool = False) -> EnginePool:
    """Build an EnginePool over reduced real models and (by default) warm
    every standby executable so the measured run compiles nothing.
    ``slots`` / ``pages`` override slot count / usable page count per
    model name (the ROADMAP "per-model tuning" knobs — e.g. give a
    p50-lagging model more slots without re-sizing every host);
    ``lazy_kv`` switches admission to prompt-only page reservation with
    decode-time growth and preempt-and-requeue on ``OutOfPages``;
    ``planner_config`` seeds every per-model planner (load-shed
    watermarks, victim rule — its ``lazy`` field is overridden by
    ``lazy_kv``); ``prefix_cache`` attaches a radix prompt cache to
    every capable standby engine (incapable families skip gracefully)
    and its hit-admission executables are warmed with everything
    else."""
    hosts: Dict[str, ModelHost] = {}
    for i, name in enumerate(names):
        host = build_host(
            name, allocations=(allocations or {}).get(name),
            base_slots=(slots or {}).get(name, base_slots),
            cache_len=cache_len, prompt_len=prompt_len, seed=i,
            request_rate=request_rate, reduced=reduced, paged=paged,
            page_size=page_size, total_pages=(pages or {}).get(name))
        hosts[host.profile.name] = host
    pool = EnginePool(hosts, caps=caps, lazy_kv=lazy_kv,
                      planner_config=planner_config,
                      prefix_cache=prefix_cache)
    if warm:
        pool.warmup()
    return pool
