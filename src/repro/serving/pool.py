"""Policy-driven engine pool: D-STACK's control plane over real engines.

This module is the serving control plane the paper builds in §6, realized
over the real jitted data plane of ``repro.serving.engine`` instead of the
analytic simulator. Component → paper map:

* **StandbyAllocation / ModelHost** — §3.2 + §6.1.2. On GPUs, one model at
  one GPU% is a CUDA-MPS process with a fixed thread percentage; here it is
  one ``InferenceEngine`` whose executables are compiled for one sub-mesh
  chip count. A host keeps one *standby* engine per candidate allocation
  (all sharing one set of weights), compiled once up front — so a policy's
  chip-fraction decision *selects a pre-built executable*; re-allocation is
  an engine switch, never a recompile (the paper's fast re-allocation
  story, and this repo's acceptance bar of zero per-request compilation).

* **EnginePool (a SchedView)** — the policy↔data-plane adapter. The same
  ``plan(now, view)`` that drives ``repro.core.simulator.Simulator`` drives
  this pool: it exposes ``profiles`` / ``queues`` / ``running`` /
  ``free_frac`` / ``sim.total_chips``, and enforces the §6 invariant that
  aggregate allocated chip fraction never exceeds 1.0 (except for policies
  that explicitly model uncontrolled sharing, e.g. Fixed-Batch MPS).

* **Admission (``admit``)** — §6.1 + Eq. 11/12. The policy sizes each run's
  batch with ``ModelProfile.feasible_batch_for`` (largest batch whose
  assembly + inference fits the SLO budget); admission additionally caps it
  to the chosen engine's free KV-cache slots, prefills each request into a
  slot mid-stream (continuous batching), and charges the model's runtime
  scoreboard — the quantity D-STACK's fair opportunistic pass (§6.1.1)
  equalizes.

* **PoolMetrics** (``repro.serving.metrics``) — §7/Fig. 10 reporting:
  per-model throughput, completion-latency p50/p99, SLO violations
  (dropped *and* late-but-served), runtime shares and their Jain fairness
  index, and allocation occupancy.

Time is virtual (discrete-event, from the profile's roofline latency at
the *granted* allocation) while every decode step is a real jitted
dispatch — so policy comparisons are deterministic and SLO-meaningful on a
one-core host, yet exercise the true engine hot path end to end. The
driver loop lives in ``repro.serving.controller``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.profiles import ModelProfile, build_profile
from repro.core.simulator import RunRequest
from repro.serving.engine import InferenceEngine
from repro.serving.metrics import ModelPoolMetrics, PoolResult
from repro.serving.request import Request, RequestQueue


@dataclasses.dataclass(frozen=True)
class PoolCaps:
    """Capacity config — the ``view.sim`` leg of the SchedView protocol."""
    total_chips: int
    dispatch_gap: float = 100e-6


@dataclasses.dataclass
class StandbyAllocation:
    """One pre-built (sub-mesh, executable) pair for a hosted model."""
    chips: int
    n_slots: int
    engine: InferenceEngine


class ModelHost:
    """One hosted model: shared weights + standby engines keyed by chips."""

    def __init__(self, cfg, api, params, profile: ModelProfile,
                 allocations: Dict[int, StandbyAllocation],
                 prompt_len: int = 8):
        self.cfg = cfg
        self.api = api
        self.params = params
        self.profile = profile
        self.allocations = allocations
        self.prompt_len = prompt_len
        self._prompt = None

    def prompt_batch(self) -> Dict[str, jax.Array]:
        """Deterministic single-request prompt (fixed shape: one traced
        prefill signature per engine for the whole workload)."""
        if self._prompt is None:
            b = {"tokens": jnp.ones((1, self.prompt_len), jnp.int32)}
            if self.cfg.has_encoder:
                from repro.serving import frontend
                b["enc_embeds"] = frontend.audio_frames(self.cfg, 1)
            self._prompt = b
        return self._prompt

    def engines(self) -> List[InferenceEngine]:
        return [a.engine for a in self.allocations.values()]


@dataclasses.dataclass
class PoolRun:
    """One in-flight (model, allocation, batch) run — the pool analogue of
    ``simulator.Run``; policies see ``.model`` and ``.frac``."""
    seq: int
    model: str
    req_chips: int             # what the policy asked for
    chips: int                 # granted (largest standby allocation <= ask)
    frac: float
    batch: int
    engine: InferenceEngine
    slots: Dict[int, Request]
    remaining: Dict[int, int]  # decode tokens left per slot
    latency: float             # modeled total run latency at granted chips
    step_cost: float           # latency / gen_len — virtual cost per step
    start: float
    next_time: float


class EnginePool:
    """A pool of slot engines that any ``Policy`` can drive (SchedView)."""

    def __init__(self, hosts: Dict[str, ModelHost],
                 caps: Optional[PoolCaps] = None):
        self.hosts = hosts
        self.profiles: Dict[str, ModelProfile] = {
            n: h.profile for n, h in hosts.items()}
        total = max(p.hw.chips_per_pod for p in self.profiles.values())
        self.sim = caps or PoolCaps(total_chips=total)
        self.queues: Dict[str, RequestQueue] = {}
        self._runs: Dict[int, PoolRun] = {}
        self._metrics: Dict[str, ModelPoolMetrics] = {}
        self._seq = 0
        self._alloc_frac = 0.0
        self._occ_area = 0.0
        self._last_t = 0.0
        self.reset()

    # ------------------------------------------------- SchedView protocol
    @property
    def running(self) -> List[PoolRun]:
        return list(self._runs.values())

    def free_frac(self, now: float) -> float:
        return 1.0 - self._alloc_frac

    # --------------------------------------------------------- lifecycle
    def reset(self) -> None:
        """Fresh queues/metrics/clock; engines keep their compiled
        executables (reuse the pool across policies without re-warming)."""
        self.queues = {n: RequestQueue(n, p.slo)
                       for n, p in self.profiles.items()}
        self._metrics = {n: ModelPoolMetrics() for n in self.profiles}
        self._runs.clear()
        self._seq = 0
        self._alloc_frac = 0.0
        self._occ_area = 0.0
        self._last_t = 0.0
        for host in self.hosts.values():
            for eng in host.engines():
                eng.release_all_slots()
                eng.reset_stats()

    def warmup(self) -> None:
        """Compile every standby engine's insert-prefill + slot-step path
        once, up front — after this, serving recompiles nothing."""
        for host in self.hosts.values():
            for eng in host.engines():
                slot = eng.insert(host.prompt_batch())
                eng.step()
                eng.free(slot)
        self.reset()

    def jit_cache_sizes(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for n, host in self.hosts.items():
            for alloc in host.allocations.values():
                for k, v in alloc.engine.jit_cache_sizes().items():
                    out[f"{n}/{alloc.chips}ch/{k}"] = v
        return out

    # ----------------------------------------------------------- serving
    def push(self, req: Request) -> None:
        self.queues[req.model].push(req)

    def advance_time(self, t: float) -> None:
        """Accumulate allocation occupancy up to ``t`` (controller owns
        the clock and calls this before moving ``now`` forward)."""
        self._occ_area += min(self._alloc_frac, 1.0) * (t - self._last_t)
        self._last_t = t

    def admit(self, rr: RunRequest, now: float, gen_len: int,
              drop_expired: bool = True) -> Optional[PoolRun]:
        """Translate one policy ``RunRequest`` into an engine run.

        Grants the largest standby allocation <= the requested chips (the
        paper's power-of-two sub-mesh quantization; the latency cost of the
        rounding is charged to the run), caps the batch to the engine's
        free slots, prefills each admitted request into a slot, and books
        the allocation. When the ask is below every standby engine, the
        smallest pre-built one runs instead IF it fits free capacity — a
        real system can only run allocations it has executables for
        (GSLICE's over-committed partitions depend on this). The granted
        chips are what is booked, and every divergence from the policy's
        own ledger stays visible: ``alloc_upgrades`` counts fallbacks to a
        bigger-than-asked engine, ``alloc_downgrades`` counts runs granted
        fewer chips than asked (quantization between standby points, or
        capacity pressure) whose latency exceeds what the policy budgeted.
        Returns None when nothing could start (model already running, no
        queue, no slots, or no capacity)."""
        host = self.hosts.get(rr.model)
        if host is None:
            return None
        if any(r.model == rr.model for r in self._runs.values()):
            return None                       # one run per model at a time
        q = self.queues[rr.model]
        if len(q) == 0:
            return None
        total = self.sim.total_chips
        free = self.free_frac(now)
        fitting = sorted((c for c in host.allocations if c <= rr.chips),
                         reverse=True)
        upgraded = not fitting
        cands = fitting or [min(host.allocations)]
        alloc = None
        for c in cands:
            if rr.oversubscribe or c / total <= free + 1e-9:
                alloc = host.allocations[c]
                break
        downgraded = (alloc is not None and not upgraded
                      and alloc.chips < min(rr.chips, total))
        if alloc is None or alloc.engine.free_slots == 0:
            return None
        batch = q.pop_batch(min(rr.batch, alloc.engine.free_slots), now,
                            drop_expired)
        if not batch:
            return None
        prof = self.profiles[rr.model]
        lat = prof.latency(alloc.chips, len(batch)) * rr.dilation
        gen_len = max(1, gen_len)
        run = PoolRun(
            seq=self._seq, model=rr.model, req_chips=rr.chips,
            chips=alloc.chips, frac=alloc.chips / total,
            batch=len(batch), engine=alloc.engine, slots={}, remaining={},
            latency=lat, step_cost=lat / gen_len, start=now,
            next_time=now + self.sim.dispatch_gap + lat / gen_len)
        for req in batch:
            slot = alloc.engine.insert(host.prompt_batch())
            run.slots[slot] = req
            run.remaining[slot] = gen_len
        self._seq += 1
        self._runs[run.seq] = run
        self._alloc_frac += run.frac
        m = self._metrics[rr.model]
        m.runs += 1
        m.alloc_upgrades += int(upgraded)
        m.alloc_downgrades += int(downgraded)
        m.runtime += lat
        m.chip_seconds += alloc.chips * lat
        return run

    def step_run(self, run: PoolRun, now: float) -> bool:
        """One REAL decode dispatch for all of this run's slots; completes
        and frees slots whose token budget is exhausted. True when the run
        finished and its allocation was released."""
        run.engine.step()
        done: List[Request] = []
        for slot in list(run.remaining):
            run.remaining[slot] -= 1
            if run.remaining[slot] <= 0:
                run.engine.free(slot)
                done.append(run.slots.pop(slot))
                del run.remaining[slot]
        self._metrics[run.model].tokens += len(done) + len(run.remaining)
        if done:
            self.queues[run.model].complete(done, now)
        if not run.remaining:
            del self._runs[run.seq]
            self._alloc_frac -= run.frac
            if not self._runs:        # re-zero: no float-drift build-up
                self._alloc_frac = 0.0
            return True
        run.next_time = now + run.step_cost
        return False

    def snapshot(self, policy: str, duration: float, wall_s: float,
                 steps: int) -> PoolResult:
        """Fold queue-level SLO accounting into the per-model metrics.
        Requests still queued at the end count as violations, and requests
        still decoding in KV slots are reported as ``abandoned`` — both
        mirror the simulator's accounting (which likewise neither
        completes nor violates in-flight work at the cutoff), but nothing
        disappears without a trace."""
        in_flight: Dict[str, int] = {n: 0 for n in self.queues}
        for run in self._runs.values():
            in_flight[run.model] += len(run.slots)
        per: Dict[str, ModelPoolMetrics] = {}
        for n, q in self.queues.items():
            m = self._metrics[n]
            m.completed = q.completed
            m.violated = q.violated + len(q)
            m.dropped = q.dropped
            m.late = q.late
            m.abandoned = in_flight[n]
            m.latencies = list(q.latencies)
            per[n] = m
        duration = duration or 1e-9
        return PoolResult(policy=policy, duration=duration, wall_s=wall_s,
                          per_model=per, occupancy=self._occ_area / duration,
                          steps=steps)


# --------------------------------------------------------------------------
# construction
# --------------------------------------------------------------------------
def default_allocations(profile: ModelProfile) -> List[int]:
    """Standby allocation candidates for one model: its efficacy-optimal
    chips and its knee (§5) — the two operating points D-STACK's dynamic
    adaptation moves between — plus the full pod, because temporal /
    Triton-style baselines schedule whole-accelerator runs and must get
    the latency they budgeted for, not a silently-downgraded sub-mesh."""
    return sorted({max(1, profile.opt_chips), max(1, profile.knee_chips),
                   profile.hw.chips_per_pod})


def build_host(name: str, *, profile: Optional[ModelProfile] = None,
               allocations: Optional[Sequence[int]] = None,
               base_slots: int = 4, cache_len: int = 32,
               prompt_len: int = 8, seed: int = 0,
               request_rate: float = 500.0, reduced: bool = True) -> ModelHost:
    """Build one hosted model: weights once, one standby engine per
    allocation. Every standby hosts the same ``base_slots`` KV slots so
    batch capacity is identical across allocations — what the policy's
    chip choice changes is the run's (modeled) latency, not how much it
    can batch, which isolates the spatial-allocation effect the paper
    studies."""
    from repro.configs import get_config
    from repro.models.registry import build_model

    profile = profile or build_profile(name, request_rate=request_rate)
    cfg = get_config(name)
    if reduced:
        cfg = cfg.reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(seed))
    chip_opts = sorted(set(allocations or default_allocations(profile)))
    standby: Dict[int, StandbyAllocation] = {}
    for chips in chip_opts:
        eng = InferenceEngine(api, params, cache_len=cache_len,
                              alloc_chips=chips).init_slots(base_slots)
        standby[chips] = StandbyAllocation(chips, base_slots, eng)
    return ModelHost(cfg, api, params, profile, standby,
                     prompt_len=prompt_len)


def build_pool(names: Sequence[str], *, request_rate: float = 500.0,
               base_slots: int = 4, cache_len: int = 32, prompt_len: int = 8,
               allocations: Optional[Dict[str, Sequence[int]]] = None,
               caps: Optional[PoolCaps] = None, warm: bool = True,
               reduced: bool = True) -> EnginePool:
    """Build an EnginePool over reduced real models and (by default) warm
    every standby executable so the measured run compiles nothing."""
    hosts: Dict[str, ModelHost] = {}
    for i, name in enumerate(names):
        host = build_host(
            name, allocations=(allocations or {}).get(name),
            base_slots=base_slots, cache_len=cache_len,
            prompt_len=prompt_len, seed=i, request_rate=request_rate,
            reduced=reduced)
        hosts[host.profile.name] = host
    pool = EnginePool(hosts, caps=caps)
    if warm:
        pool.warmup()
    return pool
