"""Batched inference engine — the data plane under the D-STACK scheduler.

One engine instance wraps one (model, sub-mesh) pair: jitted prefill and
decode executables, a KV/state cache, and greedy generation. On a real pod
the scheduler holds one engine per (model, chip-allocation) — this is the
TPU analogue of the paper's CUDA-MPS process with a fixed GPU% (§3.2): the
compiled executable pins the spatial allocation, and re-allocation means
switching to a standby engine compiled for a different sub-mesh while the
active one keeps serving.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.registry import ModelAPI


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0
    decode_steps: int = 0
    tokens_out: int = 0


class InferenceEngine:
    def __init__(self, api: ModelAPI, params, *, cache_len: int = 256,
                 mesh=None, donate_cache: bool = True):
        self.api = api
        self.cfg = api.cfg
        self.params = params
        self.cache_len = cache_len
        self.mesh = mesh
        self.stats = EngineStats()

        if mesh is not None:
            from jax.sharding import NamedSharding
            pspecs = api.param_specs(mesh)
            self._param_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
        else:
            self._param_sh = None

        self._prefill = jax.jit(
            lambda p, batch: api.prefill(p, batch, cache_len),
            static_argnums=())
        donate = (2,) if donate_cache else ()
        self._decode = jax.jit(
            lambda p, tok, cache: api.decode_step(p, tok, cache),
            donate_argnums=donate)

    # ------------------------------------------------------------------
    def new_cache(self, batch: int, cache_len: Optional[int] = None):
        return self.api.init_cache(batch, cache_len or self.cache_len)

    def prefill(self, batch: Dict[str, Any], cache_len: Optional[int] = None):
        if cache_len is not None and cache_len != self.cache_len:
            logits, cache = jax.jit(
                lambda p, b: self.api.prefill(p, b, cache_len))(
                    self.params, batch)
        else:
            logits, cache = self._prefill(self.params, batch)
        self.stats.prefills += 1
        return logits, cache

    def decode(self, token, cache):
        logits, cache = self._decode(self.params, token, cache)
        self.stats.decode_steps += 1
        return logits, cache

    # ------------------------------------------------------------------
    def generate(self, batch: Dict[str, Any], max_new_tokens: int,
                 greedy: bool = True, rng: Optional[jax.Array] = None):
        """Prefill + autoregressive decode. Returns (B, max_new_tokens)."""
        b = batch["tokens"].shape[0]
        need = batch["tokens"].shape[1] + max_new_tokens
        logits, cache = self.prefill(batch, max(self.cache_len, need))
        outs = []
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(max_new_tokens):
            outs.append(tok)
            logits, cache = self.decode(tok, cache)
            if greedy:
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
            else:
                rng, sub = jax.random.split(rng)
                tok = jax.random.categorical(sub, logits).astype(jnp.int32)
        self.stats.tokens_out += b * max_new_tokens
        return jnp.stack(outs, axis=1)


def make_engine(cfg, *, seed: int = 0, cache_len: int = 256,
                dtype=jnp.float32) -> InferenceEngine:
    """Convenience constructor used by examples/tests (CPU scale)."""
    from repro.models.registry import build_model
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(seed), dtype)
    return InferenceEngine(api, params, cache_len=cache_len)
