"""Batched inference engine — the data plane under the D-STACK scheduler.

One engine instance wraps one (model, sub-mesh) pair: jitted prefill and
decode executables, a KV/state cache, and greedy generation. On a real pod
the scheduler holds one engine per (model, chip-allocation) — this is the
TPU analogue of the paper's CUDA-MPS process with a fixed GPU% (§3.2): the
compiled executable pins the spatial allocation, and re-allocation means
switching to a standby engine compiled for a different sub-mesh while the
active one keeps serving.

Decode hot-path architecture
----------------------------
The paper's throughput gains assume the data plane keeps the accelerator
saturated while the scheduler multiplexes models; three mechanisms here
make that true on the host side:

1. **Scan-based generation.** ``generate`` runs the whole autoregressive
   loop as a single jitted ``jax.lax.scan`` with the KV cache donated into
   the executable — ONE dispatch per generate call instead of one per
   token. The eager per-token loop survives as ``generate_eager`` (it is
   the benchmark baseline; see ``benchmarks/bench_decode.py``).

2. **Power-of-two bucketing.** Executables specialize on cache shape AND
   scan length, so naively sizing the cache to ``prompt +
   max_new_tokens`` (or the scan to the exact token count) re-compiles
   for every distinct request. ``bucket_len`` rounds the cache length up
   to the next power of two (floored at the engine's base ``cache_len``)
   and ``generate`` buckets the scan length the same way (surplus tokens
   discarded): prefill/decode/generate executables are compiled once per
   bucket — O(log max_len) compilations total — and reused for every
   request that fits.

3. **Packed ragged prefill for admission bursts.** ``insert_many``
   admits a WHOLE admission batch in one prefill dispatch: the prompts
   are concatenated into a single (1, total_tokens) row with per-token
   segment ids (bucketed to a power of two — O(log max_len)
   executables), run through the family's ``prefill_packed`` (segment-
   masked attention; SSM state resets at segment boundaries), and each
   segment's K/V is scattered DIRECTLY into its slot's pages by one
   jitted token-indexed scatter — no per-request dispatches, no
   pad-to-max FLOPs, no intermediate dense per-slot copy. The pool's
   admission and topup paths batch through it.

4. **Slot-based continuous batching over a PAGED KV cache.**
   ``init_slots`` allocates a fixed number of slots whose K/V storage is,
   by default, a shared pool of fixed-size pages indexed per sequence by a
   block table (``repro.serving.kv_cache``; ``paged=False`` restores the
   original per-slot ring, kept as the parity/bench baseline). ``insert``
   prefills one request, allocates pages for its prompt plus its decode
   budget (``n_tokens``), and scatters the prompt K/V into them;
   ``step`` decodes one token for all slots in a single dispatch and
   reports which slots just exhausted their budget (per-request ragged
   generation lengths — the done flags drive early slot free and mid-run
   re-admission upstream); ``free`` returns the slot's pages to the pool
   and parks its table row on the null page. Because every sequence
   carries its own position/length (``cache["pos"]`` is a (B,) vector end
   to end) and pages are fully indirect, admitting a new request never
   repads, recompiles, moves another sequence's cache, or perturbs other
   slots — and KV memory in use tracks tokens actually resident instead
   of n_slots × max_len (the admission bottleneck paging removes).

5. **Declarative step plans.** ``execute(plan)`` is the single entry
   point the serving control planes drive: one ``StepPlan``
   (``repro.serving.plan``) per tick runs frees → preemptions → lazy
   page grows → first prefill chunks (ONE packed prefill) → chunk
   continuations (ONE packed prefix-recompute prefill over every
   mid-prefill slot, riding the same executables and segment scatter as
   admissions) → decodes (ONE masked slot step) — at most three model
   dispatches per tick, all against pre-compiled executables. Prefix
   recompute plus the PR-4 packed-parity guarantee is what makes
   chunked prefill bit-exact with one-shot prefill; lazy reservation +
   ``grow_slot`` is what makes vLLM-style preempt-and-requeue a plan
   variant instead of a new code path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.registry import ModelAPI
from repro.serving.faults import EngineFault, TransientFault
from repro.serving.kv_cache import NULL_PAGE, OutOfPages, PagedKVCache


def _pow2_at_least(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length()


def _packed_bucket(n: int) -> int:
    """Packed-token bucket: smallest of {2^k, 3·2^(k-1)} >= n. The packed
    prefill row is the SUM of an admission batch's prompt lengths, so its
    padding waste is pure lost prefill throughput; the half-step doubles
    the executable count per octave (still O(log max_len)) and caps the
    waste at 33% instead of 100%."""
    p = _pow2_at_least(n)
    half = 3 * p // 4
    return half if half >= n else p


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Static sampling configuration — hashable, so it is part of the jitted
    generate executable's cache key (one executable per distinct setting,
    reused across requests). temperature <= 0 means greedy arg-max."""
    temperature: float = 1.0
    top_k: int = 0
    top_p: float = 1.0


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0          # prefill DISPATCHES (a packed one counts 1)
    packed_prefills: int = 0   # of which packed multi-segment dispatches
    # chunk-continuation DISPATCHES: one packed prefix-recompute prefill
    # advances every mid-prefill slot's chunk (StepPlan admissions with
    # start > 0). Each is also counted in prefills/packed_prefills (it
    # IS a packed prefill), and prefill_tokens charges the full prefix
    # rows it computed — recompute waste stays visible
    chunk_prefills: int = 0
    # prompt tokens prefilled: what the dispatch actually computed — the
    # packed path charges sum(real lens), `prefill` charges B×S as given
    # (includes padding only if the CALLER padded the batch)
    prefill_tokens: int = 0
    decode_steps: int = 0
    tokens_out: int = 0
    inserts: int = 0
    # lazy page growth: block-table extension dispatches (page
    # boundaries crossed under PlannerConfig.lazy)
    grows: int = 0
    # fault tolerance (ISSUE 6): transient dispatch faults absorbed by
    # execute's bounded retry, and full engine resets (retries exhausted
    # or a stuck tick) that dropped all slot state for recompute-requeue
    engine_retries: int = 0
    engine_resets: int = 0
    # radix prompt cache (ISSUE 8): admissions whose prompt prefix was
    # aliased from cached pages instead of prefilled, the prompt tokens
    # those hits skipped, copy-on-write page copies (a hit ending inside
    # a page), and the teacher-forced catch-up tokens hit admissions
    # consumed through the decode dispatch (they ride `step` but are
    # prefill progress, not generated output — kept out of tokens_out)
    prefix_hits: int = 0
    prefix_hit_tokens: int = 0
    cow_copies: int = 0
    forced_catchup_tokens: int = 0
    # cross-request dedup (ISSUE 10): duplicate prompt-prefix pages a
    # row released at registration time by repointing its block table at
    # the radix cache's canonical pages — concurrent same-prefix
    # admissions double-fill pages the cache could not yet serve
    dedup_pages: int = 0
    # incremental chunk attention (ISSUE 9): continuation dispatches that
    # computed ONLY the new chunk against resident pages (no prefix
    # recompute) — each is also counted in chunk_prefills
    incr_chunks: int = 0
    # speculative decoding (ISSUE 9): draft tokens proposed, of which
    # accepted by the target's verify chunk, verify rounds run, and
    # rounds that rejected at least one draft token (rolled back to the
    # last accepted position)
    draft_tokens: int = 0
    accepted_tokens: int = 0
    spec_rounds: int = 0
    rollbacks: int = 0


class InferenceEngine:
    def __init__(self, api: ModelAPI, params, *, cache_len: int = 256,
                 mesh=None, donate_cache: bool = True,
                 alloc_chips: Optional[int] = None):
        self.api = api
        self.cfg = api.cfg
        self.params = params
        self.cache_len = cache_len
        self.mesh = mesh
        self.donate_cache = donate_cache
        # chip count of the sub-mesh this engine's executables are compiled
        # for — purely a label on this host, but the EnginePool keys standby
        # engines by it (the paper's re-allocation story: switching
        # allocation = switching to a pre-built engine, never recompiling)
        self.alloc_chips = alloc_chips
        self.stats = EngineStats()
        # fault tolerance (repro.serving.faults): injector armed at the
        # dispatch site of execute() and inside the page allocator;
        # transient dispatch faults retry up to retry_limit times with
        # exponential backoff before escalating to EngineFault
        self.fault_injector = None
        self.retry_limit = 2
        self.retry_backoff_s = 0.0
        # telemetry plane (repro.serving.telemetry): when attached, each
        # of execute()'s ≤3 dispatches is wall-clock timed behind a
        # block_until_ready and traced as a sub-span. None = every
        # instrumentation site is a single attribute check (no clock
        # reads, no blocking, bit-identical behavior).
        self.telemetry = None

        if mesh is not None:
            from jax.sharding import NamedSharding
            pspecs = api.param_specs(mesh)
            self._param_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
        else:
            self._param_sh = None

        self._prefill_jit: Dict[int, Any] = {}
        self._gen_jit: Dict[Any, Any] = {}
        donate = (2,) if donate_cache else ()
        self._donate_cache_argnums = donate
        self._decode = jax.jit(
            lambda p, tok, cache: api.decode_step(p, tok, cache),
            donate_argnums=donate)
        # one slot-step executable per sampling config (None = greedy);
        # built lazily, reused for every subsequent step
        self._slot_step_jit: Dict[Optional[SamplingParams], Any] = {}
        self._write_slot = jax.jit(_write_slot, donate_argnums=(0,))
        self._write_slot_paged = None      # built by init_slots(paged=True)
        self._clear_slot = None
        self._clear_ring = None            # built by init_slots(paged=False)
        # packed ragged prefill: one executable per (total-token bucket,
        # row_len) pair — O(log max_len) total; built lazily
        self._packed_prefill_jit: Dict[Any, Any] = {}
        self._write_segments = None        # built by init_slots
        # chunk continuation (prefix recompute) reuses _packed_prefill_jit
        # and _write_segments — chunked serving compiles nothing new
        self._set_table_row = None         # built by init_slots(paged=True)
        # radix prompt cache (enable_prefix_cache): host-side radix tree
        # over the page allocator, plus the two static-shape executables
        # hit admissions dispatch — a COW page copy and the combined
        # table-row + position write
        self.prefix_cache = None
        self._copy_page = None
        self._alias_slot = None
        # incremental chunk attention (ISSUE 9): one executable per
        # (token bucket, row_len, segment bucket) triple, shared by
        # chunked-prefill continuations and speculative verification.
        # The slot cache rides in as a READ-ONLY operand (never donated):
        # only the chunk's own K/V comes back, and the segment scatter
        # commits it
        self._chunk_prefill_jit: Dict[Any, Any] = {}
        # speculative decoding (attach_draft): a paired ring engine
        # drafts spec_k tokens per round in one scanned dispatch; the
        # target verifies them in one chunk dispatch. _draft_ready holds
        # target slots whose draft twin is admitted (identity pairing:
        # target slot i drafts in draft slot i)
        self._draft: Optional["InferenceEngine"] = None
        self._draft_scan = None
        self._spec_commit = None
        self._draft_ready: set = set()
        self.spec_k = 0

        # slot state (populated by init_slots)
        self.paged = False
        self._kv: Optional[PagedKVCache] = None
        self._slot_cache = None
        self._slot_free: List[int] = []
        self._slot_active: List[bool] = []
        self._slot_budget: List[Optional[int]] = []
        self._slot_generated: List[int] = []
        self._slot_pos: List[int] = []      # host mirror of cache["pos"]
        self._slot_sampling: Optional[SamplingParams] = None
        self._slot_rng = None
        self._last_tok = None
        self._step_skip = frozenset()

    # ------------------------------------------------------------------
    def bucket_len(self, need: int) -> int:
        """Cache-length bucket for ``need`` tokens: next power of two,
        floored at the engine's base cache_len (compile once per bucket)."""
        return max(self.cache_len, _pow2_at_least(need))

    def new_cache(self, batch: int, cache_len: Optional[int] = None):
        return self.api.init_cache(batch, cache_len or self.cache_len)

    def prefill(self, batch: Dict[str, Any], cache_len: Optional[int] = None):
        clen = cache_len or self.cache_len
        fn = self._prefill_jit.get(clen)
        if fn is None:
            api = self.api
            fn = jax.jit(lambda p, b, _c=clen: api.prefill(p, b, _c))
            self._prefill_jit[clen] = fn
        logits, cache = fn(self.params, batch)
        self.stats.prefills += 1
        self.stats.prefill_tokens += int(
            batch["tokens"].shape[0] * batch["tokens"].shape[1])
        return logits, cache

    def prefill_packed(self, packed: Dict[str, Any],
                       row_len: Optional[int] = None):
        """One dispatch over a packed batch of variable-length prompts.

        ``packed`` is the pytree ``_pack_prompts`` builds: ``tokens``
        (1, T) with T already bucketed to a power of two, ``seg_ids``
        (T,), ``seg_starts``/``seg_lens`` (S,) with S the pow2 bucket of
        the real segment count, plus ``enc_embeds`` for encoder models.
        Returns (per-segment last logits (S, V), packed cache). One
        executable per (T, row_len, S) triple — O(log³), and in practice
        near-additive because the three grow together.

        ``row_len`` defaults to the pow2 bucket of the batch's longest
        prompt (capped at slot_len), NOT slot_len itself: the fallback's
        per-segment row work (attention, conv, SSD) is quadratic/linear
        in row_len, and an engine with a long cache serving short
        prompts must not pay cache-sized rows per admission. The segment
        axis is bucketed for the same reason — a chunk continuation
        carrying one or two segments must not pay the full slot count's
        attention rows."""
        if row_len is None:
            row_len = min(self.slot_len, _pow2_at_least(
                int(jnp.max(packed["seg_lens"]))))
        row_len = max(1, row_len)
        key = (packed["tokens"].shape[1], row_len,
               packed["seg_starts"].shape[0])
        fn = self._packed_prefill_jit.get(key)
        if fn is None:
            api = self.api
            fn = jax.jit(lambda p, pk, _r=row_len: api.prefill_packed(
                p, pk, _r))
            self._packed_prefill_jit[key] = fn
        logits, pcache = fn(self.params, packed)
        self.stats.prefills += 1
        self.stats.packed_prefills += 1
        self.stats.prefill_tokens += int(jnp.sum(packed["seg_lens"]))
        return logits, pcache

    def prefill_chunk_packed(self, packed: Dict[str, Any],
                             row_len: Optional[int] = None):
        """One INCREMENTAL dispatch over a packed batch of continuation
        chunks: each segment's new tokens attend the K/V its slot already
        wrote into the page pool (through the slot's block-table row)
        plus the chunk itself causally — nothing before the chunk is
        recomputed. Same (T, row_len, S) bucket discipline as
        ``prefill_packed``; ``packed`` additionally carries ``seg_slots``
        (block-table rows to read) and ``hist_lens`` (tokens already
        resident per segment). Returns (per-segment last logits (S, V),
        per-token argmax (T,), packed cache) — the per-token argmax row
        is what speculative verification scores drafts against. Stats are
        charged by the callers (a continuation is prefill progress; a
        verify chunk is not)."""
        if row_len is None:
            row_len = min(self.slot_len, _pow2_at_least(
                int(jnp.max(packed["seg_lens"]))))
        row_len = max(1, row_len)
        key = (packed["tokens"].shape[1], row_len,
               packed["seg_starts"].shape[0])
        fn = self._chunk_prefill_jit.get(key)
        if fn is None:
            api = self.api
            fn = jax.jit(lambda p, pk, cache, _r=row_len: api.prefill_chunk(
                p, pk, cache, _r))
            self._chunk_prefill_jit[key] = fn
        return fn(self.params, packed, self._slot_cache)

    def decode(self, token, cache):
        logits, cache = self._decode(self.params, token, cache)
        self.stats.decode_steps += 1
        return logits, cache

    # ------------------------------------------------------------------
    def _gen_fn(self, max_new_tokens: int, greedy: bool,
                sampling: SamplingParams):
        key = (max_new_tokens, greedy, sampling)
        fn = self._gen_jit.get(key)
        if fn is None:
            api = self.api

            def pick(rng, lg):
                if greedy:
                    return rng, jnp.argmax(lg, -1).astype(jnp.int32)
                rng, sub = jax.random.split(rng)
                return rng, L.sample_logits(
                    sub, lg, temperature=sampling.temperature,
                    top_k=sampling.top_k, top_p=sampling.top_p)

            def gen(params, logits, cache, rng):
                rng, tok0 = pick(rng, logits)

                def body(carry, _):
                    tok, cache, rng = carry
                    lg, cache = api.decode_step(params, tok, cache)
                    rng, nxt = pick(rng, lg)
                    return (nxt, cache, rng), tok

                (_, cache, _), toks = jax.lax.scan(
                    body, (tok0, cache, rng), None, length=max_new_tokens)
                # cache is returned (and discarded by the caller) so the
                # donated input can alias the output — true in-place reuse
                return toks.swapaxes(0, 1), cache           # (B, T), cache

            fn = jax.jit(gen, donate_argnums=(2,) if self.donate_cache else ())
            self._gen_jit[key] = fn
        return fn

    def generate(self, batch: Dict[str, Any], max_new_tokens: int,
                 greedy: bool = True, rng: Optional[jax.Array] = None,
                 sampling: Optional[SamplingParams] = None):
        """Prefill + one fused scan over all decode steps (single dispatch).

        Returns (B, max_new_tokens). Bit-equivalent to ``generate_eager``
        under greedy decoding. Passing ``sampling`` switches the scan body
        to temperature/top-k/top-p sampling (greedy is ignored); the
        sampler runs INSIDE the fused loop, so sampled generation still
        costs one dispatch per call. The scan length is bucketed to a
        power of two (like the cache length) so a stream of varying
        generation lengths compiles O(log) executables per sampling
        config, not one per distinct length; surplus tokens discarded."""
        if sampling is not None:
            greedy = False
        sampling = sampling or SamplingParams()
        b = batch["tokens"].shape[0]
        t_bucket = max(1, _pow2_at_least(max_new_tokens))
        need = batch["tokens"].shape[1] + t_bucket
        logits, cache = self.prefill(batch, self.bucket_len(need))
        if rng is None:
            rng = jax.random.PRNGKey(0)
        toks, _ = self._gen_fn(t_bucket, greedy, sampling)(
            self.params, logits, cache, rng)
        self.stats.decode_steps += t_bucket
        self.stats.tokens_out += b * max_new_tokens
        return toks[:, :max_new_tokens]

    def generate_eager(self, batch: Dict[str, Any], max_new_tokens: int,
                       greedy: bool = True, rng: Optional[jax.Array] = None):
        """Seed-engine reference path, kept as the bench_decode baseline and
        for parity tests: one jitted dispatch per token from a Python loop,
        and an UNBUCKETED exact-length prefill that re-traces/compiles
        whenever the request needs more than the base cache_len (the seed
        constructed a fresh ``jax.jit`` per such call)."""
        b = batch["tokens"].shape[0]
        need = max(self.cache_len, batch["tokens"].shape[1] + max_new_tokens)
        if need != self.cache_len:
            api = self.api
            logits, cache = jax.jit(
                lambda p, bt: api.prefill(p, bt, need))(self.params, batch)
            self.stats.prefills += 1
        else:
            logits, cache = self.prefill(batch, self.cache_len)
        outs = []
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(max_new_tokens):
            outs.append(tok)
            logits, cache = self.decode(tok, cache)
            if greedy:
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
            else:
                rng, sub = jax.random.split(rng)
                tok = jax.random.categorical(sub, logits).astype(jnp.int32)
        self.stats.tokens_out += b * max_new_tokens
        return jnp.stack(outs, axis=1)

    # ------------------------------------------ slot continuous batching
    @property
    def n_slots(self) -> int:
        return 0 if self._slot_cache is None else len(self._slot_active)

    @property
    def free_slots(self) -> int:
        return len(self._slot_free)

    @property
    def free_pages(self) -> int:
        """Unallocated KV pages (0 when this engine has nothing to page —
        pure-SSM state is O(1), so pages never gate its admission)."""
        return self._kv.free_pages if self.paged else 0

    @property
    def total_pages(self) -> int:
        return self._kv.allocator.num_pages if self.paged else 0

    def init_slots(self, n_slots: int, cache_len: Optional[int] = None, *,
                   paged: bool = True, page_size: int = 8,
                   total_pages: Optional[int] = None,
                   sampling: Optional[SamplingParams] = None,
                   rng_seed: int = 0):
        """Allocate slot state for continuous batching.

        ``paged=True`` (default, for families with KV to page) backs the
        slots with a block-table page pool of ``total_pages`` usable pages
        (default ``n_slots * cache_len / page_size`` — same bytes as the
        rings it replaces; pass fewer pages and more slots to let mixed
        lengths share memory, which is the whole point). ``paged=False``
        keeps the original per-slot ring (the parity baseline).
        ``sampling`` fixes this engine's slot-step sampling config (None =
        greedy; each distinct config is one executable, compiled once).

        Sliding-window configs stay on ring slots even when ``paged`` is
        requested: the ring's overwrite IS the window, while a paged slot
        retains full history (pages never evict) and would silently widen
        the model's attention."""
        self.slot_len = cache_len or self.cache_len
        self.paged = (bool(paged) and bool(self.api.paged_keys)
                      and not getattr(self.cfg, "sliding_window", 0))
        # re-initializing slots invalidates any attached prefix cache
        # (page pool and page size may change) — re-enable explicitly
        self.prefix_cache = None
        self._copy_page = None
        self._alias_slot = None
        self._slot_sampling = sampling
        self._slot_rng = jax.random.PRNGKey(rng_seed)
        if self.paged:
            if self.slot_len % page_size:
                raise ValueError(
                    f"cache_len {self.slot_len} must be a multiple of "
                    f"page_size {page_size}")
            self.page_size = page_size
            self.max_pages = self.slot_len // page_size
            usable = total_pages or n_slots * self.max_pages
            self._kv = PagedKVCache(n_slots, page_size, self.max_pages,
                                    num_pages=usable)
            self._kv.allocator.fault_injector = self.fault_injector
            # +1 physical page: id 0 is the reserved null page
            self._slot_cache = self.api.init_paged_cache(
                n_slots, usable + 1, page_size, self.max_pages)
            self._write_slot_paged = jax.jit(
                _make_write_slot_paged(self.api.paged_keys, page_size),
                donate_argnums=(0,))
            self._clear_slot = jax.jit(_clear_slot, donate_argnums=(0, 1))
            self._set_table_row = jax.jit(_set_table_row, donate_argnums=(0,))
        else:
            self._kv = None
            self._slot_cache = self.api.init_cache(n_slots, self.slot_len)
            self._clear_ring = jax.jit(_clear_ring, donate_argnums=(0, 1))
        # decode/chunk dispatches merge per-row cache leaves through a step
        # mask; page-indexed leaves (and the table, which decode never
        # writes) pass through — their dead writes land on the null page
        # or at a not-yet-valid position that is overwritten before read
        self._step_skip = (frozenset(self.api.paged_keys) | {"block_tables"}
                          if self.paged else frozenset())
        self._write_segments = jax.jit(
            _make_write_segments(self.api.paged_keys), donate_argnums=(0, 1))
        self._slot_free = list(range(n_slots))
        self._slot_active = [False] * n_slots
        self._slot_budget = [None] * n_slots
        self._slot_generated = [0] * n_slots
        self._slot_pos = [0] * n_slots
        self._active_mask = jnp.zeros((n_slots,), bool)
        self._last_tok = jnp.zeros((n_slots,), jnp.int32)
        return self

    # ------------------------------------------------ admission accounting
    def _need_tokens(self, prompt_len: int, n_tokens: Optional[int]) -> int:
        """KV entries a request pins: prompt + decode budget, capped at the
        slot maximum (an unbudgeted request reserves the full slot — the
        ring-equivalent worst case)."""
        cap = self.slot_len
        if n_tokens is None:
            return cap
        return min(cap, int(prompt_len) + max(1, int(n_tokens)))

    def pages_needed(self, prompt_len: int, n_tokens: Optional[int]) -> int:
        if not self.paged:
            return 0
        return self._kv.pages_needed(self._need_tokens(prompt_len, n_tokens))

    def can_admit(self, prompt_len: int, n_tokens: Optional[int]) -> bool:
        """Admission check: a free slot AND enough free pages for the
        request's whole horizon. Pages are reserved for the full prompt +
        budget up front (not grown lazily per step) so an admitted run can
        never deadlock mid-decode on a page it cannot get. Mirrors every
        condition ``insert`` enforces — including the paged requirement
        that the prompt leave decode room — so a True here can never turn
        into an insert-time exception."""
        if not self._slot_free:
            return False
        if not self.paged:
            return True
        if prompt_len >= self.slot_len:
            return False
        return self._kv.allocator.can_alloc(
            self.pages_needed(prompt_len, n_tokens))

    def insert(self, batch: Dict[str, Any],
               n_tokens: Optional[int] = None,
               reserve_tokens: Optional[int] = None) -> int:
        """Admit one request (batch size 1) into a free slot mid-stream.

        Prefills the prompt and writes the resulting cache into the slot —
        paged: scatter into freshly allocated pages + set the slot's block
        table row; ring: write the slot's rows. ``n_tokens`` is the
        request's decode budget: ``step`` reports the slot done after that
        many tokens, and (paged) only ``prompt + n_tokens`` worth of pages
        are claimed instead of the ring's full ``cache_len``.
        ``reserve_tokens`` overrides the page horizon claimed NOW (>= the
        prompt; the lazy planner reserves just the written tokens and
        ``grow_slot``s later). Raises ``OutOfPages`` (slot untouched) when
        the pool can't cover it."""
        if not self._slot_free:
            raise RuntimeError("no free slots")
        assert batch["tokens"].shape[0] == 1, "insert admits one request"
        s = batch["tokens"].shape[1]
        slot = self._slot_free[0]          # claim only after pages are ours
        if self.paged:
            if s >= self.slot_len:
                raise ValueError(
                    f"prompt of {s} tokens leaves no decode room in a "
                    f"{self.slot_len}-token paged slot (pages are never "
                    f"evicted; use a longer cache_len)")
            # unlike the ring (which wraps, sliding-window style), a paged
            # slot cannot outgrow its table: the budget is capped at the
            # page capacity so decode can never write past the last page
            room = self.slot_len - s
            budget = room if n_tokens is None else max(
                1, min(int(n_tokens), room))
            horizon = s + budget if reserve_tokens is None else max(
                s, min(int(reserve_tokens), self.slot_len))
            self._kv.alloc(slot, horizon)
            table_row = jnp.asarray(self._kv.table_row(slot), jnp.int32)
        else:
            budget = None if n_tokens is None else max(1, int(n_tokens))
        self._slot_free.pop(0)
        logits, one = self.prefill(batch, self.slot_len)
        if self.paged:
            self._slot_cache = self._write_slot_paged(
                self._slot_cache, one, jnp.int32(slot), table_row)
        else:
            self._slot_cache = self._write_slot(self._slot_cache, one,
                                                jnp.int32(slot))
        self._last_tok = self._last_tok.at[slot].set(
            jnp.argmax(logits[0], -1).astype(jnp.int32))
        self._slot_active[slot] = True
        self._slot_budget[slot] = budget
        self._slot_generated[slot] = 0
        self._slot_pos[slot] = s
        self._active_mask = self._active_mask.at[slot].set(True)
        self.stats.inserts += 1
        return slot

    # ------------------------------------------------ packed batch insert
    def _pack_prompts(self, batches: List[Dict[str, Any]],
                      lens: List[int]) -> Dict[str, Any]:
        """Concatenate an admission batch into one packed prompt row.

        Total tokens bucket to the next power of two (same O(log) compile
        discipline as ``generate``) and the segment axis buckets to the
        next power of two of the REAL segment count (the fallback's
        attention/conv/SSD row work is linear in the padded segment
        count — a one-segment chunk continuation must not pay the whole
        slot count's rows). Padding tokens carry segment id S (matched
        by no real token) and empty segments have length 0."""
        import numpy as np
        s_max = max(1, _pow2_at_least(len(batches)))
        t = max(1, _packed_bucket(sum(lens)))
        tokens = np.zeros((1, t), np.int32)
        seg_ids = np.full((t,), s_max, np.int32)
        starts = np.zeros((s_max,), np.int32)
        seg_lens = np.zeros((s_max,), np.int32)
        off = 0
        for i, (b, ln) in enumerate(zip(batches, lens)):
            tokens[0, off:off + ln] = np.asarray(b["tokens"])[0]
            seg_ids[off:off + ln] = i
            starts[i] = off
            seg_lens[i] = ln
            off += ln
        packed = {"tokens": jnp.asarray(tokens),
                  "seg_ids": jnp.asarray(seg_ids),
                  "seg_starts": jnp.asarray(starts),
                  "seg_lens": jnp.asarray(seg_lens)}
        if self.cfg.has_encoder:
            enc = [jnp.asarray(b["enc_embeds"]) for b in batches]
            pad = jnp.zeros_like(enc[0])
            packed["enc_embeds"] = jnp.concatenate(
                enc + [pad] * (s_max - len(enc)), axis=0)
        return packed

    def insert_many(self, batches: List[Dict[str, Any]],
                    n_tokens: Optional[List[Optional[int]]] = None,
                    reserve_tokens: Optional[List[Optional[int]]] = None
                    ) -> List[int]:
        """Admit a whole admission batch in ONE prefill dispatch.

        Semantically equivalent to calling ``insert`` once per request
        (same slots claimed in free-list order, same pages, bit-identical
        greedy decode afterwards) but the data plane does two dispatches
        total instead of 2 × batch: one packed ragged prefill over the
        concatenated prompts, and one token-indexed scatter that writes
        each segment's K/V DIRECTLY into its slot's pages (per-segment
        leaves — SSM state, conv tails, cross K/V, positions — take a
        batched row write in the same executable). Page allocation is
        all-or-nothing across the batch: on ``OutOfPages`` every page
        already claimed is returned and no slot is touched.
        ``reserve_tokens[i]`` (>= prompt i's length) overrides request
        i's page horizon — the StepPlanner's lazy-reservation knob."""
        n = len(batches)
        if n == 0:
            return []
        if n > len(self._slot_free):
            raise RuntimeError(
                f"insert_many of {n} requests, {len(self._slot_free)} "
                f"free slots")
        if n_tokens is None:
            n_tokens = [None] * n
        if reserve_tokens is None:
            reserve_tokens = [None] * n
        for b in batches:
            assert b["tokens"].shape[0] == 1, \
                "insert_many packs single-request batches"
        lens = [int(b["tokens"].shape[1]) for b in batches]
        budgets: List[Optional[int]] = []
        for s, nt in zip(lens, n_tokens):
            if self.paged:
                if s >= self.slot_len:
                    raise ValueError(
                        f"prompt of {s} tokens leaves no decode room in a "
                        f"{self.slot_len}-token paged slot (pages are never "
                        f"evicted; use a longer cache_len)")
                room = self.slot_len - s
                budgets.append(room if nt is None else max(
                    1, min(int(nt), room)))
            else:
                if s > self.slot_len:
                    raise ValueError(
                        f"prompt of {s} tokens exceeds the {self.slot_len}-"
                        f"token slot (packed prefill cannot ring-wrap)")
                budgets.append(None if nt is None else max(1, int(nt)))
        slots = self._slot_free[:n]
        if self.paged:
            claimed: List[int] = []
            try:
                for slot, s, budget, rsv in zip(slots, lens, budgets,
                                                reserve_tokens):
                    horizon = s + budget if rsv is None else max(
                        s, min(int(rsv), self.slot_len))
                    self._kv.alloc(slot, horizon)
                    claimed.append(slot)
            except OutOfPages:
                for slot in claimed:
                    self._kv.free(slot)
                raise
        del self._slot_free[:n]

        packed = self._pack_prompts(batches, lens)
        logits, pcache = self.prefill_packed(
            packed, row_len=min(self.slot_len, _pow2_at_least(max(lens))))
        args = self._segment_dest(slots, lens)
        self._slot_cache, self._last_tok = self._write_segments(
            self._slot_cache, self._last_tok, pcache, logits, *args)
        for slot, s, budget in zip(slots, lens, budgets):
            self._slot_active[slot] = True
            self._slot_budget[slot] = budget
            self._slot_generated[slot] = 0
            self._slot_pos[slot] = s
        self._active_mask = self._active_mask.at[
            jnp.asarray(slots, jnp.int32)].set(True)
        self.stats.inserts += n
        return slots

    def _segment_dest(self, slots: List[int], lens: List[int]):
        """Host-side destination indices for the packed-segment scatter.

        Per-token coordinates (dest0, dest1): (physical page, in-page
        offset) when paged — computed from the pages just allocated, so
        the prefill K/V lands straight in the page pool — or (slot row,
        column) for ring slots. Padding tokens target the null page
        (paged; duplicate writes there are dead by convention) or an
        out-of-bounds column (ring; scatter drops them). Per-segment
        coordinates are the slot ids, padded with ``n_slots`` (out of
        bounds, dropped)."""
        import numpy as np
        t = max(1, _packed_bucket(sum(lens)))
        # segment axis bucketed like _pack_prompts; padding entries carry
        # slot id n_slots — out of bounds on the SLOT axis, dropped
        s_max = max(1, _pow2_at_least(len(slots)))
        seg_slots = np.full((s_max,), self.n_slots, np.int32)
        seg_slots[:len(slots)] = slots
        if self.paged:
            dest0 = np.zeros((t,), np.int32)             # null page
            dest1 = np.zeros((t,), np.int32)
            tables = np.full((s_max, self.max_pages), NULL_PAGE, np.int32)
            off = 0
            for i, (slot, ln) in enumerate(zip(slots, lens)):
                pages = np.asarray(self._kv.pages(slot), np.int32)
                p = np.arange(ln)
                dest0[off:off + ln] = pages[p // self.page_size]
                dest1[off:off + ln] = p % self.page_size
                tables[i, :len(pages)] = pages
                off += ln
            table_rows = jnp.asarray(tables)
        else:
            dest0 = np.zeros((t,), np.int32)
            dest1 = np.full((t,), self.slot_len, np.int32)   # OOB: dropped
            off = 0
            for slot, ln in zip(slots, lens):
                dest0[off:off + ln] = slot
                dest1[off:off + ln] = np.arange(ln)
                off += ln
            table_rows = None
        return (jnp.asarray(dest0), jnp.asarray(dest1),
                jnp.asarray(seg_slots), table_rows)

    def _pack_chunks(self, batches: List[Dict[str, Any]], lens: List[int],
                     slots: List[int], hists: List[int]) -> Dict[str, Any]:
        """Pack continuation chunks for the incremental prefill: the
        regular packed-prompt row plus ``seg_slots`` (whose block-table
        row each segment reads its history through; padding carries
        ``n_slots``, clamped inside the model where its zero-length
        segment attends nothing) and ``hist_lens`` (tokens already
        resident; padding 0)."""
        import numpy as np
        packed = self._pack_prompts(batches, lens)
        s_max = packed["seg_starts"].shape[0]
        seg_slots = np.full((s_max,), self.n_slots, np.int32)
        seg_slots[:len(slots)] = slots
        hist = np.zeros((s_max,), np.int32)
        hist[:len(hists)] = hists
        packed["seg_slots"] = jnp.asarray(seg_slots)
        packed["hist_lens"] = jnp.asarray(hist)
        return packed

    def _segment_dest_at(self, slots: List[int], lens: List[int],
                         offs: List[int]):
        """``_segment_dest`` for continuation chunks: segment i's tokens
        land at positions ``offs[i] .. offs[i]+lens[i]`` of its slot
        (paged only — the incremental path requires resident pages).
        Table rows are the slot's CURRENT pages: the chunk's destination
        pages were reserved before the dispatch (admission horizon or an
        executed grow)."""
        import numpy as np
        assert self.paged
        t = max(1, _packed_bucket(sum(lens)))
        s_max = max(1, _pow2_at_least(len(slots)))
        seg_slots = np.full((s_max,), self.n_slots, np.int32)
        seg_slots[:len(slots)] = slots
        dest0 = np.zeros((t,), np.int32)             # null page
        dest1 = np.zeros((t,), np.int32)
        tables = np.full((s_max, self.max_pages), NULL_PAGE, np.int32)
        off = 0
        for i, (slot, ln, h) in enumerate(zip(slots, lens, offs)):
            pages = np.asarray(self._kv.pages(slot), np.int32)
            p = np.arange(h, h + ln)
            dest0[off:off + ln] = pages[p // self.page_size]
            dest1[off:off + ln] = p % self.page_size
            tables[i, :len(pages)] = pages
            off += ln
        return (jnp.asarray(dest0), jnp.asarray(dest1),
                jnp.asarray(seg_slots), jnp.asarray(tables))

    def free(self, slot: int) -> None:
        """Release a slot: its pages return to the pool, its block-table
        row parks on the null page, and its position pins to 0 (here and
        after every subsequent step) so vacant rows' dead writes land in
        the null page and their attention reads are masked to zero."""
        if not self._slot_active[slot]:
            return
        if slot in self._draft_ready:
            # the draft twin dies with its target
            self._draft.free(slot)
            self._draft_ready.discard(slot)
        self._slot_active[slot] = False
        self._slot_free.append(slot)
        self._slot_pos[slot] = 0
        if self.paged:
            self._kv.free(slot)
            self._slot_cache, self._active_mask = self._clear_slot(
                self._slot_cache, self._active_mask, jnp.int32(slot))
        else:
            cache = dict(self._slot_cache)
            cache["pos"], self._active_mask = self._clear_ring(
                cache["pos"], self._active_mask, jnp.int32(slot))
            self._slot_cache = cache

    # ------------------------------------------- radix prompt cache
    def prefix_cache_capable(self) -> bool:
        """A family can prefix-share iff pages + ``pos`` are a row's
        ENTIRE sequence state — i.e. the paged slot cache carries exactly
        the paged K/V leaves plus ``block_tables``/``pos``. Families with
        extra per-row leaves (SSM state, conv tails, cross K/V) fold the
        whole prefix into non-shareable state, so aliasing pages would
        not skip their prefill."""
        if not self.paged or self._slot_cache is None:
            return False
        extra = (set(self._slot_cache.keys())
                 - set(self.api.paged_keys) - {"block_tables", "pos"})
        return not extra

    # --------------------------------- incremental chunk / speculation
    def chunk_capable(self) -> bool:
        """A family takes the incremental continuation path iff its paged
        pages + ``pos`` are a row's entire sequence state (same criterion
        as the prefix cache — extra per-row leaves mean the prefix must
        be recomputed to carry the state forward), the family ships a
        ``prefill_chunk``, and it has no experts (the MoE packed-prefill
        caveat: per-token routing under segment masking is not yet
        bit-stable across packings — see tests/test_moe.py)."""
        if not self.prefix_cache_capable():
            return False
        if self.api.prefill_chunk is None:
            return False
        return not getattr(self.cfg, "num_experts", 0)

    def spec_capable(self) -> bool:
        """Speculative decoding additionally requires greedy slot
        sampling: draft/verify equivalence is an arg-max identity."""
        return self.chunk_capable() and self._slot_sampling is None

    def host_last_token(self, slot: int) -> int:
        """Host read of the slot's pending token (the next decode input,
        not yet emitted). The planner captures it once per request as the
        speculation seed; a per-slot sync, so gated on spec serving."""
        import numpy as np
        return int(np.asarray(self._last_tok[slot]))

    def draft_synced(self, slot: int) -> bool:
        """True when the slot's draft twin exists and sits at the same
        written-token position — the next spec round needs no re-init."""
        return (self._draft is not None and slot in self._draft_ready
                and self._draft._slot_pos[slot] == self._slot_pos[slot])

    def enable_prefix_cache(self):
        """Attach a radix prompt cache over this engine's page allocator
        and build the two hit-admission executables (COW page copy,
        combined table-row + position write). Raises for incapable
        families — callers that want best-effort use
        ``prefix_cache_capable`` first."""
        if not self.prefix_cache_capable():
            raise ValueError(
                f"{self.cfg.name}: prefix cache needs a paged engine whose "
                "per-row state is exactly pages + pos (families with SSM "
                "state / conv tails / cross K/V cannot alias their prefix)")
        from repro.serving.prefix_cache import PrefixCache
        self.prefix_cache = PrefixCache(self._kv.allocator, self.page_size)
        # recovery keeps radix nodes touched within this many cache
        # operations of the fault (``PrefixCache.retain_recent``) — the
        # hot working set survives an engine reset instead of flushing
        self.prefix_hot_window = 64
        if self._copy_page is None:
            self._copy_page = jax.jit(_make_copy_page(self.api.paged_keys),
                                      donate_argnums=(0,))
            self._alias_slot = jax.jit(_alias_slot, donate_argnums=(0,))
        return self.prefix_cache

    def warm_prefix_ops(self) -> None:
        """Compile the hit-admission executables up front (the pool/bench
        0-recompile discipline): the COW copy warms null-page → null-page
        (dead by convention), the alias write warms against a vacant
        slot's existing parked state (null table row, position 0) so
        warming is a no-op on serving state."""
        if self.prefix_cache is None:
            return
        self._slot_cache = self._copy_page(
            self._slot_cache, jnp.int32(NULL_PAGE), jnp.int32(NULL_PAGE))
        if self._slot_free:
            slot = self._slot_free[0]
            null_row = jnp.full((self.max_pages,), NULL_PAGE, jnp.int32)
            self._slot_cache = self._alias_slot(
                self._slot_cache, jnp.int32(slot), null_row, jnp.int32(0))
            # registration-time dedup pushes repointed block-table rows
            # through set_table_row; warm it the same no-op way (a
            # vacant slot's row is already the null row) so a first
            # dedup after a jit-freeze snapshot cannot compile
            self._slot_cache = self._set_table_row(
                self._slot_cache, jnp.int32(slot), null_row)

    def slot_pages(self, slot: int) -> List[int]:
        """Physical pages backing a slot, in logical order (the prefix
        cache registers a finished prefill's leading pages)."""
        return self._kv.pages(slot) if self.paged else []

    def alias_admit(self, batch: Dict[str, Any], hit,
                    n_tokens: Optional[int] = None,
                    reserve_tokens: Optional[int] = None) -> int:
        """Admit one request whose prompt prefix is a cache hit — ZERO
        prefill dispatches for the covered tokens.

        ``hit`` is a pinned ``PrefixHit`` from ``prefix_cache.match``:
        its fully-covered pages alias read-only into the new slot's block
        table (the row adopts the match-time pins), a partial-page match
        is copied into the row's first fresh page (one static-shape COW
        dispatch; the pin on the source releases after the copy), and the
        remaining horizon allocates fresh pages all-or-nothing. The slot
        starts at ``pos = covered`` with ``last_tok`` = the first
        uncovered prompt token, so teacher-forced catch-up steps (the
        planner's ``StepPlan.forced``, or ``catchup_prefill``) replay the
        prompt tail through the regular decode dispatch — each step
        writes K/V at exactly the position whole-prompt prefill would
        have, and the final forced step leaves ``last_tok`` = argmax over
        the full prompt, exactly what ``insert`` seeds. Hit admissions
        are therefore bit-exact with cache-off admission by construction.

        Raises ``OutOfPages`` with nothing changed (the caller keeps the
        hit's pins and must ``release_hit`` it)."""
        if not self._slot_free:
            raise RuntimeError("no free slots")
        assert self.prefix_cache is not None, "enable_prefix_cache first"
        assert batch["tokens"].shape[0] == 1, "alias_admit admits one request"
        s = int(batch["tokens"].shape[1])
        covered = int(hit.covered)
        assert 0 < covered < s, \
            f"hit covers {covered} of a {s}-token prompt"
        if s >= self.slot_len:
            raise ValueError(
                f"prompt of {s} tokens leaves no decode room in a "
                f"{self.slot_len}-token paged slot (pages are never "
                f"evicted; use a longer cache_len)")
        room = self.slot_len - s
        budget = room if n_tokens is None else max(1, min(int(n_tokens),
                                                          room))
        horizon = s + budget if reserve_tokens is None else max(
            covered + 1, min(int(reserve_tokens), self.slot_len))
        slot = self._slot_free[0]          # claim only after pages are ours
        fresh = self._kv.alloc_alias(slot, hit.pages, horizon)
        self._slot_free.pop(0)
        if hit.cow_src is not None:
            # the partially-matched page copies into the row's first page
            # past the aliased prefix; the divergent suffix inside it is
            # stale but never read (attention masks by pos) and is
            # overwritten in order by the forced catch-up writes
            self._slot_cache = self._copy_page(
                self._slot_cache, jnp.int32(hit.cow_src),
                jnp.int32(fresh[0]))
            self._kv.allocator.release([hit.cow_src])
            self.stats.cow_copies += 1
        row = jnp.asarray(self._kv.table_row(slot), jnp.int32)
        self._slot_cache = self._alias_slot(
            self._slot_cache, jnp.int32(slot), row, jnp.int32(covered))
        import numpy as np
        toks = np.asarray(batch["tokens"])[0]
        self._last_tok = self._last_tok.at[slot].set(
            jnp.int32(int(toks[covered])))
        self._slot_active[slot] = True
        self._slot_budget[slot] = budget
        self._slot_generated[slot] = 0
        self._slot_pos[slot] = covered
        self._active_mask = self._active_mask.at[slot].set(True)
        self.stats.inserts += 1
        self.stats.prefix_hits += 1
        self.stats.prefix_hit_tokens += covered
        if self.telemetry is not None:
            self.telemetry.instant(
                self.telemetry.engine_track(self), "prefix_hit",
                slot=slot, covered=covered,
                cow=int(hit.cow_src is not None))
        return slot

    def catchup_prefill(self, slot: int, tokens, covered: int) -> None:
        """Teacher-forced completion of an aliased prompt, one decode
        dispatch per remaining token (the pool plane's eager form; the
        tick plane spreads the same steps across ticks via
        ``StepPlan.forced``). After the loop the slot sits exactly where
        a whole-prompt insert would: ``pos = len(tokens)``, ``last_tok``
        = argmax over the full prompt."""
        for i in range(int(covered), len(tokens)):
            self._last_tok = self._last_tok.at[slot].set(
                jnp.int32(int(tokens[i])))
            self.step([slot], forced={slot})

    def dedup_slot_prefix(self, slot: int, tokens, n_full: int) -> int:
        """Cross-request prefix dedup at registration time (ISSUE 10).

        When two same-prefix prompts prefill CONCURRENTLY, both miss at
        admission (the cache cannot serve what is not yet registered)
        and both fill their own pages with bit-identical K/V for the
        shared prefix (the PR-4 packed-prefill parity guarantee: a
        token's K/V depends only on the tokens before it). The first to
        finish registers its pages as the canonical ones; this call —
        made right after the SECOND registers — compares the slot's
        leading ``n_full`` pages against the tree's canonical walk and
        repoints every differing entry at the canonical page, releasing
        the row's duplicate (refcount 1 → actually freed). One
        pre-compiled ``set_table_row`` dispatch pushes the updated row;
        values never change (identical content), so streams are
        untouched. Safe because every later write on a registered row
        lands at ``pos >= prompt_len``, past the repointed prefix.
        Returns duplicate pages actually freed."""
        if not self.paged or self.prefix_cache is None or n_full < 1:
            return 0
        ps = self.page_size
        canonical = self.prefix_cache.canonical_pages(
            list(tokens)[:n_full * ps])
        own = self._kv.pages(slot)
        swaps = [(i, c) for i, (o, c)
                 in enumerate(zip(own[:n_full], canonical)) if o != c]
        if not swaps:
            return 0
        freed = self._kv.repoint(slot, swaps)
        row = jnp.asarray(self._kv.table_row(slot), jnp.int32)
        self._slot_cache = self._set_table_row(
            self._slot_cache, jnp.int32(slot), row)
        self.stats.dedup_pages += freed
        if self.telemetry is not None:
            self.telemetry.instant(
                self.telemetry.engine_track(self), "prefix_dedup",
                slot=slot, pages=freed)
        return freed

    # -------------------------------------------- lazy page reservation
    def slot_pos(self, slot: int) -> int:
        """Tokens written to the slot so far (host mirror of pos)."""
        return self._slot_pos[slot]

    def reserved_tokens(self, slot: int) -> int:
        """Token horizon the slot's pages currently cover (slot_len for
        ring/dense slots — they are fully backed by construction)."""
        if not self.paged:
            return self.slot_len
        return self._kv.length(slot)

    def slot_page_count(self, slot: int) -> int:
        return len(self._kv.pages(slot)) if self.paged else 0

    def kv_pages_needed(self, tokens: int) -> int:
        """Pages required to hold ``tokens`` KV entries (0 when unpaged)
        — the planner-facing page arithmetic of the PageView protocol."""
        return self._kv.pages_needed(max(1, int(tokens))) if self.paged \
            else 0

    def grow_slot(self, slot: int, upto_tokens: int) -> int:
        """Extend a resident slot's page horizon to cover ``upto_tokens``
        (lazy reservation: admission claimed only the written prefix).
        Newly crossed page boundaries allocate pages and push the updated
        block-table row to the device — one small pre-compiled dispatch,
        only when pages were actually added. Raises ``OutOfPages`` with
        the slot untouched (the planner's preemption signal). Returns the
        number of pages added."""
        if not self.paged:
            return 0
        have = self._kv.length(slot)
        delta = min(int(upto_tokens), self.slot_len) - have
        if delta <= 0:
            return 0
        fresh = self._kv.append(slot, delta)
        if fresh:
            row = jnp.asarray(self._kv.table_row(slot), jnp.int32)
            self._slot_cache = self._set_table_row(
                self._slot_cache, jnp.int32(slot), row)
            self.stats.grows += 1
        return len(fresh)

    def ensure_decode_room(self, slots) -> None:
        """Grow every slot to cover its next decode write (lazy pools call
        this before stepping; raises ``OutOfPages`` naming nothing —
        callers preempt a victim and retry)."""
        for slot in slots:
            self.grow_slot(slot, self._slot_pos[slot] + 1)

    # ------------------------------------------------- chunked prefill
    def chunk_append(self, chunks: List[Tuple[int, Dict[str, Any], bool]]
                     ) -> None:
        """Advance every mid-prefill slot by one chunk in ONE packed
        prefill dispatch (prefix recompute).

        ``chunks`` is [(slot, prefix pytree (1, done+chunk), final)] —
        each entry carries the request's FULL prompt prefix up to the end
        of this tick's chunk. The prefixes pack into one segmented row
        and run through the SAME ``prefill_packed`` executables
        admissions use (same pow2 token buckets — chunk continuation
        compiles nothing of its own), and ``_write_segments`` scatters
        every segment straight onto its slot: already-written prefix
        positions are REWRITTEN with bit-identical values (a token's K/V
        never depends on later tokens, and the packed fallback's exact-
        zero padding makes row-bucket size invisible — the PR-4 parity
        guarantee), the new chunk's tokens land on their pages for the
        first time, and the per-segment leaves (position, SSM state,
        conv tail, cross K/V) carry the partial segment forward as the
        recomputed post-prefix state. ``final`` segments leave
        ``last_tok`` = argmax of the full prompt's last logits — exactly
        what a one-shot insert seeds — so chunked prefill is bit-exact
        with whole-prompt admission by construction. The recompute costs
        O(prefix) extra FLOPs per chunk (the classic chunked-prefill
        trade: bounded per-tick work, decode never stalls on a burst).

        ``chunk_capable`` engines skip the recompute entirely
        (``stats.incr_chunks``): only the NEW tokens pack, and the
        incremental chunk attention kernel scores them against the K/V
        already resident in the slot's pages — O(chunk) per continuation
        instead of O(prefix + chunk). Each new position runs the same
        masked-decode attention body a decode step would, so the
        continuation stays exact with the whole-prompt admission it
        replaces."""
        if not chunks:
            return
        lens = []
        for slot, b, _ in chunks:
            ln = int(b["tokens"].shape[1])
            assert self._slot_active[slot], f"chunk into vacant slot {slot}"
            assert ln <= self.reserved_tokens(slot), \
                f"slot {slot}: chunk outruns its reserved pages"
            assert ln > self._slot_pos[slot], \
                f"slot {slot}: chunk makes no progress"
            lens.append(ln)
        slots = [slot for slot, _, _ in chunks]
        if self.chunk_capable():
            import numpy as np
            offs = [self._slot_pos[slot] for slot in slots]
            new_lens = [ln - off for ln, off in zip(lens, offs)]
            news = [{"tokens": jnp.asarray(
                np.asarray(b["tokens"])[:, off:ln])}
                for (_, b, _), off, ln in zip(chunks, offs, lens)]
            packed = self._pack_chunks(news, new_lens, slots, offs)
            seg_logits, _, pcache = self.prefill_chunk_packed(
                packed, row_len=min(self.slot_len,
                                    _pow2_at_least(max(new_lens))))
            args = self._segment_dest_at(slots, new_lens, offs)
            self._slot_cache, self._last_tok = self._write_segments(
                self._slot_cache, self._last_tok, pcache, seg_logits, *args)
            for slot, ln in zip(slots, lens):
                self._slot_pos[slot] = ln
            self.stats.prefills += 1
            self.stats.packed_prefills += 1
            self.stats.chunk_prefills += 1
            self.stats.incr_chunks += 1
            self.stats.prefill_tokens += sum(new_lens)
            return
        packed = self._pack_prompts([b for _, b, _ in chunks], lens)
        logits, pcache = self.prefill_packed(
            packed, row_len=min(self.slot_len, _pow2_at_least(max(lens))))
        args = self._segment_dest(slots, lens)
        self._slot_cache, self._last_tok = self._write_segments(
            self._slot_cache, self._last_tok, pcache, logits, *args)
        for slot, ln in zip(slots, lens):
            self._slot_pos[slot] = ln
        self.stats.chunk_prefills += 1

    # ------------------------------------------- speculative decoding
    def attach_draft(self, draft: "InferenceEngine", spec_k: int
                     ) -> "InferenceEngine":
        """Pair a small ring-slot draft engine with this (paged, greedy)
        target for speculative decoding: per spec round the draft
        proposes up to ``spec_k`` tokens in ONE scanned dispatch and the
        target verifies them all in ONE incremental chunk dispatch.

        Identity pairing — target slot i drafts in draft slot i — so the
        draft needs at least as many slots, each long enough to mirror a
        full target slot (ring wrap would corrupt the mirrored history).
        The ring never pages, so drafting can neither OutOfPages nor
        perturb the target's pool. Vocabularies must agree: accepted
        draft tokens feed the target's embedding directly."""
        if int(spec_k) < 1:
            raise ValueError("spec_k must be >= 1")
        if not self.spec_capable():
            raise ValueError(
                f"{self.cfg.name}: speculative decoding needs a paged "
                "greedy engine whose per-row state is exactly pages + pos "
                "and whose family ships prefill_chunk")
        if draft.paged:
            raise ValueError("draft must use ring slots (paged=False)")
        if draft.n_slots < self.n_slots or draft.slot_len < self.slot_len:
            raise ValueError(
                f"draft needs >= {self.n_slots} slots of >= "
                f"{self.slot_len} tokens (has {draft.n_slots} x "
                f"{getattr(draft, 'slot_len', 0)})")
        if draft.cfg.vocab_size != self.cfg.vocab_size:
            raise ValueError(
                f"draft/target vocabularies differ "
                f"({draft.cfg.vocab_size} vs {self.cfg.vocab_size})")
        self._draft = draft
        self.spec_k = int(spec_k)
        self._draft_ready = set()
        api, skip = draft.api, draft._step_skip

        # the whole draft round is one scanned dispatch: spec_k + 1
        # masked greedy decode-steps (the same body `step` runs), each
        # step's per-slot mask an input. Step i writes the previous
        # token's K/V and proposes the next; the final step exists only
        # to write the last proposal's K/V (its own proposal is
        # discarded) so an all-accepted round leaves the draft exactly
        # one teacher-forced bonus token behind the target.
        # the scan also assembles the verify-chunk token row ON DEVICE:
        # position 0 of each segment is the target's pending token,
        # positions 1..k its draft proposals. The host builds only the
        # (static) index vectors, so the verify dispatch queues
        # back-to-back behind the draft scan with no host sync — and no
        # separate gather dispatch — between them.
        def scan_fn(params, tok, cache, masks, tok_t, idx_t, idx_d,
                    step_idx, slot_idx):
            # teacher-force inside the dispatch: pin each paired row's
            # pending token to the target's — the host never reads
            # either engine's _last_tok to start a round
            tok = tok.at[idx_d].set(tok_t[idx_t], mode="drop")

            def body(carry, mask_t):
                tok, cache = carry
                tok, cache = _slot_decode_step(api, skip, params, tok,
                                               cache, mask_t)
                return (tok, cache), tok

            (tok, cache), props = jax.lax.scan(body, (tok, cache), masks)
            seed = tok_t[jnp.clip(slot_idx, 0, tok_t.shape[0] - 1)]
            drafted = props[jnp.maximum(step_idx - 1, 0), slot_idx]
            verify = jnp.where(step_idx == 0, seed, drafted)[None, :]
            return props, verify, tok, cache

        self._draft_scan = jax.jit(scan_fn, donate_argnums=(2,))

        # end-of-round commit fused into ONE dispatch: the packed-segment
        # scatter plus the fixups (pin both engines' pending tokens to
        # the bonus, rewind the draft ring to the accepted horizon) —
        # separately they cost three extra dispatch overheads per round.
        # All round-variable integers ride as ONE flat upload, sliced by
        # the (static) arg shapes: [bonus | draft pos | accepted pos |
        # dest pages/offsets].
        ws = _make_write_segments(self.api.paged_keys)

        def commit_fn(slot_cache, last_tok, lt_d, pos_d, pcache,
                      seg_logits, aux, segs, tables, idx_t, idx_d):
            n, s = idx_t.shape[0], segs.shape[0]
            gv, dp = aux[:n], aux[n:2 * n]
            new_pos = aux[2 * n:2 * n + s]
            dest = aux[2 * n + s:].reshape(2, -1)
            pcache = dict(pcache)
            pcache["pos"] = new_pos
            slot_cache, last_tok = ws(slot_cache, last_tok, pcache,
                                      seg_logits, dest[0], dest[1], segs,
                                      tables)
            return (slot_cache,
                    last_tok.at[idx_t].set(gv, mode="drop"),
                    lt_d.at[idx_d].set(gv, mode="drop"),
                    pos_d.at[idx_d].set(dp, mode="drop"))

        self._spec_commit = jax.jit(commit_fn, donate_argnums=(0, 1, 2, 3))
        self._spec_consts = {}
        self._spec_dest = {}
        return draft

    def _spec_round(self, entries: List[Tuple[int, int, Optional[List[int]]]],
                    res) -> None:
        """One draft → verify → accept/rollback round for the plan's
        ``spec`` entries [(slot, k, init_tokens-or-None)].

        Protocol (greedy): the target's pending token t sits at position
        P = ``_slot_pos[slot]`` with its K/V unwritten. The draft —
        teacher-forced to the same history — proposes d_1..d_k; the
        verify chunk runs [t, d_1..d_k] through the incremental prefill,
        whose per-token argmax row IS the sequence of tokens greedy
        decode would have emitted one step at a time. The longest prefix
        a of agreeing drafts is accepted, and position P+a's argmax is
        the bonus token — a+1 tokens emitted per round (so a round is
        never slower than the decode step it replaced). Commit rides the
        existing segment scatter with the packed ``pos`` overridden to
        the ACCEPTED horizon P+a+1: rejected positions' K/V land in the
        slot's reserved pages but sit past pos, never attended, and are
        rewritten in order before they ever matter — rollback costs zero
        dispatches and conserves pages. The draft rolls back the same
        way (ring pos rewind) and both ends hold the bonus token as
        their pending input, keeping the pair in lockstep for the next
        round."""
        import numpy as np
        tel = self.telemetry
        draft = self._draft
        slots = [s for s, _, _ in entries]
        offs = [self._slot_pos[s] for s in slots]

        # (re)admit draft twins that are missing or out of lockstep (the
        # slot decoded plainly while speculation was gated off): one
        # packed prefill on the DRAFT engine re-mirrors the history
        admit = []
        for (slot, _, init), off in zip(entries, offs):
            if self.draft_synced(slot):
                continue
            if slot in self._draft_ready:
                draft.free(slot)
                self._draft_ready.discard(slot)
            assert init is not None and len(init) == off, \
                f"slot {slot}: draft init missing or mismatched"
            admit.append((slot, init))
        if admit:
            order = [s for s, _ in admit]
            chosen = set(order)
            draft._slot_free = order + [s for s in draft._slot_free
                                        if s not in chosen]
            t0 = tel.t0() if tel is not None else 0.0
            got = draft.insert_many(
                [{"tokens": jnp.asarray(np.asarray(toks, np.int32)[None, :])}
                 for _, toks in admit],
                n_tokens=[None] * len(admit))
            assert got == order, "draft twin landed on the wrong slot"
            self._draft_ready.update(order)
            res.dispatches += 1
            if tel is not None:
                tel.dispatch_done(draft, "spec_admit", len(admit), t0,
                                  sync=draft._slot_cache, segs=len(admit))

        # round constants: every index vector, scan mask, and segment-
        # layout array depends only on (slots, ks) — identical for every
        # steady-state round — so each combination's host numpy and
        # device arrays build ONCE and replay. A spec round's HOST cost
        # is what bounds the speedup over plain per-token decode
        # (bench_decode --speculative measures exactly this), so the
        # per-round work must be O(changed state), not O(layout).
        ckey = (tuple(slots), tuple(k for _, k, _ in entries))
        consts = self._spec_consts.get(ckey)
        if consts is None:
            # index vectors pad to each engine's OWN slot count (out of
            # bounds, mode="drop"); the draft gets its own padding — it
            # may have more slots, so the target's n_slots could be a
            # live row there
            idx = np.full((self.n_slots,), self.n_slots, np.int32)
            idx[:len(slots)] = slots
            idxd = np.full((self.n_slots,), draft.n_slots, np.int32)
            idxd[:len(slots)] = slots
            n_steps = self.spec_k + 1
            m = np.zeros((n_steps, draft.n_slots), bool)
            for slot, k, _ in entries:
                m[:k + 1, slot] = True
            vlens = [k + 1 for _, k, _ in entries]
            t = max(1, _packed_bucket(sum(vlens)))
            s_max = max(1, _pow2_at_least(len(slots)))
            seg_ids = np.full((t,), s_max, np.int32)
            seg_starts = np.zeros((s_max,), np.int32)
            seg_lens = np.zeros((s_max,), np.int32)
            seg_slots = np.full((s_max,), self.n_slots, np.int32)
            seg_slots[:len(slots)] = slots
            step_np = np.zeros((t,), np.int32)
            slot_np = np.zeros((t,), np.int32)
            starts = []
            off = 0
            for j, (slot, k, _) in enumerate(entries):
                ln = k + 1
                seg_ids[off:off + ln] = j
                seg_starts[j] = off
                seg_lens[j] = ln
                step_np[off:off + ln] = np.arange(ln)
                slot_np[off:off + ln] = slot
                starts.append(off)
                off += ln
            consts = {
                "idx_j": jnp.asarray(idx), "idx_d": jnp.asarray(idxd),
                "mask": jnp.asarray(m), "n_steps": n_steps,
                "vlens": vlens, "t": t, "s_max": s_max, "starts": starts,
                "row_len": min(self.slot_len, _pow2_at_least(max(vlens))),
                "seg_ids": jnp.asarray(seg_ids),
                "seg_starts": jnp.asarray(seg_starts),
                "seg_lens": jnp.asarray(seg_lens),
                "seg_slots": jnp.asarray(seg_slots),
                "step_idx": jnp.asarray(step_np),
                "slot_idx": jnp.asarray(slot_np),
            }
            self._spec_consts[ckey] = consts
        idx_j, idx_d = consts["idx_j"], consts["idx_d"]
        vlens, starts = consts["vlens"], consts["starts"]
        t, s_max = consts["t"], consts["s_max"]

        # ---- draft: k+1 masked steps (teacher-forcing fused into the
        # scan prologue), one dispatch, nothing read back yet
        t0 = tel.t0() if tel is not None else 0.0
        props, verify_tok, dtok, dcache = self._draft_scan(
            draft.params, draft._last_tok, draft._slot_cache,
            consts["mask"], self._last_tok, idx_j, idx_d,
            consts["step_idx"], consts["slot_idx"])
        draft._last_tok = dtok
        draft._slot_cache = dcache
        res.dispatches += 1
        if tel is not None:
            tel.dispatch_done(draft, "spec_draft", consts["n_steps"], t0,
                              sync=props, slots=len(slots))

        # ---- verify: [t, d_1..d_k] per slot, one incremental chunk.
        # The token row is gathered from the draft's proposals ON
        # DEVICE, so the verify queues behind the scan without a host
        # sync and the two dispatches pipeline; only hist_lens (the
        # per-slot accepted horizon) uploads fresh each round
        hist = np.zeros((s_max,), np.int32)
        hist[:len(offs)] = offs
        packed = {
            "tokens": verify_tok,
            "seg_ids": consts["seg_ids"],
            "seg_starts": consts["seg_starts"],
            "seg_lens": consts["seg_lens"],
            "seg_slots": consts["seg_slots"],
            "hist_lens": jnp.asarray(hist),
        }
        t0 = tel.t0() if tel is not None else 0.0
        seg_logits, tok_argmax, pcache = self.prefill_chunk_packed(
            packed, row_len=consts["row_len"])
        res.dispatches += 1
        if tel is not None:
            tel.dispatch_done(self, "spec_verify",
                              _packed_bucket(sum(vlens)), t0,
                              sync=(seg_logits, pcache),
                              segs=len(slots), tokens=sum(vlens))

        # ---- accept / commit / rollback: the round's ONLY host reads —
        # both dispatches are already in flight when these block
        props_h = np.asarray(props).T.tolist()   # per-slot proposal lists
        amax = np.asarray(tok_argmax).tolist()
        new_pos = np.zeros((s_max,), np.int32)
        gvals = np.zeros((self.n_slots,), np.int32)
        dpos = np.zeros((self.n_slots,), np.int32)
        emitted_total = accepted_total = drafted_total = n_roll = 0
        for j, (slot, k, _) in enumerate(entries):
            st = starts[j]
            pl = props_h[slot]
            a = 0
            while a < k and pl[a] == amax[st + a]:
                a += 1
            g = amax[st + a]                         # bonus token
            res.spec_tokens[slot] = pl[:a] + [g]
            new_pos[j] = offs[j] + a + 1
            gvals[j] = g
            dpos[j] = offs[j] + a + 1
            self._slot_pos[slot] = offs[j] + a + 1
            self._slot_generated[slot] += a + 1
            draft._slot_pos[slot] = offs[j] + a + 1
            emitted_total += a + 1
            accepted_total += a
            drafted_total += k
            if a < k:
                n_roll += 1
        # commit through the segment scatter with pos pinned to the
        # accepted horizon (rejected K/V sits past pos, never attended),
        # fused with the fixups — pending tokens pinned to the BONUS
        # (the scatter seeds argmax after P+k, not P+a), draft ring
        # rolled back to lockstep — in ONE dispatch. A resident slot's
        # pages are stable, so its table row uploads once per (slots,
        # pages) set; only the per-token dest coords (which track the
        # accepted horizon) re-upload each round.
        dkey = (ckey[0], self._kv.version)
        cached = self._spec_dest.get(dkey)
        if cached is None:
            if len(self._spec_dest) > 64:
                self._spec_dest.clear()
            pages_h = [np.asarray(self._kv.pages(s), np.int32)
                       for s in slots]
            tb = np.full((s_max, self.max_pages), NULL_PAGE, np.int32)
            for i, p in enumerate(pages_h):
                tb[i, :len(p)] = p
            cached = (pages_h, jnp.asarray(tb))
            self._spec_dest[dkey] = cached
        pages_h, tables = cached
        n = self.n_slots
        aux = np.zeros((2 * n + s_max + 2 * t,), np.int32)
        aux[:n], aux[n:2 * n] = gvals, dpos
        aux[2 * n:2 * n + s_max] = new_pos
        dest = aux[2 * n + s_max:].reshape(2, t)
        for i, (p, ln, h) in enumerate(zip(pages_h, vlens, offs)):
            span = np.arange(h, h + ln)
            dest[0, starts[i]:starts[i] + ln] = p[span // self.page_size]
            dest[1, starts[i]:starts[i] + ln] = span % self.page_size
        dc = dict(draft._slot_cache)
        (self._slot_cache, self._last_tok, draft._last_tok,
         dc["pos"]) = self._spec_commit(
            self._slot_cache, self._last_tok, draft._last_tok, dc["pos"],
            pcache, seg_logits, jnp.asarray(aux),
            consts["seg_slots"], tables, idx_j, idx_d)
        draft._slot_cache = dc

        self.stats.spec_rounds += 1
        self.stats.draft_tokens += drafted_total
        self.stats.accepted_tokens += accepted_total
        self.stats.rollbacks += n_roll
        self.stats.tokens_out += emitted_total
        for slot, active in enumerate(self._slot_active):
            if active:
                budget = self._slot_budget[slot]
                if (budget is not None
                        and self._slot_generated[slot] >= budget
                        and slot not in res.done):
                    res.done.append(slot)
        if tel is not None:
            tel.instant(tel.engine_track(self), "spec_round",
                        slots=len(slots), drafted=drafted_total,
                        accepted=accepted_total, rollbacks=n_roll)

    # ---------------------------------------------------- fault tolerance
    def attach_faults(self, injector, max_retries: Optional[int] = None,
                      backoff_s: Optional[float] = None) -> None:
        """Arm a ``FaultInjector`` at this engine's two fault sites: the
        dispatch site of ``execute`` and the page allocator (injected
        ``OutOfPages`` rides the existing all-or-nothing rollback paths).
        Attach AFTER warmup so the fault schedule is independent of
        compilation order. Pass ``injector=None`` to disarm."""
        self.fault_injector = injector
        if max_retries is not None:
            self.retry_limit = int(max_retries)
        if backoff_s is not None:
            self.retry_backoff_s = float(backoff_s)
        if self._kv is not None:
            self._kv.allocator.fault_injector = injector

    # ------------------------------------------------------- telemetry
    def attach_telemetry(self, tel) -> None:
        """Arm (or with None, disarm) the serving telemetry plane
        (``repro.serving.telemetry.Telemetry``) on this engine. Like
        ``attach_faults``, attach AFTER warmup: timing covers only warm
        executables. Timing blocks on dispatch outputs
        (``block_until_ready``), which changes wall-clock pipelining but
        never values, dispatch counts, or compilation."""
        self.telemetry = tel

    def recover(self) -> int:
        """Engine reset after an unrecoverable fault (retries exhausted,
        or a stuck tick whose dispatch was killed mid-flight): slot state
        on the device must be treated as lost, so every slot is freed —
        pages return to the pool, positions pin to 0 — and the page-
        conservation audit runs before serving resumes. Callers
        (planner/pool) recompute-requeue the evicted residents; recompute
        means surviving greedy streams replay bit-exactly.

        The radix prompt cache is NOT flushed (ISSUE 10): registered
        prefix pages hold only fully-written K/V from prompts that
        finished prefill before the fault — slot loss cannot have
        corrupted them (a faulted tick's writes target unregistered
        rows' pages) — so the hot subtree survives
        (``PrefixCache.retain_recent`` over ``prefix_hot_window``) and
        post-recovery admissions keep hitting. The conservation audit
        accounts the survivors: free + cache-held == total. Returns how
        many slots were dropped."""
        dropped = sum(1 for a in self._slot_active if a)
        self.release_all_slots(flush_cache=False)
        if self.prefix_cache is not None:
            self.prefix_cache.retain_recent(self.prefix_hot_window)
        if self.paged:
            held = (self.prefix_cache.held_pages
                    if self.prefix_cache is not None else 0)
            assert (self._kv.free_pages + held
                    == self._kv.allocator.num_pages), \
                "engine recovery leaked pages"
        self.check_page_invariants()
        self.stats.engine_resets += 1
        if self.telemetry is not None:
            self.telemetry.instant(self.telemetry.engine_track(self),
                                   "engine_reset", dropped=dropped)
        return dropped

    def check_page_invariants(self) -> bool:
        """Host-side page audit for the chaos suite: allocator
        conservation plus slot-level ownership (vacant slots own no
        pages, live rows match the allocator). No-op for ring engines."""
        if not self.paged:
            return True
        extra = (self.prefix_cache.page_refs()
                 if self.prefix_cache is not None else None)
        self._kv.check_invariants(extra_refs=extra)
        if self.prefix_cache is not None:
            self.prefix_cache.check_invariants()
        for slot in self._slot_free:
            assert not self._kv.pages(slot), \
                f"vacant slot {slot} still owns pages"
        return True

    # ------------------------------------------------- plan execution
    def execute(self, plan) -> "Any":
        """Run one ``StepPlan`` — the single data-plane entry point of
        the declarative serving API (``repro.serving.plan``). Fixed
        order: frees → cancels → preemptions → grows → first chunks (ONE
        packed prefill) → continuation chunks (ONE packed recompute
        prefill) → decodes (ONE slot step): at most three model
        dispatches per tick, all against pre-compiled executables.
        Returns a ``StepResult``.

        Fault tolerance: with a ``FaultInjector`` attached
        (``attach_faults``), injected ``TransientFault``s at the dispatch
        site retry up to ``retry_limit`` times with exponential backoff
        (``stats.engine_retries``); exhausted retries raise
        ``EngineFault`` — the control planes' engine-reset signal. The
        fault fires BEFORE the plan mutates anything, so a retried
        execute is indistinguishable from a clean one. Injected allocator
        failures surface in the result instead of raising: a failed
        admission batch (``admission_failed`` — insert_many rolled back
        all-or-nothing) or failed grows (``failed_grows`` — those slots
        are neither chunked nor decoded this tick); the planner requeues
        the affected requests under the recompute discipline, so their
        streams are unchanged when they are re-admitted."""
        attempts = 0
        while self.fault_injector is not None:
            try:
                self.fault_injector.maybe_fault("dispatch")
                break
            except TransientFault as e:
                self.stats.engine_retries += 1
                attempts += 1
                if self.telemetry is not None:
                    self.telemetry.instant(
                        self.telemetry.engine_track(self), "retry",
                        attempt=attempts)
                if attempts > self.retry_limit:
                    raise EngineFault(
                        f"dispatch fault persisted past {self.retry_limit} "
                        f"retries") from e
                if self.retry_backoff_s > 0:
                    import time
                    time.sleep(self.retry_backoff_s * (2 ** (attempts - 1)))
        tel = self.telemetry
        if tel is None or tel.trace is None:
            return self._execute_plan(plan)
        with tel.trace.span(tel.engine_track(self), "execute",
                            admissions=len(plan.admissions),
                            decodes=len(plan.decodes),
                            frees=len(plan.frees), cancels=len(plan.cancels),
                            preemptions=len(plan.preemptions),
                            grows=len(plan.grows)):
            return self._execute_plan(plan)

    def _execute_plan(self, plan) -> "Any":
        import numpy as np

        from repro.serving.plan import StepResult
        res = StepResult()
        for slot in plan.frees:
            self.free(slot)
        for slot in plan.cancels:
            self.free(slot)
        for slot in plan.preemptions:
            self.free(slot)
        tel = self.telemetry
        failed: set = set()
        if plan.grows:
            t0 = tel.t0() if tel is not None else 0.0
            for slot, upto in plan.grows:
                try:
                    self.grow_slot(slot, upto)
                except OutOfPages:
                    # injected (or genuinely racy) allocator failure: the
                    # slot is untouched but its next write is unbacked —
                    # skip its chunk/decode this tick, report for requeue
                    failed.add(slot)
                    res.failed_grows.append(slot)
            if tel is not None:
                tel.dispatch_done(self, "grow", len(plan.grows), t0,
                                  sync=self._slot_cache,
                                  failed=len(res.failed_grows))
        alias = [c for c in plan.admissions
                 if c.slot is None and getattr(c, "alias", None) is not None]
        first = [c for c in plan.admissions
                 if c.slot is None and getattr(c, "alias", None) is None]
        cont = [c for c in plan.admissions if c.slot is not None
                and c.slot not in failed]
        for c in alias:
            # prefix-cache hit: zero model dispatches — at most one COW
            # page copy plus one table-row/pos write, both warm. Each hit
            # consumes its match-time pins; on OutOfPages (fresh tail
            # pages) nothing changed, so the pins return to the cache and
            # the planner requeues the request like any failed admission
            try:
                slot = self.alias_admit(c.batch, c.alias,
                                        n_tokens=c.n_tokens,
                                        reserve_tokens=c.reserve_tokens)
                res.admitted[c.rid] = slot
            except OutOfPages:
                self.prefix_cache.release_hit(c.alias)
                if tel is not None:
                    tel.instant(tel.engine_track(self),
                                "alias_admission_failed", rid=c.rid)
        if first:
            t0 = tel.t0() if tel is not None else 0.0
            try:
                slots = self.insert_many(
                    [c.batch for c in first],
                    n_tokens=[c.n_tokens for c in first],
                    reserve_tokens=[c.reserve_tokens for c in first])
                res.admitted.update(
                    {c.rid: s for c, s in zip(first, slots)})
                res.dispatches += 1
                if tel is not None:
                    ntok = sum(int(c.batch["tokens"].shape[1])
                               for c in first)
                    tel.dispatch_done(self, "admission_prefill",
                                      _packed_bucket(ntok), t0,
                                      sync=(self._slot_cache,
                                            self._last_tok),
                                      segs=len(first), tokens=ntok)
            except OutOfPages:
                # all-or-nothing rollback already ran: no slot was touched;
                # the planner requeues the whole staged batch
                res.admission_failed = True
                if tel is not None:
                    tel.instant(tel.engine_track(self), "admission_failed",
                                segs=len(first))
        if cont:
            t0 = tel.t0() if tel is not None else 0.0
            self.chunk_append([(c.slot, c.batch, c.final) for c in cont])
            res.dispatches += 1
            if tel is not None:
                ntok = sum(int(c.batch["tokens"].shape[1]) for c in cont)
                tel.dispatch_done(self, "chunk_prefill",
                                  _packed_bucket(ntok), t0,
                                  sync=(self._slot_cache, self._last_tok),
                                  segs=len(cont), tokens=ntok)
        decodes = [s for s in plan.decodes if s not in failed]
        forced = [(s, t) for s, t in getattr(plan, "forced", [])
                  if s not in failed]
        if decodes or forced:
            t0 = tel.t0() if tel is not None else 0.0
            # teacher-forced catch-up slots join THE decode dispatch: the
            # planner pre-picked this tick's prompt token per slot; the
            # masked step writes its K/V at pos (exactly what prefill
            # would write there) and advances pos. Forced outputs never
            # reach res.tokens — nothing was generated for the stream
            for s, t in forced:
                self._last_tok = self._last_tok.at[s].set(jnp.int32(int(t)))
            toks, done = self.step(decodes + [s for s, _ in forced],
                                   forced={s for s, _ in forced})
            t = np.asarray(toks)
            res.tokens = {int(s): int(t[s]) for s in decodes}
            res.done = list(done)
            res.dispatches += 1
            if tel is not None:
                tel.dispatch_done(self, "decode",
                                  len(decodes) + len(forced), t0,
                                  sync=toks, forced=len(forced))
        spec = [e for e in getattr(plan, "spec", ())
                if e[0] not in failed]
        if spec:
            self._spec_round(spec, res)
        return res

    def _get_slot_step(self, sampling: Optional[SamplingParams]):
        fn = self._slot_step_jit.get(sampling)
        if fn is None:
            api = self.api
            skip = self._step_skip
            if sampling is None:
                fn = jax.jit(
                    lambda p, tok, cache, active: _slot_decode_step(
                        api, skip, p, tok, cache, active),
                    donate_argnums=self._donate_cache_argnums)
            else:
                fn = jax.jit(
                    lambda p, tok, cache, active, rng, _s=sampling:
                    _slot_decode_step(api, skip, p, tok, cache, active,
                                      rng, _s),
                    donate_argnums=self._donate_cache_argnums)
            self._slot_step_jit[sampling] = fn
        return fn

    def step(self, slots: Optional[List[int]] = None,
             forced: Optional[set] = None
             ) -> Tuple[jax.Array, List[int]]:
        """One decode step in a single dispatch — for all active slots
        (default) or only the plan's ``decodes`` subset.

        Returns ``(tokens, done)``: tokens (n_slots,) with sampling (or
        greedy arg-max) already applied — entries for unstepped slots keep
        their previous value and must be ignored (``slot_active``) — and
        ``done``, the active slots whose per-request token budget is now
        exhausted (reported every step until the caller frees them). The
        done flags are host-side counters, so reading them never syncs
        the device. The step mask is an INPUT to one shared executable:
        stepping a subset (the plan API excludes mid-prefill slots)
        retraces nothing.

        Slots in ``forced`` are teacher-forced prompt catch-up (a prefix-
        cache hit replaying its uncovered tail): the caller pre-loaded
        the slot's ``last_tok`` with a prompt token, the step writes that
        token's K/V and advances ``pos`` exactly like prefill would, but
        the slot's generated counter — and the emitted-token accounting —
        are untouched: nothing was sampled for the stream."""
        import numpy as np
        if slots is None:
            mask = self._active_mask
            stepped = [s for s, a in enumerate(self._slot_active) if a]
        else:
            m = np.zeros((self.n_slots,), bool)
            for s in slots:
                m[s] = self._slot_active[s]
            mask = jnp.asarray(m)
            stepped = [s for s in slots if self._slot_active[s]]
        forced = forced or set()
        fn = self._get_slot_step(self._slot_sampling)
        if self._slot_sampling is None:
            tok, self._slot_cache = fn(
                self.params, self._last_tok, self._slot_cache, mask)
        else:
            self._slot_rng, sub = jax.random.split(self._slot_rng)
            tok, self._slot_cache = fn(
                self.params, self._last_tok, self._slot_cache, mask, sub)
        self._last_tok = tok
        n_forced = 0
        for slot in stepped:
            self._slot_pos[slot] += 1
            if slot in forced:
                n_forced += 1
            else:
                self._slot_generated[slot] += 1
        done: List[int] = []
        for slot, active in enumerate(self._slot_active):
            if active:
                budget = self._slot_budget[slot]
                if budget is not None and self._slot_generated[slot] >= budget:
                    done.append(slot)
        self.stats.decode_steps += 1
        self.stats.tokens_out += len(stepped) - n_forced
        self.stats.forced_catchup_tokens += n_forced
        return tok, done

    def slot_active(self, slot: int) -> bool:
        return self._slot_active[slot]

    def kv_cache_bytes(self) -> int:
        """Device bytes held by the slot cache (all leaves — the paged
        pool's block tables and the null page are charged too, so paged
        vs ring comparisons are honest)."""
        if self._slot_cache is None:
            return 0
        return int(sum(x.nbytes for x in jax.tree.leaves(self._slot_cache)))

    # --------------------------------------------- pool accounting hooks
    def release_all_slots(self, flush_cache: bool = True) -> None:
        """Force-free every slot (pool reset between policy runs), and
        restore the canonical free-list order for slots AND pages: a
        freed slot/page re-enters its list in free order, so without the
        re-sort a reset engine would hand out history-dependent ids —
        harmless for correctness (streams are slot-id agnostic) but
        fatal for exact replay (the chaos harness's determinism check
        replays a seeded fault schedule whose interleaving depends on
        deterministic tie-breaks over slot ids).

        ``flush_cache=True`` (the pool-reset default) also drops the
        prefix cache: a replayed seeded run must start from a cold
        cache (hit patterns are deterministic but history-dependent).
        ``recover()`` passes False — a mid-run engine reset keeps the
        hot radix working set (its own conservation audit accounts the
        cache-held pages)."""
        for slot, active in enumerate(self._slot_active):
            if active:
                self.free(slot)
        if self.prefix_cache is not None and flush_cache:
            self.prefix_cache.flush()
        self._slot_free.sort()
        if self.paged:
            self._kv.allocator.sort_free()
        if self._draft is not None:
            # freeing the targets freed their twins; restore the draft's
            # canonical free-list order too (same exact-replay argument)
            self._draft.release_all_slots()

    def reset_stats(self) -> None:
        """Zero the counters WITHOUT touching the jit caches — the pool
        warms executables once, then resets before the measured run."""
        self.stats = EngineStats()

    def jit_cache_sizes(self) -> Dict[str, int]:
        """Executable-cache cardinality, for asserting the pool's
        no-per-request-recompilation invariant. Counts traced signatures
        where jax exposes them (``_cache_size``), else cache-key entries."""
        def n(fn) -> int:
            try:
                return fn._cache_size()
            except (AttributeError, TypeError):
                # private jax API gone: fall back to counting the function
                # itself — new cache keys are still caught, intra-key
                # retraces are not. Warn so the no-recompilation check
                # can't degrade silently.
                import warnings
                warnings.warn(
                    "jax private _cache_size() unavailable; recompilation "
                    "accounting degrades to cache-key counting",
                    RuntimeWarning, stacklevel=2)
                return 1
        out = {
            "prefill": sum(n(f) for f in self._prefill_jit.values()),
            "packed_prefill": sum(
                n(f) for f in self._packed_prefill_jit.values()),
            "generate": sum(n(f) for f in self._gen_jit.values()),
            "decode": n(self._decode),
            "slot_step": sum(n(f) for f in self._slot_step_jit.values()),
            "write_slot": n(self._write_slot),
        }
        if self._write_segments is not None:
            out["write_segments"] = n(self._write_segments)
        if self._write_slot_paged is not None:
            out["write_slot_paged"] = n(self._write_slot_paged)
            out["clear_slot"] = n(self._clear_slot)
            out["set_table_row"] = n(self._set_table_row)
        if self._clear_ring is not None:
            out["clear_ring"] = n(self._clear_ring)
        if self._copy_page is not None:
            out["copy_page"] = n(self._copy_page)
            out["alias_slot"] = n(self._alias_slot)
        if self._chunk_prefill_jit:
            out["chunk_prefill"] = sum(
                n(f) for f in self._chunk_prefill_jit.values())
        if self._draft_scan is not None:
            out["draft_scan"] = n(self._draft_scan)
        if self._spec_commit is not None:
            out["spec_commit"] = n(self._spec_commit)
        return out


def _merge_rows(new, old, mask, skip):
    """Keep ``new`` cache leaves only for rows in ``mask``; rows outside
    it retain ``old`` bit-for-bit. Per-row leaves carry batch at axis 0
    (1-D ``pos``) or axis 1 (stacked ``(layers, B, ...)``) — the same
    layout rule ``_write_slot`` relies on. Leaves in ``skip`` (paged K/V
    pools, the block table) are page-indexed, not row-indexed, and pass
    through: masked-off rows' dead writes there land at a not-yet-valid
    position (always overwritten before any read attends to it) or on
    the null page."""
    out = {}
    for key, nl in new.items():
        if key in skip:
            out[key] = nl
            continue
        axis = 0 if nl.ndim == 1 else 1
        shape = [1] * nl.ndim
        shape[axis] = mask.shape[0]
        out[key] = jnp.where(mask.reshape(shape), nl,
                             old[key].astype(nl.dtype))
    return out


def _slot_decode_step(api, skip, params, tok, cache, mask, rng=None,
                      sampling: Optional[SamplingParams] = None):
    logits, new = api.decode_step(params, tok, cache)
    # rows outside the step mask — vacant slots AND mid-prefill slots the
    # plan excluded — keep every per-row leaf (pos, SSM state, ring K/V)
    # bit-identical: an un-merged vacant row would creep back to
    # full-cache attention cost (ring) or walk off its null-page table
    # row (paged) within cache_len steps, and an advanced mid-prefill
    # row would corrupt its carried state
    cache = _merge_rows(new, cache, mask, skip)
    if sampling is None:
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    else:
        nxt = L.sample_logits(rng, logits, temperature=sampling.temperature,
                              top_k=sampling.top_k, top_p=sampling.top_p)
    # unstepped rows keep their last token (a mid-prefill slot's pending
    # teacher-forced token must survive an interleaved decode dispatch)
    return jnp.where(mask, nxt, tok), cache


def _write_slot(big, one, slot):
    """Write a batch-1 cache into row ``slot`` of a slotted cache. Every
    stacked leaf is (layers, batch, ...); the position vector is (batch,)."""
    def wr(b_leaf, o_leaf):
        o_leaf = o_leaf.astype(b_leaf.dtype)
        axis = 0 if b_leaf.ndim == 1 else 1
        return jax.lax.dynamic_update_slice_in_dim(b_leaf, o_leaf, slot,
                                                   axis=axis)
    return jax.tree.map(wr, big, one)


def _make_write_slot_paged(paged_keys, page_size: int):
    """Build the paged insert-scatter: paged leaves route the batch-1
    dense prefill cache through the slot's block-table row into the page
    pool; per-row leaves (pos, SSM state, cross K/V) take the dense row
    write. The table row is always the full padded (max_pages,) vector —
    one static shape, so a stream of varying prompt/budget page counts
    compiles exactly one executable (padding entries scatter their zeros
    into the never-read null page)."""
    paged_keys = frozenset(paged_keys)

    def write(big, one, slot, table_row):
        max_pages = table_row.shape[0]
        out = {}
        for key, b_leaf in big.items():
            if key == "block_tables":
                out[key] = jax.lax.dynamic_update_slice_in_dim(
                    b_leaf, table_row[None], slot, axis=0)
            elif key in paged_keys:
                o = one[key]                     # (layers, 1, slot_len, ...)
                o = o[:, 0].reshape(
                    (o.shape[0], max_pages, page_size) + o.shape[3:])
                out[key] = b_leaf.at[:, table_row].set(o.astype(b_leaf.dtype))
            else:
                o_leaf = one[key].astype(b_leaf.dtype)
                axis = 0 if b_leaf.ndim == 1 else 1
                out[key] = jax.lax.dynamic_update_slice_in_dim(
                    b_leaf, o_leaf, slot, axis=axis)
        return out

    return write


def _make_write_segments(paged_keys):
    """Build the packed-insert scatter: per-TOKEN leaves (the family's
    ``PAGED_KEYS`` — packed (layers, T, ...) order) scatter each token at
    its (dest0, dest1) coordinate, which is (physical page, offset) on a
    paged cache and (slot row, column) on a ring; every other leaf is
    per-SEGMENT and takes a batched row write at the slot ids. Padding
    tokens land on the null page (paged, dead by convention) or out of
    bounds (ring, dropped by scatter semantics); padding segments carry
    slot id n_slots (out of bounds, dropped). One static-shape executable
    per packed-token bucket — the batch's segment count never retraces."""
    paged_keys = frozenset(paged_keys)

    def write(cache, last_tok, pcache, logits, dest0, dest1, seg_slots,
              table_rows):
        out = {}
        for key, b_leaf in cache.items():
            if key == "block_tables":
                out[key] = b_leaf.at[seg_slots].set(table_rows)
            elif key in paged_keys:
                o = pcache[key].astype(b_leaf.dtype)      # (layers, T, ...)
                out[key] = b_leaf.at[:, dest0, dest1].set(o)
            else:
                o = pcache[key].astype(b_leaf.dtype)      # (layers, S, ...)
                axis = 0 if b_leaf.ndim == 1 else 1
                if axis == 0:
                    out[key] = b_leaf.at[seg_slots].set(o)
                else:
                    out[key] = b_leaf.at[:, seg_slots].set(o)
        new_last = last_tok.at[seg_slots].set(
            jnp.argmax(logits, -1).astype(jnp.int32))
        return out, new_last

    return write


def _set_table_row(cache, slot, table_row):
    """Push a grown slot's block-table row to the device (lazy page
    reservation: pages appear as decode/chunk writes cross page
    boundaries). One static shape — the row is always the full padded
    (max_pages,) vector — so growth never retraces."""
    cache = dict(cache)
    cache["block_tables"] = cache["block_tables"].at[slot].set(table_row)
    return cache


def _make_copy_page(paged_keys):
    """Build the copy-on-write page copy: every paged K/V leaf copies
    physical page ``src`` onto ``dst`` — one static-shape executable
    regardless of which pages are involved, so a stream of COW hits
    compiles exactly once. The alias path dispatches it at most once per
    hit admission (only when the match ends inside a page)."""
    paged_keys = frozenset(paged_keys)

    def copy(cache, src, dst):
        out = dict(cache)
        for key in sorted(paged_keys):
            leaf = out[key]                   # (layers, pages, page_size, …)
            page = jax.lax.dynamic_slice_in_dim(leaf, src, 1, axis=1)
            out[key] = jax.lax.dynamic_update_slice_in_dim(
                leaf, page, dst, axis=1)
        return out

    return copy


def _alias_slot(cache, slot, table_row, pos):
    """Point a hit admission's slot at its aliased + fresh pages and set
    its position to the covered prefix length — the ONLY device writes a
    fully-page-aligned hit needs (COW adds one page copy). Like
    ``_set_table_row``, the row is the full padded (max_pages,) vector:
    one static shape for every hit."""
    cache = dict(cache)
    cache["block_tables"] = cache["block_tables"].at[slot].set(table_row)
    cache["pos"] = cache["pos"].at[slot].set(pos)
    return cache


def _clear_slot(cache, mask, slot):
    """Park a freed slot: position 0 + whole table row on the null page,
    so its dead writes can never alias a page later granted to another
    sequence. The active-mask clear rides the same dispatch — a separate
    eager scatter costs a full dispatch overhead per free."""
    cache = dict(cache)
    cache["pos"] = cache["pos"].at[slot].set(0)
    cache["block_tables"] = cache["block_tables"].at[slot].set(NULL_PAGE)
    return cache, mask.at[slot].set(False)


def _clear_ring(pos, mask, slot):
    """Ring-slot free: position and active-mask clear in one dispatch."""
    return pos.at[slot].set(0), mask.at[slot].set(False)


def make_engine(cfg, *, seed: int = 0, cache_len: int = 256,
                dtype=jnp.float32) -> InferenceEngine:
    """Convenience constructor used by examples/tests (CPU scale)."""
    from repro.models.registry import build_model
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(seed), dtype)
    return InferenceEngine(api, params, cache_len=cache_len)
