"""Batched inference engine — the data plane under the D-STACK scheduler.

One engine instance wraps one (model, sub-mesh) pair: jitted prefill and
decode executables, a KV/state cache, and greedy generation. On a real pod
the scheduler holds one engine per (model, chip-allocation) — this is the
TPU analogue of the paper's CUDA-MPS process with a fixed GPU% (§3.2): the
compiled executable pins the spatial allocation, and re-allocation means
switching to a standby engine compiled for a different sub-mesh while the
active one keeps serving.

Decode hot-path architecture
----------------------------
The paper's throughput gains assume the data plane keeps the accelerator
saturated while the scheduler multiplexes models; three mechanisms here
make that true on the host side:

1. **Scan-based generation.** ``generate`` runs the whole autoregressive
   loop as a single jitted ``jax.lax.scan`` with the KV cache donated into
   the executable — ONE dispatch per generate call instead of one per
   token. The eager per-token loop survives as ``generate_eager`` (it is
   the benchmark baseline; see ``benchmarks/bench_decode.py``).

2. **Power-of-two bucketing.** Executables specialize on cache shape AND
   scan length, so naively sizing the cache to ``prompt +
   max_new_tokens`` (or the scan to the exact token count) re-compiles
   for every distinct request. ``bucket_len`` rounds the cache length up
   to the next power of two (floored at the engine's base ``cache_len``)
   and ``generate`` buckets the scan length the same way (surplus tokens
   discarded): prefill/decode/generate executables are compiled once per
   bucket — O(log max_len) compilations total — and reused for every
   request that fits.

3. **Slot-based continuous batching.** ``init_slots`` allocates a
   fixed-slot cache (batch = n_slots, ring length = slot cache_len);
   ``insert`` prefills one request and writes its rows into a free slot
   mid-stream, ``step`` decodes one token for all slots in a single
   dispatch, ``free`` releases a slot (its length resets to 0 so the
   ragged decode-attention path treats the row as empty). Because every
   sequence carries its own position/length (``cache["pos"]`` is a (B,)
   vector end to end), admitting a new request never repads, recompiles,
   or perturbs other slots — the paper's "efficient batch size under SLO"
   lever implemented at the kernel level.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.registry import ModelAPI


def _pow2_at_least(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Static sampling configuration — hashable, so it is part of the jitted
    generate executable's cache key (one executable per distinct setting,
    reused across requests). temperature <= 0 means greedy arg-max."""
    temperature: float = 1.0
    top_k: int = 0
    top_p: float = 1.0


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0
    decode_steps: int = 0
    tokens_out: int = 0
    inserts: int = 0


class InferenceEngine:
    def __init__(self, api: ModelAPI, params, *, cache_len: int = 256,
                 mesh=None, donate_cache: bool = True,
                 alloc_chips: Optional[int] = None):
        self.api = api
        self.cfg = api.cfg
        self.params = params
        self.cache_len = cache_len
        self.mesh = mesh
        self.donate_cache = donate_cache
        # chip count of the sub-mesh this engine's executables are compiled
        # for — purely a label on this host, but the EnginePool keys standby
        # engines by it (the paper's re-allocation story: switching
        # allocation = switching to a pre-built engine, never recompiling)
        self.alloc_chips = alloc_chips
        self.stats = EngineStats()

        if mesh is not None:
            from jax.sharding import NamedSharding
            pspecs = api.param_specs(mesh)
            self._param_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
        else:
            self._param_sh = None

        self._prefill_jit: Dict[int, Any] = {}
        self._gen_jit: Dict[Any, Any] = {}
        donate = (2,) if donate_cache else ()
        self._decode = jax.jit(
            lambda p, tok, cache: api.decode_step(p, tok, cache),
            donate_argnums=donate)
        self._slot_step = jax.jit(
            lambda p, tok, cache, active: _slot_decode_step(
                api, p, tok, cache, active),
            donate_argnums=donate)
        self._write_slot = jax.jit(_write_slot, donate_argnums=(0,))

        # slot state (populated by init_slots)
        self._slot_cache = None
        self._slot_free: List[int] = []
        self._slot_active: List[bool] = []
        self._last_tok = None

    # ------------------------------------------------------------------
    def bucket_len(self, need: int) -> int:
        """Cache-length bucket for ``need`` tokens: next power of two,
        floored at the engine's base cache_len (compile once per bucket)."""
        return max(self.cache_len, _pow2_at_least(need))

    def new_cache(self, batch: int, cache_len: Optional[int] = None):
        return self.api.init_cache(batch, cache_len or self.cache_len)

    def prefill(self, batch: Dict[str, Any], cache_len: Optional[int] = None):
        clen = cache_len or self.cache_len
        fn = self._prefill_jit.get(clen)
        if fn is None:
            api = self.api
            fn = jax.jit(lambda p, b, _c=clen: api.prefill(p, b, _c))
            self._prefill_jit[clen] = fn
        logits, cache = fn(self.params, batch)
        self.stats.prefills += 1
        return logits, cache

    def decode(self, token, cache):
        logits, cache = self._decode(self.params, token, cache)
        self.stats.decode_steps += 1
        return logits, cache

    # ------------------------------------------------------------------
    def _gen_fn(self, max_new_tokens: int, greedy: bool,
                sampling: SamplingParams):
        key = (max_new_tokens, greedy, sampling)
        fn = self._gen_jit.get(key)
        if fn is None:
            api = self.api

            def pick(rng, lg):
                if greedy:
                    return rng, jnp.argmax(lg, -1).astype(jnp.int32)
                rng, sub = jax.random.split(rng)
                return rng, L.sample_logits(
                    sub, lg, temperature=sampling.temperature,
                    top_k=sampling.top_k, top_p=sampling.top_p)

            def gen(params, logits, cache, rng):
                rng, tok0 = pick(rng, logits)

                def body(carry, _):
                    tok, cache, rng = carry
                    lg, cache = api.decode_step(params, tok, cache)
                    rng, nxt = pick(rng, lg)
                    return (nxt, cache, rng), tok

                (_, cache, _), toks = jax.lax.scan(
                    body, (tok0, cache, rng), None, length=max_new_tokens)
                # cache is returned (and discarded by the caller) so the
                # donated input can alias the output — true in-place reuse
                return toks.swapaxes(0, 1), cache           # (B, T), cache

            fn = jax.jit(gen, donate_argnums=(2,) if self.donate_cache else ())
            self._gen_jit[key] = fn
        return fn

    def generate(self, batch: Dict[str, Any], max_new_tokens: int,
                 greedy: bool = True, rng: Optional[jax.Array] = None,
                 sampling: Optional[SamplingParams] = None):
        """Prefill + one fused scan over all decode steps (single dispatch).

        Returns (B, max_new_tokens). Bit-equivalent to ``generate_eager``
        under greedy decoding. Passing ``sampling`` switches the scan body
        to temperature/top-k/top-p sampling (greedy is ignored); the
        sampler runs INSIDE the fused loop, so sampled generation still
        costs one dispatch per call. The scan length is bucketed to a
        power of two (like the cache length) so a stream of varying
        generation lengths compiles O(log) executables per sampling
        config, not one per distinct length; surplus tokens discarded."""
        if sampling is not None:
            greedy = False
        sampling = sampling or SamplingParams()
        b = batch["tokens"].shape[0]
        t_bucket = max(1, _pow2_at_least(max_new_tokens))
        need = batch["tokens"].shape[1] + t_bucket
        logits, cache = self.prefill(batch, self.bucket_len(need))
        if rng is None:
            rng = jax.random.PRNGKey(0)
        toks, _ = self._gen_fn(t_bucket, greedy, sampling)(
            self.params, logits, cache, rng)
        self.stats.decode_steps += t_bucket
        self.stats.tokens_out += b * max_new_tokens
        return toks[:, :max_new_tokens]

    def generate_eager(self, batch: Dict[str, Any], max_new_tokens: int,
                       greedy: bool = True, rng: Optional[jax.Array] = None):
        """Seed-engine reference path, kept as the bench_decode baseline and
        for parity tests: one jitted dispatch per token from a Python loop,
        and an UNBUCKETED exact-length prefill that re-traces/compiles
        whenever the request needs more than the base cache_len (the seed
        constructed a fresh ``jax.jit`` per such call)."""
        b = batch["tokens"].shape[0]
        need = max(self.cache_len, batch["tokens"].shape[1] + max_new_tokens)
        if need != self.cache_len:
            api = self.api
            logits, cache = jax.jit(
                lambda p, bt: api.prefill(p, bt, need))(self.params, batch)
            self.stats.prefills += 1
        else:
            logits, cache = self.prefill(batch, self.cache_len)
        outs = []
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(max_new_tokens):
            outs.append(tok)
            logits, cache = self.decode(tok, cache)
            if greedy:
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
            else:
                rng, sub = jax.random.split(rng)
                tok = jax.random.categorical(sub, logits).astype(jnp.int32)
        self.stats.tokens_out += b * max_new_tokens
        return jnp.stack(outs, axis=1)

    # ------------------------------------------ slot continuous batching
    @property
    def n_slots(self) -> int:
        return 0 if self._slot_cache is None else len(self._slot_active)

    @property
    def free_slots(self) -> int:
        return len(self._slot_free)

    def init_slots(self, n_slots: int, cache_len: Optional[int] = None):
        """Allocate a fixed-slot cache for continuous batching."""
        self.slot_len = cache_len or self.cache_len
        self._slot_cache = self.api.init_cache(n_slots, self.slot_len)
        self._slot_free = list(range(n_slots))
        self._slot_active = [False] * n_slots
        self._active_mask = jnp.zeros((n_slots,), bool)
        self._last_tok = jnp.zeros((n_slots,), jnp.int32)
        return self

    def insert(self, batch: Dict[str, Any]) -> int:
        """Admit one request (batch size 1) into a free slot mid-stream.

        Prefills the prompt against the slot ring length and writes the
        resulting cache rows into the slot; other slots' rows are untouched
        so their decoding is unaffected. Returns the slot id."""
        if not self._slot_free:
            raise RuntimeError("no free slots")
        assert batch["tokens"].shape[0] == 1, "insert admits one request"
        slot = self._slot_free.pop(0)
        logits, one = self.prefill(batch, self.slot_len)
        self._slot_cache = self._write_slot(self._slot_cache, one,
                                            jnp.int32(slot))
        self._last_tok = self._last_tok.at[slot].set(
            jnp.argmax(logits[0], -1).astype(jnp.int32))
        self._slot_active[slot] = True
        self._active_mask = self._active_mask.at[slot].set(True)
        self.stats.inserts += 1
        return slot

    def free(self, slot: int) -> None:
        """Release a slot. Its position pins to 0 (here and after every
        subsequent step), so vacant rows attend over at most one cache
        slot instead of drifting back toward full-cache cost."""
        if not self._slot_active[slot]:
            return
        self._slot_active[slot] = False
        self._slot_free.append(slot)
        self._active_mask = self._active_mask.at[slot].set(False)
        self._slot_cache["pos"] = self._slot_cache["pos"].at[slot].set(0)

    def step(self):
        """One decode step for ALL slots in a single dispatch.

        Returns (tokens (n_slots,), logits-argmax already applied). Tokens
        for inactive slots are garbage and must be ignored by the caller
        (``slot_active``)."""
        tok, self._slot_cache = self._slot_step(
            self.params, self._last_tok, self._slot_cache,
            self._active_mask)
        self._last_tok = tok
        self.stats.decode_steps += 1
        self.stats.tokens_out += sum(self._slot_active)
        return tok

    def slot_active(self, slot: int) -> bool:
        return self._slot_active[slot]

    # --------------------------------------------- pool accounting hooks
    def release_all_slots(self) -> None:
        """Force-free every slot (pool reset between policy runs)."""
        for slot, active in enumerate(self._slot_active):
            if active:
                self.free(slot)

    def reset_stats(self) -> None:
        """Zero the counters WITHOUT touching the jit caches — the pool
        warms executables once, then resets before the measured run."""
        self.stats = EngineStats()

    def jit_cache_sizes(self) -> Dict[str, int]:
        """Executable-cache cardinality, for asserting the pool's
        no-per-request-recompilation invariant. Counts traced signatures
        where jax exposes them (``_cache_size``), else cache-key entries."""
        def n(fn) -> int:
            try:
                return fn._cache_size()
            except (AttributeError, TypeError):
                # private jax API gone: fall back to counting the function
                # itself — new cache keys are still caught, intra-key
                # retraces are not. Warn so the no-recompilation check
                # can't degrade silently.
                import warnings
                warnings.warn(
                    "jax private _cache_size() unavailable; recompilation "
                    "accounting degrades to cache-key counting",
                    RuntimeWarning, stacklevel=2)
                return 1
        return {
            "prefill": sum(n(f) for f in self._prefill_jit.values()),
            "generate": sum(n(f) for f in self._gen_jit.values()),
            "decode": n(self._decode),
            "slot_step": n(self._slot_step),
            "write_slot": n(self._write_slot),
        }


def _slot_decode_step(api, params, tok, cache, active):
    logits, cache = api.decode_step(params, tok, cache)
    # vacant rows' positions stay pinned at 0: decode_step increments pos
    # for every row, and an un-pinned vacant row would creep back to
    # full-cache attention cost within cache_len steps
    cache["pos"] = jnp.where(active, cache["pos"], 0)
    return jnp.argmax(logits, -1).astype(jnp.int32), cache


def _write_slot(big, one, slot):
    """Write a batch-1 cache into row ``slot`` of a slotted cache. Every
    stacked leaf is (layers, batch, ...); the position vector is (batch,)."""
    def wr(b_leaf, o_leaf):
        o_leaf = o_leaf.astype(b_leaf.dtype)
        axis = 0 if b_leaf.ndim == 1 else 1
        return jax.lax.dynamic_update_slice_in_dim(b_leaf, o_leaf, slot,
                                                   axis=axis)
    return jax.tree.map(wr, big, one)


def make_engine(cfg, *, seed: int = 0, cache_len: int = 256,
                dtype=jnp.float32) -> InferenceEngine:
    """Convenience constructor used by examples/tests (CPU scale)."""
    from repro.models.registry import build_model
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(seed), dtype)
    return InferenceEngine(api, params, cache_len=cache_len)
