"""Unified serving metrics for the engine pool (paper §7 reporting).

One ``PoolResult`` per (policy, workload) run carries everything the
paper's comparison tables need: per-model throughput, completion-latency
p50/p99, SLO violations (dropped + late-but-served), GPU runtime shares,
the Jain fairness index over those shares (§6.3 / Fig. 10), and the
pool's allocation occupancy (the real-engine analogue of the simulator's
knee-credited utilization)."""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence


def jain_index(values: Sequence[float]) -> float:
    """Jain's fairness index (Σx)² / (n·Σx²) over non-negative shares:
    1.0 when all shares are equal, 1/n when one consumer has everything.
    Empty or all-zero input is vacuously fair (1.0)."""
    vals = [max(0.0, float(v)) for v in values]
    n = len(vals)
    ss = sum(v * v for v in vals)
    if n == 0 or ss <= 0.0:
        return 1.0
    tot = sum(vals)
    return (tot * tot) / (n * ss)


def percentile(xs: Sequence[float], q: float,
               default: float = float("nan")) -> float:
    """Nearest-rank percentile (q in [0, 1]) of ``xs``."""
    if not xs:
        return default
    s = sorted(xs)
    idx = min(len(s) - 1, max(0, math.ceil(q * len(s)) - 1))
    return s[idx]


@dataclasses.dataclass
class ModelPoolMetrics:
    """Per-model accounting over one pool run."""
    completed: int = 0
    violated: int = 0          # dropped-expired + late-but-served + queued
    dropped: int = 0
    late: int = 0
    # admitted into KV slots but still decoding when the run was cut off
    # at duration — counted in neither completed nor violated (mirrors the
    # simulator's accounting) but reported so they can't vanish silently
    abandoned: int = 0
    runs: int = 0
    # allocation-quantization divergences from the policy's own ledger
    # (see EnginePool.admit): upgrades ran the smallest pre-built engine
    # because no standby was <= the ask (more chips than budgeted);
    # downgrades got fewer chips than asked (slower than budgeted)
    alloc_upgrades: int = 0
    alloc_downgrades: int = 0
    # paged-KV admission accounting: requests refused at least once
    # because the page pool (KV memory), not slot count or chips,
    # couldn't back their prompt + n_tokens horizon (counted once per
    # request, however many planning cycles it sat blocked); and requests
    # inserted into a running run's early-freed slots (mid-run
    # re-admission)
    blocked_on_memory: int = 0
    topups: int = 0
    # lazy page reservation (StepPlanner): residents evicted because the
    # page pool ran dry mid-decode/mid-prefill (their pages freed), and
    # their requests pushed straight back to the queue for a
    # from-scratch re-prefill on re-admission (vLLM-style recompute
    # preemption). Every preemption requeues immediately, so the two
    # counters track together; a requeued request that then expires is
    # additionally counted dropped/violated like any other
    preemptions: int = 0
    requeues: int = 0
    # per-cause terminal counters (ISSUE 6). With completed/dropped these
    # partition every request the plane ever accepted or refused:
    #   cancelled        — client cancel, queued or resident (no violation)
    #   deadline_aborted — evicted while resident, past SLO deadline
    #   shed             — refused at admission (load-shed watermarks)
    # Mirrored from RequestQueue (the accounting source of truth) at
    # snapshot/observe time, never incremented here directly.
    cancelled: int = 0
    deadline_aborted: int = 0
    shed: int = 0
    # fault-tolerance accounting, mirrored from EngineStats: transient
    # dispatch faults absorbed by retry, and full engine resets (retries
    # exhausted or stuck tick) that recompute-requeued the residents
    engine_retries: int = 0
    engine_resets: int = 0
    # radix prompt cache (ISSUE 8), mirrored from EngineStats: admissions
    # whose prefix aliased cached pages instead of prefilling, the prompt
    # tokens those hits skipped, and copy-on-write page copies for hits
    # that diverged mid-page
    prefix_hits: int = 0
    prefix_hit_tokens: int = 0
    cow_copies: int = 0
    # speculative decoding (ISSUE 9), mirrored from EngineStats: draft
    # tokens proposed, draft tokens the target accepted, verify rounds
    # dispatched, and rounds that rolled at least one token back
    draft_tokens: int = 0
    accepted_tokens: int = 0
    spec_rounds: int = 0
    rollbacks: int = 0
    runtime: float = 0.0       # virtual busy seconds (Σ run latencies)
    chip_seconds: float = 0.0  # allocation-weighted: Σ chips·latency
    tokens: int = 0
    latencies: List[float] = dataclasses.field(default_factory=list)
    # streaming latency views, mirrored from RequestQueue like latencies:
    # TTFT (arrival → first token) of completed requests, and mean
    # time-between-tokens per completed request — the figures that make
    # chunked-prefill TBT wins visible in PoolResult (ISSUE 7)
    ttfts: List[float] = dataclasses.field(default_factory=list)
    tbts: List[float] = dataclasses.field(default_factory=list)
    # multi-tenant serving (ISSUE 10): decode tokens served per tenant,
    # populated by the planner's observe only for requests that carry a
    # tenant label — single-tenant planes pay nothing. Jain over these
    # values is the per-tenant fairness figure the gateway bench reports.
    tenant_tokens: Dict[str, int] = dataclasses.field(default_factory=dict)

    def throughput(self, duration: float) -> float:
        return self.completed / duration if duration > 0 else 0.0

    def tenant_fairness(self) -> float:
        """Jain index over per-tenant served decode tokens (1.0 when no
        tenant labels were seen — vacuously fair)."""
        return jain_index(list(self.tenant_tokens.values()))

    @property
    def p50(self) -> float:
        return percentile(self.latencies, 0.50)

    @property
    def p99(self) -> float:
        return percentile(self.latencies, 0.99)

    @property
    def ttft_p50(self) -> float:
        return percentile(self.ttfts, 0.50)

    @property
    def ttft_p99(self) -> float:
        return percentile(self.ttfts, 0.99)

    @property
    def tbt_p50(self) -> float:
        return percentile(self.tbts, 0.50)

    @property
    def tbt_p99(self) -> float:
        return percentile(self.tbts, 0.99)


@dataclasses.dataclass
class PoolResult:
    policy: str
    duration: float            # virtual seconds the schedule spans
    wall_s: float              # host wall-clock spent executing it
    per_model: Dict[str, ModelPoolMetrics]
    occupancy: float           # ∫ min(alloc_frac, 1) dt / duration
    # ∫ (KV pages in use / usable pages) dt / duration — how hard the
    # paged cache memory is actually working (0.0 for unpaged pools)
    page_occupancy: float = 0.0
    steps: int = 0             # real engine decode dispatches issued
    truncated: bool = False    # hit a controller backstop (max_steps /
                               # max_time) — metrics cover a partial run

    @property
    def total_tokens(self) -> int:
        return sum(m.tokens for m in self.per_model.values())

    @property
    def total_completed(self) -> int:
        return sum(m.completed for m in self.per_model.values())

    @property
    def total_violated(self) -> int:
        return sum(m.violated for m in self.per_model.values())

    def throughput(self, model: Optional[str] = None) -> float:
        if model:
            return self.per_model[model].throughput(self.duration)
        return self.total_completed / self.duration if self.duration else 0.0

    def fairness(self, key: str = "runtime") -> float:
        """Jain index over per-model shares — ``runtime`` (the paper's
        Fig. 10 measure: accelerator time each model received) or
        ``chip_seconds`` (allocation-weighted) or ``completed``."""
        return jain_index([getattr(m, key) for m in self.per_model.values()])

    # ------------------------------------------------------------- display
    def table_rows(self) -> List[str]:
        rows = [
            f"{self.policy:16s} thr={self.throughput():8.1f}/s "
            f"tok/s={self.total_tokens / self.duration:9.0f} "
            f"viol={self.total_violated:5d} "
            f"jain={self.fairness():.3f} occ={self.occupancy:.3f} "
            f"pages={self.page_occupancy:.3f} "
            f"steps={self.steps} wall={self.wall_s:.2f}s"
            + (" [TRUNCATED]" if self.truncated else "")]
        for n, m in sorted(self.per_model.items()):
            rows.append(
                f"    {n:26s} served={m.completed:5d} viol={m.violated:4d} "
                f"p50={m.p50 * 1e3:7.2f}ms p99={m.p99 * 1e3:7.2f}ms "
                f"runtime={m.runtime * 1e3:8.2f}ms runs={m.runs}"
                + (f" ttft_p50={m.ttft_p50 * 1e3:.2f}ms"
                   f" ttft_p99={m.ttft_p99 * 1e3:.2f}ms"
                   if m.ttfts else "")
                + (f" tbt_p50={m.tbt_p50 * 1e3:.2f}ms" if m.tbts else "")
                + (f" alloc_up={m.alloc_upgrades}"
                   if m.alloc_upgrades else "")
                + (f" alloc_down={m.alloc_downgrades}"
                   if m.alloc_downgrades else "")
                + (f" mem_blocked={m.blocked_on_memory}"
                   if m.blocked_on_memory else "")
                + (f" topups={m.topups}" if m.topups else "")
                + (f" preempt={m.preemptions}/{m.requeues}"
                   if m.preemptions else "")
                + (f" abandoned={m.abandoned}" if m.abandoned else "")
                + (f" cancelled={m.cancelled}" if m.cancelled else "")
                + (f" aborted={m.deadline_aborted}"
                   if m.deadline_aborted else "")
                + (f" shed={m.shed}" if m.shed else "")
                + (f" retries={m.engine_retries}"
                   if m.engine_retries else "")
                + (f" resets={m.engine_resets}" if m.engine_resets else "")
                + (f" pfx_hits={m.prefix_hits}({m.prefix_hit_tokens}tok)"
                   if m.prefix_hits else "")
                + (f" cow={m.cow_copies}" if m.cow_copies else "")
                + (f" spec={m.accepted_tokens}/{m.draft_tokens}"
                   f"({m.spec_rounds}r,{m.rollbacks}rb)"
                   if m.spec_rounds else ""))
        return rows
