"""Declarative step-plan serving: ONE ``StepPlan`` per tick.

D-STACK's core claim is that a spatio-temporal scheduler deciding *what
runs each tick* is what buys throughput under SLOs. The imperative API
this module replaces hid that decision inside calls scattered across
``EnginePool.admit``/``topup``, ``InferenceEngine.insert``/``free``/
``step`` and the controller loop — so tick-granular features (chunked
prefill, preempt-and-requeue) could not be expressed without the
scheduler reaching into engine internals. Here the boundary is a plan:

  * ``StepPlanner`` observes the queue and the engine's page/slot state
    and emits a ``StepPlan`` — admissions (as ``PrefillChunk``s), decode
    slots, preemptions, frees, and lazy page grows — once per tick;
  * ``InferenceEngine.execute(plan)`` runs it with a BOUNDED number of
    dispatches: at most one packed-prefill dispatch (all first chunks),
    one chunk-continuation dispatch (all in-flight prefills advance
    together through one packed prefix-recompute prefill), and one
    decode dispatch (all decoding slots) — every executable
    pre-compiled, zero recompiles while serving;
  * ``StepResult`` reports what actually happened (tokens per slot, done
    slots, rid→slot bindings) and ``StepPlanner.observe`` folds it back
    into queue/metrics state.

The two ROADMAP follow-ons this API exists for are plan *variants*, not
new code paths:

**Chunked prefill** (Sarathi-style): ``PlannerConfig.chunk_tokens`` caps
the prefill tokens computed per tick, so a long prompt is split into a
first chunk (packed prefill of positions 0..c) plus continuation chunks
that re-run the packed prefill over the growing prefix (prefix
recompute) and scatter each tick's new K/V onto the slot's pages —
already-written positions are rewritten with bit-identical values (a
causal token's K/V never depends on later tokens, and the packed
fallback's exact-zero padding makes the row bucket invisible — the PR-4
parity guarantee), and the per-segment leaves carry the partial segment
forward as recomputed post-prefix state. That makes chunked prefill
BIT-EXACT with one-shot prefill (asserted per family in
``tests/test_plan.py``) while admission work interleaves with in-flight
decodes instead of stalling them (time-between-tokens p99 — see
``bench_decode --chunked-prefill``). The recompute trades O(prefix)
extra prefill FLOPs per chunk for a per-tick work bound of
~``chunk_tokens`` — the classic chunked-prefill trade, and the chunks
reuse the admission path's packed executables (same pow2 token buckets:
chunked serving compiles NOTHING new).

**Page preemption** (vLLM-style recompute preemption):
``PlannerConfig.lazy`` reserves pages for the tokens a request has
actually written instead of its whole prompt+budget horizon, growing
page-by-page as decode proceeds. When the pool runs dry the planner
preempts the lowest-priority resident — by default the one with the
most SLO slack per unit of sunk recompute work (``preemption_key``;
``PlannerConfig.victim="newest"`` restores the legacy latest-arrival
rule) — frees its pages, and requeues the request; on re-admission its
prompt re-prefills from scratch, so the final token stream is unchanged
(greedy decode is deterministic). ``preemptions`` / ``requeues`` are
counted in ``ModelPoolMetrics``.

**The failure half** (ISSUE 6) is plan machinery too: client cancels
and deadline aborts are ``StepPlan.cancels`` events (pages free like
any other free, terminal cause accounted per request); overload sheds
at ``submit`` against ``PlannerConfig`` watermarks instead of queueing
toward a timeout; and injected/transient runtime faults
(``repro.serving.faults``) are absorbed by execute-level retry, result-
level requeue (``failed_grows``/``admission_failed``), or a full
engine reset (``StepPlanner.recover``) that recompute-requeues every
resident — the same discipline as preemption, so surviving greedy
streams stay bit-exact (asserted by ``tests/test_chaos.py``).

``EnginePool.admit`` and ``EnginePool.topup`` route their shared
admission logic through ``StepPlanner.select_admissible`` (one gate, one
head-reservation/aging scheme, one ``blocked_on_memory`` accounting) and
execute the resulting plan — the legacy imperative entry points survive
as thin shims over plans.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.faults import EngineFault
from repro.serving.metrics import ModelPoolMetrics
from repro.serving.request import Request, RequestQueue


@dataclasses.dataclass(frozen=True)
class PrefillChunk:
    """One tick's worth of prefill for one request.

    ``start == 0`` chunks carry no slot: the engine claims one and runs
    them through the packed ragged prefill (one dispatch for all first
    chunks in the plan). ``start > 0`` chunks name the slot that is
    mid-prefill; ``batch`` then holds the FULL prefix up to the chunk's
    end, and they advance through one shared packed prefix-recompute
    prefill (one dispatch for all continuations in the plan). ``final``
    marks the chunk that completes the prompt — its last-token logits
    seed the first generated token, exactly as a one-shot prefill's last
    logits would."""
    rid: int
    batch: Any                         # token pytree for THIS chunk (B=1)
    start: int                         # absolute prompt offset
    length: int                        # tokens in this chunk
    final: bool
    slot: Optional[int] = None         # None -> engine claims a slot
    n_tokens: Optional[int] = None     # decode budget (first chunk only)
    # KV horizon (tokens) to reserve pages for NOW (first chunk only).
    # None = the legacy up-front reservation (prompt + budget); the lazy
    # planner passes just the chunk's own tokens and grows later.
    reserve_tokens: Optional[int] = None
    # prefix-cache hit (``PrefixHit``) backing a zero-dispatch alias
    # admission: instead of prefilling, the engine aliases the hit's
    # pages into the new slot's block table (plus at most one COW page
    # copy) and the uncovered tail arrives via ``StepPlan.forced``
    # teacher-forced catch-up. First chunks only (``slot is None``);
    # ``length == 0`` — no prefill tokens are computed for the chunk.
    alias: Optional[Any] = None


@dataclasses.dataclass
class StepPlan:
    """Everything one engine does this tick, decided up front.

    Execution order inside ``InferenceEngine.execute`` is fixed —
    frees → cancels → preemptions → grows → admissions (first chunks,
    one packed prefill) → continuations (one packed recompute prefill)
    → decodes (one step) — so a planner can project page availability
    exactly: pages released by frees/cancels/preemptions are usable by
    this same plan's grows/admissions."""
    admissions: List[PrefillChunk] = dataclasses.field(default_factory=list)
    decodes: List[int] = dataclasses.field(default_factory=list)
    preemptions: List[int] = dataclasses.field(default_factory=list)
    frees: List[int] = dataclasses.field(default_factory=list)
    # lifecycle Cancel events: slots whose requests terminated this tick
    # (client cancel or deadline abort) — executed exactly like frees
    # (pages back to the pool, table row to the null page) but kept
    # separate so accounting and tests can tell completion from abort
    cancels: List[int] = dataclasses.field(default_factory=list)
    # lazy page growth: extend slot's page horizon to cover >= tokens
    grows: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
    # teacher-forced catch-up: (slot, prompt token) pairs riding THE
    # decode dispatch — an aliased admission consumes its uncovered
    # prompt tail one token per tick, writing exactly the K/V a prefill
    # would write there, with zero extra dispatches. Forced outputs
    # never reach ``StepResult.tokens`` (nothing was generated)
    forced: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
    # speculative decoding (ISSUE 9): (slot, k, init_tokens-or-None)
    # rounds replacing plain decode steps for those slots — the engine's
    # paired draft proposes k tokens and ONE incremental chunk dispatch
    # verifies them all. ``init_tokens`` is the slot's full written
    # history (prompt + emitted prefix), present only when the draft
    # twin must be (re)admitted; None while the pair is in lockstep
    spec: List[Tuple[int, int, Optional[List[int]]]] = dataclasses.field(
        default_factory=list)

    @property
    def empty(self) -> bool:
        return not (self.admissions or self.decodes or self.preemptions
                    or self.frees or self.cancels or self.grows
                    or self.forced or self.spec)


@dataclasses.dataclass
class StepResult:
    """What ``execute`` actually did: sampled tokens per DECODED slot,
    slots whose budgets are now exhausted, rid→slot bindings for this
    plan's first-chunk admissions, and the dispatch count (the bounded-
    dispatch invariant: <= 3 model dispatches per tick).

    Failure feedback (injected or genuine allocator trouble):
    ``failed_grows`` lists slots whose lazy page growth failed — they
    were neither chunked nor decoded this tick and the planner must
    recompute-requeue them; ``admission_failed`` means the whole
    first-chunk batch rolled back all-or-nothing (no slot touched) and
    the staged requests must requeue."""
    tokens: Dict[int, int] = dataclasses.field(default_factory=dict)
    done: List[int] = dataclasses.field(default_factory=list)
    admitted: Dict[int, int] = dataclasses.field(default_factory=dict)
    dispatches: int = 0
    failed_grows: List[int] = dataclasses.field(default_factory=list)
    admission_failed: bool = False
    # speculative rounds: the 1..k+1 tokens each spec slot emitted this
    # tick (accepted drafts + the verify dispatch's bonus token), in
    # stream order — the multi-token sibling of ``tokens``
    spec_tokens: Dict[int, List[int]] = dataclasses.field(
        default_factory=dict)


@dataclasses.dataclass
class PlannerConfig:
    # prompt tokens prefilled per tick across ALL requests; 0 = unchunked
    # (every admission prefills its whole prompt in its first chunk)
    chunk_tokens: int = 0
    # lazy page reservation + preempt-and-requeue on OutOfPages; False =
    # the legacy deadlock-free up-front prompt+budget reservation
    lazy: bool = False
    gen_len: int = 4                   # default decode budget (n_tokens=0)
    drop_expired: bool = True
    # page reservation with aging for the page-blocked FIFO head (the
    # ROADMAP anti-starvation follow-on): the head's reservation ratchets
    # up to its need as pages free, and bypassing smaller requests cannot
    # spend reserved pages
    head_reservation: bool = True
    # deadline aborts: evict residents whose SLO deadline has passed (the
    # same page-freeing Cancel event a client cancel emits). Off by
    # default — the legacy planes only police deadlines at the queue
    # (drop_expired) and at completion (late)
    deadline_aborts: bool = False
    # load-shed watermarks (graceful degradation): refuse NEW submissions
    # when the queue is already this deep / the page pool this full —
    # fail fast at admission instead of timing out resident. None = never
    shed_queue_depth: Optional[int] = None
    shed_page_frac: Optional[float] = None     # in-use fraction, 0..1
    # OutOfPages victim policy: "slack" scores residents by SLO slack per
    # unit of sunk recompute work (see preemption_key); "newest" is the
    # legacy latest-arrival rule
    victim: str = "slack"
    # radix prompt cache (needs an engine with ``enable_prefix_cache()``
    # attached): admissions matching a cached prefix alias its pages
    # instead of prefilling them, finished prompts register their full
    # pages, and cold cache nodes are evicted BEFORE any resident is
    # preempted when pages run short
    prefix_cache: bool = False
    # hit-quality floor: a hit must cover >= 1 full page AND >= this
    # fraction of the prompt, else it counts as a miss (the uncovered
    # tail advances one teacher-forced token per tick, so low-coverage
    # hits trade little prefill for a long serialized catch-up)
    prefix_min_frac: float = 0.5
    # speculative decoding (needs ``engine.attach_draft``): draft up to
    # spec_k tokens per decoding slot per tick and verify them in one
    # incremental chunk dispatch. 0 = off
    spec_k: int = 0
    # decode-batch knee ABOVE which speculation is withheld (the
    # accelerator is compute-bound there and verify FLOPs displace
    # decode FLOPs — see ``core.scheduler.speculation_worthwhile``).
    # None = always worthwhile (CPU-scale tests)
    spec_knee_batch: Optional[int] = None
    # acceptance-rate gate: withhold speculation while the trailing
    # acceptance EMA sits below this floor (a chronically-wrong draft
    # burns a dispatch per round for nothing), except on every
    # ``spec_probe_every``-th eligible tick — the probe that lets the
    # EMA recover when the workload turns draftable again
    spec_min_accept: float = 0.0
    spec_probe_every: int = 16
    # tiered, tenant-fair admission (ISSUE 10): tier name -> weight
    # (higher admits first; e.g. {"interactive": 4, "standard": 2,
    # "batch": 1}). None = strict FIFO (every existing plane). Within a
    # tier, the least-served tenant admits first (a DWRR-style deficit
    # over admitted service, DARIS arXiv:2504.08795), so one tenant's
    # burst cannot monopolize admission against another's stream
    tiers: Optional[Dict[str, float]] = None
    # anti-starvation bound for the LOWEST tier: once its oldest waiting
    # request has been bypassed by this many higher-tier admissions, it
    # outranks everything on the next pick — so a batch request admits
    # after at most tier_bypass_limit higher-tier admissions once it is
    # the tier's oldest (plus the page/SLO gates every admission faces)
    tier_bypass_limit: int = 8


class TieredAdmission:
    """Weighted-tier, tenant-fair admission ordering (ISSUE 10).

    Replaces the admission scans' strict-FIFO pop with a keyed pick
    (``RequestQueue.pop_pick``): higher-weight tiers admit first; within
    a tier the tenant with the greatest service deficit (least admitted
    prompt+budget tokens, deficit-round-robin style) wins; arrival then
    rid break remaining ties, so a single-tenant single-tier queue
    degenerates to exact FIFO.

    Anti-starvation bound: the LOWEST tier's oldest waiting request
    tracks how many higher-tier admissions bypassed it; at
    ``bypass_limit`` it outranks every other request on the next pick.
    A batch-tier request that reaches "oldest in tier" therefore admits
    after at most ``bypass_limit`` further higher-tier admissions —
    subject only to the same page/SLO gates every admission faces
    (asserted by ``test_lowest_tier_starvation_bound``).

    Per-tenant charges are renormalized after every admission so the
    least-served tenant still WAITING reads 0: values stay bounded, a
    tenant never seen before reads 0 (the fair default for newcomers),
    and a tenant served while another waits keeps a positive charge —
    so the waiting tenant wins the next same-tier pick."""

    def __init__(self, tiers: Dict[str, float], *,
                 default_tier: str = "standard", bypass_limit: int = 8):
        if not tiers:
            raise ValueError("TieredAdmission needs at least one tier")
        self.tiers = dict(tiers)
        self.default_tier = (default_tier if default_tier in self.tiers
                             else min(self.tiers, key=self.tiers.get))
        self.bypass_limit = max(1, int(bypass_limit))
        self.deficit: Dict[str, float] = {}
        self._lowest = min(self.tiers, key=self.tiers.get)
        self._low_head: Optional[int] = None     # rid of the tier's oldest
        self._low_bypassed = 0

    def weight(self, req: Request) -> float:
        w = self.tiers.get(req.tier)
        return w if w is not None else self.tiers[self.default_tier]

    def _starving(self, req: Request) -> bool:
        return (req.rid == self._low_head
                and self._low_bypassed >= self.bypass_limit)

    def key(self):
        """Pick key for ``RequestQueue.pop_pick`` — lowest wins."""
        def k(req: Request):
            return (0 if self._starving(req) else 1,
                    -self.weight(req),
                    self.deficit.get(req.tenant, 0.0),
                    req.arrival, req.rid)
        return k

    def admitted(self, req: Request, cost: float, waiting) -> None:
        """Record an actual admission: charge the tenant's deficit by the
        admitted service (prompt + decode budget tokens) and advance the
        lowest tier's bypass counter against ``waiting`` (requests still
        queued after this pick)."""
        t = req.tenant
        self.deficit[t] = self.deficit.get(t, 0.0) + float(cost)
        # renormalize against the least-served tenant STILL WAITING (an
        # unseen waiting tenant reads 0): relative order among waiting
        # tenants is preserved, charges stay bounded, and a tenant that
        # has been served while another waits keeps its positive charge
        # until the other catches up
        waiting_tenants = {r.tenant for r in waiting}
        if waiting_tenants:
            lo = min(self.deficit.get(w, 0.0) for w in waiting_tenants)
            if lo > 0.0:
                for k in self.deficit:
                    self.deficit[k] = max(0.0, self.deficit[k] - lo)
        low = [r for r in waiting if (r.tier if r.tier in self.tiers
                                      else self.default_tier) == self._lowest]
        if not low:
            self._low_head, self._low_bypassed = None, 0
            return
        head = min(low, key=lambda r: (r.arrival, r.rid))
        if head.rid != self._low_head:
            self._low_head, self._low_bypassed = head.rid, 0
        tier = req.tier if req.tier in self.tiers else self.default_tier
        if tier != self._lowest:
            self._low_bypassed += 1


@dataclasses.dataclass
class _Resident:
    """Planner-side state for one occupied slot."""
    req: Request
    batch: Any                         # full prompt pytree (B=1)
    prompt_len: int
    done: int                          # prompt tokens prefilled so far
    budget: int                        # decode-token budget
    prefilling: bool                   # True until the final chunk ran
    # teacher-forced catch-up (aliased admissions): a ``forced`` resident
    # consumes prompt[done] one token per tick via ``StepPlan.forced``
    # until the prompt completes — it never takes continuation chunks
    forced: bool = False
    host_tokens: Optional[List[int]] = None   # prompt as host ints (lazy)
    # pinned PrefixHit while STAGED only: the engine consumes the pins at
    # alias admission (or releases them itself on OutOfPages), so observe
    # clears this on both outcomes; recover() releases it when execute
    # never ran (fault-before-mutation / stuck tick)
    alias: Any = None
    registered: bool = False           # prompt pages inserted in the cache
    # speculation seed: argmax over the full prompt (the pending token
    # right after prefill, never itself emitted) — captured ONCE from
    # the device before the first decode so the planner can rebuild the
    # slot's written history for draft (re)admission
    seed_tok: Optional[int] = None


def preemption_key(req: Request, sunk_tokens: int, now: float,
                   mode: str = "slack") -> Tuple:
    """Victim-ordering key for OutOfPages preemption — HIGHEST wins.

    ``slack`` prefers the resident with the most SLO slack per unit of
    sunk work: score = (deadline − now) / (1 + tokens already written).
    A resident with slack to spare and little invested work is the
    cheapest to recompute and the likeliest to still meet its deadline
    after re-admission (DARIS-style slack-aware eviction); a nearly-due
    or deeply-prefilled resident is protected. Infinite/absent SLOs map
    to a huge finite slack so the ratio still discriminates on sunk
    work, which also makes ``slack`` degrade to least-sunk-first (≈ the
    newest resident) on SLO-free workloads. ``newest`` is the legacy
    latest-arrival rule. Callers append the slot id for a deterministic
    tie-break."""
    if mode == "newest":
        return (0.0, req.arrival)
    slack = req.deadline - now
    if not math.isfinite(slack):
        slack = 1e18
    return (slack / (1.0 + max(0, int(sunk_tokens))), req.arrival)


def _prompt_tokens(batch) -> int:
    return int(batch["tokens"].shape[1])


def _chunk_batch(batch, stop: int):
    """Truncate a prompt pytree to its first ``stop`` tokens. Every
    chunk — first or continuation — carries the FULL prefix up to its
    end plus the non-token inputs (``enc_embeds``): the engine's chunk
    executor recomputes the prefix (packed prefill) and rewrites its
    already-written positions with bit-identical values."""
    if stop >= batch["tokens"].shape[1]:
        return batch
    out = dict(batch)
    out["tokens"] = batch["tokens"][:, :stop]
    return out


class StepPlanner:
    """Builds one ``StepPlan`` per tick from (policy knobs + queue +
    engine page/slot view), and folds ``StepResult``s back into
    queue/metrics state.

    Two usage modes share the same admission gate:

    * **tick plane** (bound engine + queue): ``submit`` requests with
      real prompt arrays, then ``build`` → ``engine.execute`` →
      ``observe`` once per tick. This is what ``bench_decode
      --chunked-prefill`` and the plan-equivalence tests drive.
    * **pool plane** (``EnginePool``): one planner per hosted model;
      ``admit``/``topup`` call ``select_admissible`` (the single
      admission gate — KV pages, SLO expiry, head reservation) against
      whichever standby engine the policy granted, and execute the
      resulting whole-prompt plan.
    """

    def __init__(self, engine=None, queue: Optional[RequestQueue] = None,
                 config: Optional[PlannerConfig] = None,
                 metrics: Optional[ModelPoolMetrics] = None):
        self.engine = engine
        self.queue = queue
        self.config = config or PlannerConfig()
        self.metrics = metrics if metrics is not None else ModelPoolMetrics()
        self._resident: Dict[int, _Resident] = {}
        self._staged: List[_Resident] = []    # admissions awaiting a slot
        self._to_free: List[int] = []
        self._prompts: Dict[int, Any] = {}    # rid -> prompt pytree
        self._blocked_rids: set = set()
        # head reservation: (rid of the page-blocked FIFO head, pages
        # ratcheted for it so far)
        self._resv_rid: Optional[int] = None
        self._resv_pages: int = 0
        # per-request emitted tokens (tick plane); preemption clears a
        # stream — the restarted request re-emits from scratch
        self.streams: Dict[int, List[int]] = {}
        # rids cancelled while in flight (resident or staged): the next
        # build() emits their Cancel event; a cancelled rid caught at a
        # requeue point (preemption, failed admission, engine reset)
        # terminates there instead of re-entering the queue
        self._cancelled: set = set()
        self._now = 0.0                    # last build() time (victim keys)
        # speculation feedback: trailing acceptance-rate EMA (optimistic
        # start — the first rounds measure it), eligible-tick counter
        # (drives the probe cadence), and the k planned per spec slot
        # this tick (observe turns emitted counts into acceptance rates)
        self._spec_accept_ema = 1.0
        self._spec_ticks = 0
        self._spec_planned: Dict[int, int] = {}
        # telemetry plane (repro.serving.telemetry.Telemetry), set by
        # EnginePool.attach_telemetry or directly by the tick plane;
        # None = zero-cost (one attribute check per lifecycle event)
        self.telemetry = None
        # tiered, tenant-fair admission (None = strict FIFO, the exact
        # legacy pop order — every existing plane takes this branch)
        self.admission = (TieredAdmission(
            self.config.tiers, bypass_limit=self.config.tier_bypass_limit)
            if self.config.tiers else None)

    def _tel_event(self, name: str, req: Request, **args) -> None:
        tel = self.telemetry
        if tel is not None:
            tel.request_event(req.model, name, rid=req.rid, **args)

    # ------------------------------------------------------- tick plane
    def submit(self, req: Request, batch) -> bool:
        """Enqueue a request with its real prompt (token pytree, B=1).
        Returns False when the request was load-shed at admission (the
        ``PlannerConfig`` watermarks — queue depth / page occupancy —
        are crossed): it terminates immediately with state ``shed``
        rather than queueing toward a certain timeout."""
        self.streams.setdefault(req.rid, [])
        if self.should_shed():
            self.queue.shed_request(req)
            self.metrics.shed = self.queue.shed
            self._tel_event("shed", req)
            return False
        self.queue.push(req)
        self._tel_event("queued", req)
        self._prompts[req.rid] = batch
        return True

    def should_shed(self, queue_len: Optional[int] = None,
                    page_frac: Optional[float] = None) -> bool:
        """Backpressure gate: True when either load-shed watermark is
        crossed. Callers without a bound queue/engine (the pool plane)
        pass explicit measurements."""
        cfg = self.config
        if cfg.shed_queue_depth is not None:
            if queue_len is None:
                queue_len = len(self.queue) if self.queue is not None else 0
            if queue_len >= cfg.shed_queue_depth:
                return True
        if cfg.shed_page_frac is not None:
            if page_frac is None:
                eng = self.engine
                if (eng is None or not getattr(eng, "paged", False)
                        or eng.total_pages <= 0):
                    page_frac = 0.0
                else:
                    page_frac = 1.0 - eng.free_pages / eng.total_pages
            if page_frac >= cfg.shed_page_frac:
                return True
        return False

    def cancel(self, rid: int) -> bool:
        """Client cancellation (disconnect). A still-queued request is
        removed immediately; a resident or staged one is marked and the
        next ``build`` emits its Cancel event — the slot's pages free
        before that plan grows or admits, and mid-chunked-prefill
        residents are no special case (their partial pages free the same
        way). Returns False for unknown or already-terminal rids."""
        if self.queue is not None and self.queue.cancel(rid) is not None:
            self._prompts.pop(rid, None)
            self.metrics.cancelled = self.queue.cancelled
            return True
        live = {r.req.rid for r in self._resident.values()}
        live.update(r.req.rid for r in self._staged)
        if rid in live:
            self._cancelled.add(rid)
            return True
        return False

    def busy(self) -> bool:
        return bool(self._resident or self._staged or self._to_free
                    or (self.queue is not None and len(self.queue)))

    def _budget_of(self, req: Request, prompt_len: int) -> int:
        eng = self.engine
        want = req.n_tokens if req.n_tokens > 0 else self.config.gen_len
        room = max(1, eng.slot_len - prompt_len)
        return max(1, min(int(want), room))

    def _pages_for(self, tokens: int) -> int:
        return self.engine.kv_pages_needed(tokens)

    def _grow_cost(self, slot: int, upto: int) -> int:
        """New pages needed to extend ``slot``'s horizon to ``upto``."""
        eng = self.engine
        if not eng.paged:
            return 0
        have = eng.reserved_tokens(slot)
        if upto <= have:
            return 0
        return self._pages_for(upto) - self._pages_for(max(1, have))

    def _pick_victim(self, excluded: set) -> Optional[int]:
        """Victim for OutOfPages preemption / stall-breaking, by
        ``PlannerConfig.victim``: ``slack`` (default) scores residents
        by SLO slack per unit of sunk recompute work — see
        ``preemption_key`` — so a nearly-due or deeply-prefilled
        resident is protected; ``newest`` preserves the legacy
        latest-arrival rule. Ties break on (arrival, slot id) so the
        choice is deterministic."""
        eng = self.engine
        cands = []
        for slot, r in self._resident.items():
            if slot in excluded:
                continue
            sunk = eng.slot_pos(slot) if eng is not None else r.done
            cands.append(preemption_key(r.req, sunk, self._now,
                                        self.config.victim) + (slot,))
        if not cands:
            return None
        return max(cands)[-1]

    # ---------------------------------------------------- prefix cache
    def _pcache(self):
        """The engine's prefix cache when BOTH the config flag and the
        engine attachment agree; None disables every cache path (the
        pool plane's unbound planners pass the engine explicitly)."""
        eng = self.engine
        if not self.config.prefix_cache or eng is None:
            return None
        return eng.prefix_cache

    @staticmethod
    def _host_tokens(r: _Resident) -> List[int]:
        if r.host_tokens is None:
            r.host_tokens = [int(t)
                             for t in np.asarray(r.batch["tokens"])[0]]
        return r.host_tokens

    def _min_covered(self, eng, prompt_len: int) -> int:
        """Hit-quality floor for ``PrefixCache.match`` (see
        ``PlannerConfig.prefix_min_frac``)."""
        return max(eng.page_size,
                   int(math.ceil(self.config.prefix_min_frac * prompt_len)))

    def _evict_cache(self, need: int, pages_avail: int) -> int:
        """Evict cold radix nodes to cover ``need`` pages BEFORE any
        resident is preempted: a cached-but-unreferenced prefix page is
        strictly cheaper to reclaim than a resident's recompute-requeue.
        Returns the updated availability projection."""
        cache = self._pcache()
        if cache is None or need <= pages_avail:
            return pages_avail
        freed = cache.evict(need - pages_avail)
        if freed:
            eng = self.engine
            if eng.telemetry is not None:
                eng.telemetry.instant(eng.telemetry.engine_track(eng),
                                      "prefix_evict", pages=freed)
        return pages_avail + freed

    def _register_prompts(self) -> None:
        """Insert finished prompts' full pages into the prefix cache —
        once per resident, only after its prompt is COMPLETE. That
        timing is the safety argument for read-only aliasing: chunk
        recompute (which rewrites prompt positions) is over, and every
        later write — decode or a dead masked write — lands at
        ``pos >= prompt_len``, past the registered pages."""
        cache = self._pcache()
        eng = self.engine
        if cache is None or not eng.paged:
            return
        ps = eng.page_size
        for slot, r in self._resident.items():
            if r.prefilling or r.registered:
                continue
            r.registered = True
            n_full = r.prompt_len // ps
            if n_full < 1:
                continue
            toks = self._host_tokens(r)
            cache.insert(toks[:n_full * ps], eng.slot_pages(slot)[:n_full])
            # concurrent same-prefix prefills double-filled pages the
            # cache could not yet serve: repoint this row at the
            # canonical pages (bit-identical content) and free its
            # duplicates — zero-cost when nothing matches
            eng.dedup_slot_prefix(slot, toks, n_full)

    def build(self, now: float) -> StepPlan:
        """Emit this tick's plan. Mutates planner bookkeeping under the
        assumption the plan WILL be executed (the tick loop always does:
        build → execute → observe)."""
        eng, q, cfg = self.engine, self.queue, self.config
        self._now = now
        plan = StepPlan()
        plan.frees = list(self._to_free)
        self._to_free = []

        # -- phase 0: lifecycle events. Client cancels and (when enabled)
        # deadline aborts terminate residents via plan.cancels — the same
        # page-freeing event, whatever phase the victim was in: a
        # mid-chunked-prefill resident's partial pages free exactly like
        # a decoder's. Accounting is terminal here (the queue's per-cause
        # counters); nothing requeues.
        for slot, r in sorted(self._resident.items()):
            if r.req.rid in self._cancelled:
                self._terminate(slot, r, plan, cancelled=True)
            elif cfg.deadline_aborts and now > r.req.deadline:
                self._terminate(slot, r, plan, cancelled=False)

        freed = set(plan.frees) | set(plan.cancels)
        # page/slot projection: execution frees/cancels/preempts before
        # it grows/admits, so released pages count as available
        pages_avail = eng.free_pages + sum(
            eng.slot_page_count(s) for s in plan.frees) + sum(
            eng.slot_page_count(s) for s in plan.cancels)
        slots_avail = eng.free_slots + len(plan.frees) + len(plan.cancels)
        # decode set snapshot BEFORE this tick's final chunks flip flags
        decodes = [s for s, r in sorted(self._resident.items())
                   if not r.prefilling and s not in freed]

        # -- phase A: decode page growth (lazy), preempting on shortage
        victims: set = set()
        for slot in list(decodes):
            if slot in victims:
                continue
            # next decode writes at pos = written tokens; cover it
            upto = min(eng.slot_pos(slot) + 1, eng.slot_len)
            need = self._grow_cost(slot, upto)
            pages_avail = self._evict_cache(need, pages_avail)
            while need > pages_avail:
                v = self._pick_victim(excluded=victims | freed)
                if v is None:
                    break
                victims.add(v)
                pages_avail += eng.slot_page_count(v)
                pages_avail += self._preempt(v, plan, now)
                if v == slot:
                    need = 0
                    break
            if slot in victims:
                continue
            if upto > eng.reserved_tokens(slot):
                # always recorded, even at zero page cost: the horizon
                # bookkeeping must advance with the physical coverage
                plan.grows.append((slot, upto))
                pages_avail -= need

        # -- phase A': teacher-forced catch-up for aliased admissions.
        # Each forced resident consumes ONE uncovered prompt token this
        # tick, riding the decode dispatch — zero extra dispatches. Its
        # page need is exactly a decode's (the forced write lands at
        # slot_pos), competing through the same evict-then-preempt
        # ladder; a failed grow requeues it like any decode's would.
        for slot, r in sorted(self._resident.items()):
            if (not r.forced or slot in victims or slot in freed
                    or slot not in self._resident):
                continue
            upto = min(eng.slot_pos(slot) + 1, eng.slot_len)
            need = self._grow_cost(slot, upto)
            pages_avail = self._evict_cache(need, pages_avail)
            while need > pages_avail:
                v = self._pick_victim(excluded=victims | freed)
                if v is None:
                    break
                victims.add(v)
                pages_avail += eng.slot_page_count(v)
                pages_avail += self._preempt(v, plan, now)
                if v == slot:
                    need = 0
                    break
            if slot in victims:
                continue
            if upto > eng.reserved_tokens(slot):
                plan.grows.append((slot, upto))
                pages_avail -= need
            toks = self._host_tokens(r)
            plan.forced.append((slot, toks[r.done]))
            r.done += 1
            if r.done >= r.prompt_len:
                # the final forced step's logits seed the first sampled
                # token exactly as a one-shot prefill's last logits
                # would — decodable from the NEXT tick's snapshot
                r.prefilling = False
                r.forced = False

        decodes = [s for s in decodes if s not in victims]
        slots_avail += len(victims)

        # -- phase A_spec: move eligible decode slots onto speculative
        # rounds. Gated on the roofline knee (speculate while decode is
        # memory-bound; see ``speculation_worthwhile``) and on the
        # trailing acceptance EMA with periodic probes. A spec slot's
        # page horizon widens from pos+1 to pos+k+1 (the verify chunk
        # writes k+1 positions); on page shortage k degrades instead of
        # preempting anyone — speculation is an optimization and must
        # never evict a resident to fund itself.
        self._spec_planned = {}
        pages_avail = self._plan_spec(plan, decodes, pages_avail)

        # -- phase B: continuation chunks for in-flight prefills, oldest
        # request first (finish what is resident before admitting more).
        # Each selected continuation advances by a full ``chunk_tokens``
        # quantum of NEW tokens, and the budget is charged the whole
        # RECOMPUTED row (prefix + chunk) — the work the dispatch
        # actually does — so per-tick prefill cost stays bounded by
        # ~max(chunk_tokens, longest prefix + quantum); the oldest
        # continuation always proceeds even when its row alone exceeds
        # the budget (liveness — without it a long prompt could never
        # finish).
        budget_left = cfg.chunk_tokens if cfg.chunk_tokens > 0 else math.inf
        quantum = cfg.chunk_tokens if cfg.chunk_tokens > 0 else math.inf
        inflight = sorted(
            ((r.req.arrival, r.req.rid, slot) for slot, r in
             self._resident.items()
             if r.prefilling and not r.forced
             and slot not in victims and slot not in freed))
        first_cont = True
        for _, _, slot in inflight:
            if budget_left <= 0:
                break
            r = self._resident[slot]
            c = int(min(r.prompt_len - r.done, quantum))
            if not first_cont and r.done + c > budget_left:
                continue                   # next tick
            if eng.paged:
                # shrink the chunk to what the page pool can back — the
                # cap counts the slot's PHYSICAL coverage (whole pages,
                # including slack past the reserved horizon in its last
                # page), so a zero-page-cost continuation is never
                # skipped; a zero-token chunk just waits for pages
                pages_avail = self._evict_cache(
                    self._grow_cost(slot, r.done + c), pages_avail)
                while c > 0:
                    need = self._grow_cost(slot, r.done + c)
                    if need <= pages_avail:
                        break
                    cap = (eng.slot_page_count(slot) + pages_avail) * \
                        eng.page_size - r.done
                    c = int(min(c - 1, max(0, cap)))
                if c <= 0:
                    continue
                if r.done + c > eng.reserved_tokens(slot):
                    plan.grows.append((slot, r.done + c))
                    pages_avail -= self._grow_cost(slot, r.done + c)
            final = (r.done + c) == r.prompt_len
            plan.admissions.append(PrefillChunk(
                rid=r.req.rid, batch=_chunk_batch(r.batch, r.done + c),
                start=r.done, length=c, final=final, slot=slot))
            budget_left -= r.done + c
            r.done += c
            if final:
                r.prefilling = False       # decodable from the NEXT tick
            first_cont = False

        # -- phase C: admissions (first chunks) from the queue
        if q is not None:
            kept = self._scan_queue(
                eng, q, now, max_batch=slots_avail,
                pages_avail=pages_avail, budget_left=budget_left)
            for req, batch, budget, c, reserve, hit, toks in kept:
                p = _prompt_tokens(batch)
                if hit is not None:
                    # prefix-cache hit: zero-cost leading chunk — no
                    # prefill tokens computed, no chunk budget charged.
                    # The uncovered tail teacher-forces from next tick
                    plan.admissions.append(PrefillChunk(
                        rid=req.rid, batch=batch, start=0, length=0,
                        final=False, n_tokens=budget,
                        reserve_tokens=reserve, alias=hit))
                    self._staged.append(_Resident(
                        req=req, batch=batch, prompt_len=p,
                        done=hit.covered, budget=budget, prefilling=True,
                        forced=True, host_tokens=toks, alias=hit))
                    self._tel_event("prefix_hit", req, covered=hit.covered,
                                    cow=hit.cow_src is not None)
                    continue
                final = c == p
                plan.admissions.append(PrefillChunk(
                    rid=req.rid, batch=_chunk_batch(batch, c),
                    start=0, length=c, final=final,
                    n_tokens=budget, reserve_tokens=reserve))
                self._staged.append(_Resident(
                    req=req, batch=batch, prompt_len=p,
                    done=c, budget=budget, prefilling=not final,
                    host_tokens=toks))

        plan.decodes = decodes
        # stall-breaker: every resident is page-starved mid-prefill and
        # nothing can free pages (no decodes, no admissions) — preempt the
        # newest resident so the oldest can make progress next tick
        if plan.empty and self._resident:
            v = self._pick_victim(excluded=set())
            if v is not None:
                self._preempt(v, plan, now)
        return plan

    def _plan_spec(self, plan: StepPlan, decodes: List[int],
                   pages_avail: int) -> int:
        """Phase A_spec: convert eligible ``decodes`` entries into
        ``plan.spec`` rounds (mutates ``decodes`` in place), widening
        their grow horizons to cover the verify chunk. Returns the
        updated page-availability projection."""
        eng, cfg = self.engine, self.config
        if (cfg.spec_k <= 0 or not decodes or eng is None
                or getattr(eng, "_draft", None) is None):
            return pages_avail
        from repro.core.scheduler.base import speculation_worthwhile
        if not speculation_worthwhile(len(decodes), cfg.spec_knee_batch):
            return pages_avail
        self._spec_ticks += 1
        probe = (self._spec_ticks % max(1, cfg.spec_probe_every)) == 0
        if self._spec_accept_ema < cfg.spec_min_accept and not probe:
            return pages_avail
        for slot in list(decodes):
            r = self._resident.get(slot)
            if r is None:
                continue
            pos = eng.slot_pos(slot)
            # k is capped so the round can never overshoot the request's
            # budget (emits <= budget_left tokens) or the slot's pages
            # (writes k+1 positions, all < slot_len); budget_left == 1
            # degenerates to a plain decode step
            budget_left = r.budget - r.req.tokens_out
            k = min(cfg.spec_k, budget_left - 1, eng.slot_len - 1 - pos)
            if k < 1:
                continue
            synced = eng.draft_synced(slot)
            if not synced and r.seed_tok is None:
                continue            # history unknown: cannot init a draft
            if eng.paged:
                base = self._grow_cost(slot, pos + 1)
                delta = self._grow_cost(slot, pos + k + 1) - base
                pages_avail = self._evict_cache(delta, pages_avail)
                while k >= 1 and (self._grow_cost(slot, pos + k + 1)
                                  - base) > pages_avail:
                    k -= 1          # degrade, never preempt, to fit
                if k < 1:
                    continue
                delta = self._grow_cost(slot, pos + k + 1) - base
                if pos + k + 1 > eng.reserved_tokens(slot):
                    # widen (or introduce) the slot's grow; phase A
                    # already charged ``base`` for its pos+1 entry
                    plan.grows = [(s, u) for s, u in plan.grows
                                  if s != slot]
                    plan.grows.append((slot, pos + k + 1))
                    pages_avail -= delta
            init: Optional[List[int]] = None
            if not synced:
                st = self.streams[r.req.rid]
                toks = self._host_tokens(r)
                init = toks[:r.prompt_len] + (
                    [r.seed_tok] + st[:-1] if st else [])
            plan.spec.append((slot, k, init))
            decodes.remove(slot)
            self._spec_planned[slot] = k
        return pages_avail

    def _preempt(self, slot: int, plan: StepPlan, now: float) -> int:
        """Evict ``slot``: pages free, request requeues, prompt restarts
        on re-admission (vLLM recompute preemption — greedy decode makes
        the restarted stream identical to an uninterrupted one). Any
        action this plan already holds for the slot — a decode, a grow, a
        continuation chunk — is scrubbed: execution frees the slot before
        it would run them. Returns the pages the scrubbed grows had been
        charged, so the caller's availability projection can re-credit
        them (they will never be allocated)."""
        r = self._resident.pop(slot)
        plan.preemptions.append(slot)
        if slot in plan.decodes:
            plan.decodes.remove(slot)
        credit = sum(self._grow_cost(s, u) for s, u in plan.grows
                     if s == slot)
        plan.grows = [(s, u) for s, u in plan.grows if s != slot]
        plan.admissions = [c for c in plan.admissions if c.slot != slot]
        plan.forced = [(s, t) for s, t in plan.forced if s != slot]
        plan.spec = [e for e in plan.spec if e[0] != slot]
        self._spec_planned.pop(slot, None)
        self.metrics.preemptions += 1
        self._tel_event("preempt", r.req, slot=slot)
        self._requeue(r.req)
        return credit

    def _terminate(self, slot: int, r: _Resident, plan: StepPlan, *,
                   cancelled: bool) -> None:
        """Emit a Cancel event for a resident and account its terminal
        cause (client ``cancelled`` or ``deadline_aborted``)."""
        plan.cancels.append(slot)
        self._resident.pop(slot)
        rid = r.req.rid
        self._cancelled.discard(rid)
        self._prompts.pop(rid, None)
        if self.queue is not None:
            if cancelled:
                self.queue.mark_cancelled(r.req)
            else:
                self.queue.abort_deadline(r.req)
        self._tel_event("cancel" if cancelled else "deadline_abort",
                        r.req, slot=slot)

    def _requeue(self, req: Request) -> None:
        """Recompute-requeue: the stream restarts from scratch on
        re-admission (greedy decode makes the replay bit-exact). A rid
        cancelled while it was in flight terminates here instead of
        re-entering the queue — cancellation wins over recovery."""
        rid = req.rid
        self.streams[rid] = []
        req.reset_stream()        # recompute discards streaming progress
        if rid in self._cancelled:
            self._cancelled.discard(rid)
            self._prompts.pop(rid, None)
            if self.queue is not None:
                self.queue.mark_cancelled(req)
            return
        if self.queue is not None:
            self.queue.push(req)
        self.metrics.requeues += 1
        self._tel_event("requeue", req)

    def recover(self, now: float) -> int:
        """Planner half of the engine-reset path (retries exhausted or a
        stuck tick): device slot state is unknown, so drop ALL of it and
        rebuild by recompute. Every resident and staged request requeues
        for a from-scratch re-prefill — the preemption discipline, so
        surviving greedy streams are unchanged — while cancelled rids
        terminate instead; the engine frees every slot and the page-
        conservation audit runs before serving resumes. Returns how many
        requests were requeued or terminated."""
        del now
        n = 0
        for slot, r in sorted(self._resident.items()):
            self._requeue(r.req)
            n += 1
        self._resident.clear()
        pcache = (self.engine.prefix_cache
                  if self.engine is not None else None)
        for r in self._staged:
            # staged alias pins were never consumed (EngineFault fires
            # before the plan mutates anything; a stuck tick never
            # executed) — return them so the engine-reset page audit
            # (free == total after the cache flush) holds
            if r.alias is not None and pcache is not None:
                pcache.release_hit(r.alias)
                r.alias = None
            self._requeue(r.req)
            n += 1
        self._staged = []
        # pending frees are for slots already popped from _resident; the
        # engine-wide release below covers them
        self._to_free = []
        if self.engine is not None:
            self.engine.recover()
        return n

    def _pop_next(self, q, now, drop_expired: bool) -> Optional[Request]:
        """The one queue pop both admission scans share: strict FIFO
        without tiers (``pop_batch(1)`` exactly — bit-identical legacy
        order), else the tiered/tenant-fair keyed pick."""
        adm = self.admission
        if adm is None:
            got = q.pop_batch(1, now, drop_expired)
            return got[0] if got else None
        return q.pop_pick(now, drop_expired, key=adm.key())

    def _note_admitted(self, req: Request, cost: float, q,
                       blocked) -> None:
        """Tiered-admission bookkeeping for a KEPT request: charge the
        tenant and advance the lowest tier's bypass counter over
        everything still waiting (queued + page-blocked this scan)."""
        if self.admission is not None:
            self._tel_event("tier_admit", req, tier=req.tier,
                            tenant=req.tenant)
            self.admission.admitted(
                req, cost, list(q) + list(blocked))

    def _scan_queue(self, eng, q, now, *, max_batch, pages_avail,
                    budget_left) -> List[Tuple]:
        """Tick-plane admission scan: pops requests the projected pages /
        slots / chunk budget can back. Returns
        [(req, batch, budget, first_chunk_len, reserve_tokens, hit,
        host_tokens)] — ``hit`` is a pinned ``PrefixHit`` for alias
        admissions (None otherwise; ``host_tokens`` likewise only
        materialized when the prefix cache looked at the prompt)."""
        cfg = self.config
        cache = self._pcache()
        kept: List[Tuple] = []
        blocked: List[Request] = []
        is_head = True
        while len(kept) < max_batch and budget_left > 0 and len(q):
            req = self._pop_next(q, now, cfg.drop_expired)
            if req is None:
                break
            batch = self._prompts[req.rid]
            p = _prompt_tokens(batch)
            # cannot ever fit — drop loudly rather than spin forever
            # (paged slots need decode room past the prompt; ring slots
            # hold at most slot_len prompt tokens for a packed insert)
            prompt_cap = eng.slot_len - 1 if eng.paged else eng.slot_len
            if p > prompt_cap:
                q.violated += 1
                q.dropped += 1
                self._prompts.pop(req.rid, None)
                is_head = False
                continue
            budget = self._budget_of(req, p)
            if eng.paged and self._pages_for(
                    min(p + budget, eng.slot_len)) > eng.total_pages:
                # full residency exceeds the whole pool: not completable
                # even with every other sequence preempted — drop loudly
                q.violated += 1
                q.dropped += 1
                self._prompts.pop(req.rid, None)
                is_head = False
                continue
            c = int(min(p, budget_left, max(1, eng.slot_len - 1)))
            reserve: Optional[int] = None
            hit = None
            toks: Optional[List[int]] = None
            if cache is not None and eng.paged:
                toks = [int(t) for t in np.asarray(batch["tokens"])[0]]
                hit = cache.match(toks, max_covered=p - 1,
                                  min_covered=self._min_covered(eng, p))
            if eng.paged:
                if hit is not None:
                    # pages for the FRESH tail only: the hit's covered
                    # pages alias at zero page cost (a refcount bump,
                    # not an allocation)
                    horizon = (hit.covered + 1 if cfg.lazy
                               else min(p + budget, eng.slot_len))
                    need = self._pages_for(horizon) - len(hit.pages)
                else:
                    horizon = c if cfg.lazy else min(p + budget,
                                                     eng.slot_len)
                    need = self._pages_for(horizon)
                reserve = horizon
                pages_avail = self._evict_cache(need, pages_avail)
                left = self._page_gate(req, is_head, need, pages_avail)
                if left is None:
                    if hit is not None:
                        # pins return to the cache; the request retries
                        # (and re-matches) on a later scan
                        cache.release_hit(hit)
                        hit = None
                    blocked.append(req)
                    is_head = False
                    continue
                pages_avail = left
            if hit is not None:
                kept.append((req, batch, budget, 0, reserve, hit, toks))
            else:
                kept.append((req, batch, budget, c, reserve, None, toks))
                budget_left -= c
            self._note_admitted(req, p + budget, q, blocked)
            is_head = False
        for req in blocked:
            q.push(req)
        return kept

    # -------------------------------------------- head reservation/aging
    def _page_gate(self, req: Request, is_head: bool, need: int,
                   pages_left: int) -> Optional[int]:
        """The one page-admission gate both scan loops share: checks
        ``need`` against the reservable pages (head reservation/aging
        applied), counts a first-time block in ``blocked_on_memory``,
        and clears a reservation its holder just spent. Returns the new
        pages_left, or None when the request is blocked — keeping this
        in one place is what stops the pool gate and the tick gate from
        drifting."""
        avail = self._reservable(req, is_head, need, pages_left)
        if need > avail:
            if req.rid not in self._blocked_rids:
                self._blocked_rids.add(req.rid)
                self.metrics.blocked_on_memory += 1
            return None
        if req.rid == self._resv_rid:
            self._resv_rid, self._resv_pages = None, 0
        return pages_left - need

    def _reservable(self, req: Request, is_head: bool, need: int,
                    pages_avail: int) -> int:
        """Pages ``req`` may draw on. The FIFO head, when page-blocked,
        accumulates a page reservation that AGES — one page per planning
        scan it stays blocked — and bypassing requests see ``pages_avail``
        minus that reservation. Early on, smaller requests still bypass
        the blocked head (the packing-over-strict-FIFO throughput choice
        is preserved); as the head waits, freed pages increasingly pool
        up for it instead of being re-snatched by an endless stream of
        small requests. The bound from
        ``test_pop_admissible_bypass_is_bounded_by_slo_expiry`` still
        holds — the SLO-expiry backstop is unchanged — but with
        reservation the head typically admits long before it."""
        if not self.config.head_reservation:
            return pages_avail
        if is_head:
            if self._resv_rid is not None and self._resv_rid != req.rid:
                # the reserved request is no longer the head — admitted,
                # expired, or dropped. The reservation is head-scoped:
                # clear it, or its pages would be withheld from every
                # later admission forever
                self._resv_rid, self._resv_pages = None, 0
            if need <= pages_avail:
                # head fits: clear any reservation it accrued
                if self._resv_rid == req.rid:
                    self._resv_rid, self._resv_pages = None, 0
                return pages_avail
            if self._resv_rid != req.rid:
                self._resv_rid, self._resv_pages = req.rid, 0
            self._resv_pages = min(need, self._resv_pages + 1)
            return pages_avail
        if self._resv_rid is None:
            return pages_avail
        return max(0, pages_avail - self._resv_pages)

    # --------------------------------------------------------- feedback
    def observe(self, res: StepResult, now: float) -> List[Request]:
        """Fold one tick's ``StepResult`` back: bind admitted slots,
        record emitted tokens, complete exhausted requests (their slots
        free at the NEXT tick's plan). Returns the completed requests.

        Failure feedback: slots whose lazy grow failed
        (``failed_grows``) recompute-requeue — their slot frees at the
        next tick's plan; a failed admission batch
        (``admission_failed``, all-or-nothing rollback) requeues every
        staged request. Neither loses a request — previously a staged
        rid missing from ``admitted`` silently vanished."""
        for slot in res.failed_grows:
            r = self._resident.pop(slot, None)
            if r is None:
                continue
            self._to_free.append(slot)
            self.metrics.preemptions += 1
            self._requeue(r.req)
        for r in self._staged:
            slot = res.admitted.get(r.req.rid)
            # the engine settled every executed alias either way: an
            # admitted hit's pins now live in the slot's row; a failed
            # one's pins went back via release_hit. Neither is ours to
            # release any more (recover() handles never-executed plans)
            r.alias = None
            if slot is not None:
                self._resident[slot] = r
                self._tel_event("admitted", r.req, slot=slot)
            else:
                self._requeue(r.req)
        self._staged = []
        self._register_prompts()
        eng = self.engine
        if (self.config.spec_k > 0 and eng is not None
                and getattr(eng, "_draft", None) is not None):
            # capture each resident's SEED token (the prefill's argmax,
            # consumed by the first decode step but never emitted) once,
            # before its first decode — it is the one generated token
            # the streams don't record, and rebuilding a draft twin's
            # history after a desync needs it
            for slot, r in self._resident.items():
                if (not r.prefilling and r.seed_tok is None
                        and not self.streams[r.req.rid]):
                    r.seed_tok = eng.host_last_token(slot)
        for slot, toks in res.spec_tokens.items():
            r = self._resident.get(slot)
            if r is None:
                continue
            req = r.req
            if req.first_token < 0:
                req.first_token = now
                self._tel_event("first_token", req)
            req.tokens_out += len(toks)
            self.streams[req.rid].extend(toks)
            if req.tenant:
                tt = self.metrics.tenant_tokens
                tt[req.tenant] = tt.get(req.tenant, 0) + len(toks)
            k = self._spec_planned.pop(slot, None)
            if k:
                # toks = accepted draft tokens + the verify bonus, so
                # acceptance rate for the round is (len-1)/k
                self._spec_accept_ema = (0.9 * self._spec_accept_ema
                                         + 0.1 * (len(toks) - 1) / k)
        for slot, tok in res.tokens.items():
            r = self._resident.get(slot)
            if r is not None:
                req = r.req
                if req.first_token < 0:
                    req.first_token = now
                    self._tel_event("first_token", req)
                req.tokens_out += 1
                self.streams[req.rid].append(tok)
                if req.tenant:
                    tt = self.metrics.tenant_tokens
                    tt[req.tenant] = tt.get(req.tenant, 0) + 1
        completed: List[Request] = []
        for slot in res.done:
            r = self._resident.pop(slot, None)
            if r is None:
                continue
            self._to_free.append(slot)
            completed.append(r.req)
            # completed rids never re-admit: reclaim the prompt arrays
            # (streams stay — they are the tick plane's output surface)
            self._prompts.pop(r.req.rid, None)
        if completed and self.queue is not None:
            self.queue.complete(completed, now)
        for req in completed:
            self._tel_event("complete", req)
        if self.queue is not None:
            # the queue's per-cause counters are the accounting source of
            # truth; the metrics mirror them for PoolResult surfacing
            m = self.metrics
            m.cancelled = self.queue.cancelled
            m.deadline_aborted = self.queue.deadline_aborted
            m.shed = self.queue.shed
        self._reclaim_prompts()
        return completed

    def _reclaim_prompts(self) -> None:
        """Drop prompt arrays for rids no longer live anywhere (queued,
        resident, or staged) — requests SLO-expired inside ``pop_batch``
        would otherwise pin their token arrays forever. Amortized: only
        runs when the map has clearly outgrown the live set."""
        prompts = self._prompts
        if not prompts:
            return
        live_n = (len(self._resident) + len(self._staged)
                  + (len(self.queue) if self.queue is not None else 0))
        if len(prompts) <= max(64, 2 * live_n):
            return
        live = {r.req.rid for r in self._resident.values()}
        live.update(r.req.rid for r in self._staged)
        if self.queue is not None:
            live.update(self.queue.rids())
        for rid in [k for k in prompts if k not in live]:
            del prompts[rid]

    # ---------------------------------------------------- pool admission
    def select_admissible(self, eng, q, prompt_len: int, max_batch: int,
                          now: float, gen_len: int,
                          drop_expired: bool = True
                          ) -> List[Tuple[Request, int]]:
        """The single admission gate ``EnginePool.admit`` AND ``topup``
        share: pop up to ``max_batch`` requests the engine can back — a
        free slot and pages for each request's reserved horizon (whole
        prompt + n_tokens budget, or just the prompt under
        ``PlannerConfig.lazy``). With ``PlannerConfig.tiers`` set, the
        pop order is the tiered/tenant-fair pick (``TieredAdmission``)
        instead of strict FIFO — every gate below is unchanged.
        Requests the pool cannot back go
        straight back to the queue, counted in ``blocked_on_memory``
        once over their lifetime; a page-blocked FIFO head accrues an
        aging page reservation that bypassing smaller requests cannot
        spend (anti-starvation). Returns [(request, token budget)] in
        queue order — except that with the prefix cache on, kept
        requests whose prompts are HOT in the radix cache (a read-only
        ``PrefixCache.peek`` covers at least the ``prefix_min_frac``
        floor) stable-sort ahead of cold ones: a hot admission aliases
        pages instead of prefilling, so serving it first spends strictly
        less of the pool. Pop order — and with it the head-reservation /
        aging anti-starvation contract — is unchanged; only the order
        WITHIN the admitted batch moves."""
        lazy = self.config.lazy
        gen_len = max(1, gen_len)
        room = max(1, eng.slot_len - prompt_len)
        cap = min(max_batch, eng.free_slots)
        pages_left = eng.free_pages
        kept: List[Tuple[Request, int]] = []
        blocked: List[Request] = []
        is_head = True
        # scan deeper than the cap: page-blocked requests must not consume
        # batch quota, or admissible requests behind them under-fill the
        # run in exactly the page-constrained regime paging targets.
        # Blocked requests are re-pushed only AFTER the scan, so the pop
        # can never retrieve the same request twice.
        while len(kept) < cap and len(q):
            req = self._pop_next(q, now, drop_expired)
            if req is None:
                break                       # remainder all expired
            budget = max(1, req.n_tokens if req.n_tokens > 0 else gen_len)
            if eng.paged:
                budget = min(budget, room)
                full = eng.kv_pages_needed(
                    min(prompt_len + budget, eng.slot_len))
                if full > eng.total_pages:
                    # full residency exceeds the whole pool: never
                    # completable — under lazy reservation it would
                    # admit and then preempt-requeue-thrash forever.
                    # Drop loudly instead (same guard as the tick plane)
                    q.violated += 1
                    q.dropped += 1
                    is_head = False
                    continue
                horizon = prompt_len + 1 if lazy else prompt_len + budget
                need = eng.kv_pages_needed(min(horizon, eng.slot_len))
                left = self._page_gate(req, is_head, need, pages_left)
                if left is None:
                    blocked.append(req)
                    is_head = False
                    continue
                pages_left = left
            kept.append((req, budget))
            self._note_admitted(req, prompt_len + budget, q, blocked)
            is_head = False
        for req in blocked:
            q.push(req)
        cache = (getattr(eng, "prefix_cache", None)
                 if self.config.prefix_cache else None)
        if cache is not None and eng.paged and len(kept) > 1:
            # hit-aware ordering: peek is strictly read-only (no clock
            # tick, no LRU touch, no pins) so probing here cannot
            # perturb eviction order or leak references
            floor = self._min_covered(eng, prompt_len)
            hot = []
            for req, _ in kept:
                batch = self._prompts.get(req.rid)
                toks = (None if batch is None else
                        [int(t) for t in np.asarray(batch["tokens"])[0]])
                hot.append(toks is not None and cache.peek(
                    toks, max_covered=prompt_len - 1) >= floor)
            if any(hot) and not all(hot):
                kept = ([rb for rb, h in zip(kept, hot) if h]
                        + [rb for rb, h in zip(kept, hot) if not h])
        return kept

    def admission_plan(self, batches: Sequence[Any],
                       kept: Sequence[Tuple[Request, int]],
                       eng=None) -> StepPlan:
        """Wrap a ``select_admissible`` result as a whole-prompt plan
        (the unchunked admission the pool plane runs). With ``eng``
        passed and the prefix cache on, prompts matching a cached prefix
        become zero-dispatch alias admissions — the pool completes their
        uncovered tail eagerly via ``InferenceEngine.catchup_prefill``
        right after the plan executes (the pool plane has no per-tick
        forced phase to ride)."""
        cache = (eng.prefix_cache
                 if eng is not None and self.config.prefix_cache else None)
        plan = StepPlan()
        for batch, (req, budget) in zip(batches, kept):
            p = _prompt_tokens(batch)
            hit = None
            if cache is not None and eng.paged:
                toks = [int(t) for t in np.asarray(batch["tokens"])[0]]
                hit = cache.match(toks, max_covered=p - 1,
                                  min_covered=self._min_covered(eng, p))
            if hit is not None:
                plan.admissions.append(PrefillChunk(
                    rid=req.rid, batch=batch, start=0, length=0,
                    final=False, n_tokens=budget,
                    reserve_tokens=(hit.covered + 1) if self.config.lazy
                    else None,
                    alias=hit))
                continue
            plan.admissions.append(PrefillChunk(
                rid=req.rid, batch=batch, start=0, length=p, final=True,
                n_tokens=budget,
                reserve_tokens=(p + 1) if self.config.lazy else None))
        return plan


# --------------------------------------------------------------------------
# tick serving loop (EventLoopHooks over the shared core event loop)
# --------------------------------------------------------------------------
class TickServer:
    """Drives one (engine, planner) pair through the shared discrete-event
    loop (``repro.core.eventloop``): arrivals land in the planner's queue,
    and each due tick builds one plan, executes it, and observes the
    result. Virtual time advances ``tick_dt`` per tick; wall time per tick
    is recorded with the decode tokens it emitted, which is exactly the
    time-between-tokens series ``bench_decode --chunked-prefill``
    reports p99 over.

    Fault handling: an attached ``FaultInjector`` (``faults``) can mark a
    tick stuck — the dispatch "hung" and the watchdog killed it — and
    ``execute`` can escalate persistent transient faults to
    ``EngineFault``; both run the same recovery: engine reset +
    recompute-requeue of every resident (``recoveries``/``stuck_ticks``
    count them). ``on_tick`` is a scripting hook ``f(server, now)``
    called before each tick's plan — the chaos suite drives cancellations
    through it. ``stall_limit`` arms a no-progress watchdog: that many
    consecutive ticks with an empty result force a recovery rather than
    spinning forever."""

    def __init__(self, planner: StepPlanner, prompt_fn,
                 tick_dt: float = 1e-3, faults=None, on_tick=None,
                 stall_limit: Optional[int] = None):
        self.planner = planner
        self.prompt_fn = prompt_fn
        self.tick_dt = tick_dt
        self.faults = faults
        self.on_tick = on_tick
        self.stall_limit = stall_limit
        self.ticks = 0
        self.dispatches = 0
        self.peak_resident = 0
        self.stuck_ticks = 0
        self.recoveries = 0            # engine resets (stuck + EngineFault)
        self._no_progress = 0
        # engines persist across servers (warm executables); report fault
        # stats as deltas from this serve's start
        self._retries0 = planner.engine.stats.engine_retries
        self._resets0 = planner.engine.stats.engine_resets
        # (wall seconds, decode tokens emitted) per executed tick
        self.tick_walls: List[Tuple[float, int]] = []
        # prefill tokens COMPUTED per executed tick (the deterministic
        # counterpart of tick_walls: what chunking actually bounds)
        self.tick_prefill: List[int] = []
        self._next_tick = 0.0
        q = planner.queue
        self._track = (f"tick/{q.model}" if q is not None
                       else f"tick/{planner.engine.cfg.name}")

    @property
    def telemetry(self):
        """The planner's telemetry plane (read by the core event loop)."""
        return self.planner.telemetry

    # ----------------------------------------------------- EventLoopHooks
    def deliver(self, req: Request) -> None:
        self.planner.submit(req, self.prompt_fn(req))

    def next_completion(self) -> float:
        return self._next_tick if self.planner.busy() else math.inf

    def next_wakeup(self, now: float) -> float:
        return math.inf

    def advance(self, t: float) -> None:
        pass

    def _mirror_fault_stats(self) -> None:
        stats = self.planner.engine.stats
        m = self.planner.metrics
        m.engine_retries = stats.engine_retries - self._retries0
        m.engine_resets = stats.engine_resets - self._resets0

    def _recover(self, now: float) -> None:
        self.recoveries += 1
        self.planner.recover(now)
        self._mirror_fault_stats()

    def fire(self, now: float, epsilon: float = 1e-12) -> int:
        if not self.planner.busy():
            return 0
        tel = self.planner.telemetry
        if tel is None or tel.trace is None:
            return self._fire(now, None)
        # one span per executed tick on the server's own track; the
        # engine's execute/dispatch spans nest on the engine track
        with tel.trace.span(self._track, "tick", tick=self.ticks):
            return self._fire(now, tel.trace)

    def _fire(self, now: float, trace) -> int:
        import time as _time
        # the tick always reschedules, whatever happens below — a faulted
        # tick that forgot to advance _next_tick would spin the loop at
        # one instant until the max_events backstop
        self._next_tick = now + self.tick_dt
        if self.on_tick is not None:
            self.on_tick(self, now)
        if trace is None:
            plan = self.planner.build(now)
        else:
            with trace.span(self._track, "plan"):
                plan = self.planner.build(now)
        eng = self.planner.engine
        if self.faults is not None and self.faults.stuck():
            # watchdog-killed tick: the plan's bookkeeping was already
            # mutated, but recovery drops ALL in-flight state (residents
            # requeue, engine releases every slot), so the half-built
            # tick leaves no trace
            self.stuck_ticks += 1
            self._recover(now)
            return 1
        pf0 = eng.stats.prefill_tokens
        t0 = _time.perf_counter()
        try:
            res = eng.execute(plan)
        except EngineFault:
            self._recover(now)
            return 1
        wall = _time.perf_counter() - t0
        self.planner.observe(res, now)
        self.ticks += 1
        self.dispatches += res.dispatches
        self.peak_resident = max(self.peak_resident,
                                 eng.n_slots - eng.free_slots)
        self.tick_walls.append((wall, len(res.tokens)))
        self.tick_prefill.append(eng.stats.prefill_tokens - pf0)
        self._mirror_fault_stats()
        progress = bool(res.tokens or res.done or res.admitted
                        or res.failed_grows or plan.admissions
                        or plan.forced or plan.frees or plan.cancels
                        or plan.preemptions)
        if progress:
            self._no_progress = 0
        elif self.stall_limit is not None:
            self._no_progress += 1
            if self._no_progress >= self.stall_limit:
                # the loop is live but the plane is wedged (should be
                # impossible — the planner's stall-breaker preempts
                # first); reset rather than spin forever
                self._recover(now)
                self._no_progress = 0
        return 1

    def plan(self, now: float) -> None:
        if self._next_tick <= now and self.planner.busy():
            self._next_tick = now + self.tick_dt

    def drained(self) -> bool:
        return not self.planner.busy()


def serve_ticks(planner: StepPlanner, requests: Sequence[Request],
                prompt_fn, *, max_ticks: int = 100_000, faults=None,
                on_tick=None, stall_limit: Optional[int] = None
                ) -> TickServer:
    """Convenience driver: serve ``requests`` (arrivals honored in
    virtual tick time) to completion through the plan API. Returns the
    ``TickServer`` whose ``planner.streams`` holds every request's
    emitted tokens and whose ``tick_walls`` holds the TBT series.
    ``faults``/``on_tick``/``stall_limit`` pass through to the server —
    the chaos harness's entry point."""
    from repro.core.eventloop import LoopConfig, run_event_loop

    server = TickServer(planner, prompt_fn, faults=faults, on_tick=on_tick,
                        stall_limit=stall_limit)

    class _Listed:
        """Adapter: materialize_arrivals expects generator-likes."""
        rate = 0.0

        def __init__(self, reqs):
            self._reqs = list(reqs)

        def until(self, t_end):
            out = [r for r in self._reqs if r.arrival < t_end]
            self._reqs = [r for r in self._reqs if r.arrival >= t_end]
            return out

    horizon = max((r.arrival for r in requests), default=0.0) + 1e-6
    out = run_event_loop(
        LoopConfig(duration=horizon, drain=True, arrival_horizon=horizon,
                   max_time=math.inf, max_events=max_ticks),
        [_Listed(requests)], server)
    server.truncated = out.truncated
    return server
