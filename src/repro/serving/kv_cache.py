"""Paged KV-cache management: a page pool, block tables, and ragged lengths.

Why paging (the memory-side dual of D-STACK's packing argument)
---------------------------------------------------------------
The slot engine's original storage contract gave every slot a fixed-length
ring: a sequence that generates 12 tokens pays the same KV memory as one
that generates 512, so KV capacity — not compute — caps how many concurrent
DNN instances the accelerator multiplexes (``EnginePool.admit`` blocks on
free slots). The paged layout replaces the per-slot ring with a shared pool
of fixed-size **pages** so long and short sequences share cache memory and
memory in use tracks the tokens actually resident.

Block-table layout (vLLM PagedAttention; on TPU, ``ragged_paged_attention``)
---------------------------------------------------------------------------
A paged cache is a pytree of ``(num_pages, page_size, ...)`` K/V buffers —
the *physical* pool — plus two small per-sequence arrays:

  ``block_tables``  (B, max_pages) int32   logical page i of row b lives in
                                           physical page block_tables[b, i]
  ``lengths``       (B,)           int32   valid tokens per row (the cache's
                                           ``pos`` vector in the engine)

Logical cache position ``t`` of row ``b`` is stored at
``(block_tables[b, t // page_size], t % page_size)``. The decode kernel
(``repro.kernels.paged_attention``) walks each row's table in logical order
via scalar-prefetched index maps, skipping pages past the row's length, so
both FLOPs and HBM traffic scale with actual sequence length.

Physical page 0 is the reserved **null page**: the allocator never hands it
out, freed rows point their whole table row at it, and vacant
continuous-batching rows harmlessly scatter their dead writes into it
(length 0 masks every read). That preserves the ring engine's "vacant rows
cost nothing and corrupt nothing" invariant even though pages — unlike ring
rows — are shared across sequences.

``PageAllocator`` is the host-side free list (admission control reads
``free_pages``); ``PagedKVCache`` wraps one model's device buffers with
alloc / append / free and raises ``OutOfPages`` as the admission-blocking
signal. The serving engine embeds the same pieces directly
(``InferenceEngine.init_slots(paged=True)``); this module is the layer the
engine, pool admission, and tests all share.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

NULL_PAGE = 0


class OutOfPages(RuntimeError):
    """The page pool cannot satisfy an allocation — the admission-control
    signal: callers (``EnginePool.admit``) must defer or shrink the batch,
    not crash."""


def pages_for(tokens: int, page_size: int) -> int:
    """Pages needed to hold ``tokens`` cache entries (at least one — every
    live sequence owns a page so its writes never touch the null page)."""
    return max(1, math.ceil(max(0, int(tokens)) / page_size))


class PageAllocator:
    """Host-side free list over a pool of ``num_pages`` usable pages.

    Page ids are 1..num_pages — id 0 is the reserved null page (see module
    docstring). Frees are LIFO so a free-then-alloc churn reuses hot pages;
    fragmentation is a non-issue because every page is the same size and
    tables provide full indirection (there is nothing contiguous to
    fragment — the classic paging argument)."""

    def __init__(self, num_pages: int):
        if num_pages < 1:
            raise ValueError(f"need at least one usable page, got {num_pages}")
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages, 0, -1))  # pop() -> 1 first
        self._allocated: set = set()
        # per-page reference counts (prefix sharing): every allocated page
        # has a count >= 1; ``share`` adds holders, ``release`` drops them
        # and returns the page to the pool at zero. ``free`` stays the
        # strict single-owner path (it refuses shared pages), so legacy
        # callers cannot silently tear a page out from under a co-holder.
        self._ref: Dict[int, int] = {}
        # duck-typed hook (repro.serving.faults.FaultInjector): when set,
        # alloc may raise an injected OutOfPages before touching the pool
        self.fault_injector = None

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> List[int]:
        """Pop ``n`` pages, all-or-nothing. Raises OutOfPages when the pool
        cannot cover the request (no partial grants — a half-allocated
        sequence would deadlock against other half-allocated sequences)."""
        if self.fault_injector is not None:
            self.fault_injector.maybe_fault("alloc")
        if n > len(self._free):
            raise OutOfPages(
                f"requested {n} pages, {len(self._free)} free "
                f"of {self.num_pages}")
        pages = [self._free.pop() for _ in range(n)]
        self._allocated.update(pages)
        for p in pages:
            self._ref[p] = 1
        return pages

    def free(self, pages: Sequence[int]) -> None:
        """Return pages to the pool. Double-frees, frees of the null page,
        and frees of a page another holder still references are errors
        (they would alias two sequences onto one page)."""
        for p in pages:
            if p == NULL_PAGE:
                raise ValueError("cannot free the reserved null page")
            if p not in self._allocated:
                raise ValueError(f"page {p} is not allocated")
            if self._ref.get(p, 1) != 1:
                raise ValueError(
                    f"page {p} has {self._ref[p]} holders — use release()")
            self._ref.pop(p, None)
            self._allocated.remove(p)
            self._free.append(p)

    # ------------------------------------------------------ prefix sharing
    def refcount(self, page: int) -> int:
        """Current holder count for a page (0 when not allocated)."""
        return self._ref.get(page, 0)

    def share(self, pages: Sequence[int]) -> None:
        """Add one holder to each page (prefix-cache aliasing). Sharing an
        unallocated page or the null page is an error — a holder can only
        piggyback on a page that already has an owner."""
        for p in pages:
            if p == NULL_PAGE:
                raise ValueError("cannot share the reserved null page")
            if p not in self._allocated:
                raise ValueError(f"page {p} is not allocated")
            self._ref[p] += 1

    def release(self, pages: Sequence[int]) -> int:
        """Drop one holder from each page; pages whose count reaches zero
        return to the pool. Returns how many pages were actually freed
        (the planner's eviction loop needs real pages, not dropped refs)."""
        freed = 0
        for p in pages:
            if p == NULL_PAGE:
                raise ValueError("cannot release the reserved null page")
            if p not in self._allocated:
                raise ValueError(f"page {p} is not allocated")
            self._ref[p] -= 1
            if self._ref[p] == 0:
                del self._ref[p]
                self._allocated.remove(p)
                self._free.append(p)
                freed += 1
        return freed

    def sort_free(self) -> None:
        """Restore the canonical free-list order (descending ids, so
        ``pop()`` hands out 1 first — the just-built state). Called on
        engine reset between runs: frees are LIFO, so the free list's
        order is otherwise a fossil of the previous run's free sequence
        and a replayed workload would receive different page ids."""
        self._free.sort(reverse=True)

    def check_invariants(self) -> bool:
        """Cheap host-side audit of the free list: page conservation, no
        duplicates, null page never live, every id in range. Raises
        AssertionError on violation — the chaos suite and hypothesis churn
        tests call this after every operation and every fault recovery."""
        free = self._free
        assert len(free) == len(set(free)), "duplicate page in free list"
        assert NULL_PAGE not in free, "null page in free list"
        assert NULL_PAGE not in self._allocated, "null page marked allocated"
        assert not set(free) & self._allocated, \
            "page simultaneously free and allocated"
        assert len(free) + len(self._allocated) == self.num_pages, (
            f"page conservation violated: {len(free)} free + "
            f"{len(self._allocated)} allocated != {self.num_pages}")
        assert all(1 <= p <= self.num_pages
                   for p in list(free) + list(self._allocated)), \
            "page id out of range"
        assert set(self._ref) == self._allocated, (
            "refcount keys and allocated set disagree: "
            f"{sorted(set(self._ref) ^ self._allocated)}")
        assert all(c >= 1 for c in self._ref.values()), \
            "allocated page with refcount < 1"
        return True


@dataclasses.dataclass
class SeqPages:
    """One sequence's page ownership: its table prefix and valid length."""
    pages: List[int]
    length: int


class PagedKVCache:
    """Block-table bookkeeping for one paged cache (host side).

    Tracks, per batch row, the ordered pages that row owns and its valid
    length; the device pytree (K/V page buffers + ``block_tables`` +
    ``pos``) is built by each model family's ``init_paged_cache`` and
    updated by the engine's jitted scatter helpers — this class is the
    source of truth the engine mirrors into those device arrays.
    """

    def __init__(self, batch: int, page_size: int, max_pages: int,
                 allocator: Optional[PageAllocator] = None,
                 num_pages: Optional[int] = None):
        if allocator is None:
            allocator = PageAllocator(num_pages or batch * max_pages)
        self.allocator = allocator
        self.batch = batch
        self.page_size = page_size
        self.max_pages = max_pages
        self._rows: Dict[int, SeqPages] = {}
        # bumps on every page-ownership change — an O(1) cache key for
        # host-side structures derived from page layouts (e.g. the
        # speculative rounds' uploaded block-table rows)
        self.version = 0

    # ------------------------------------------------------------- queries
    @property
    def free_pages(self) -> int:
        return self.allocator.free_pages

    @property
    def used_pages(self) -> int:
        return self.allocator.used_pages

    def length(self, row: int) -> int:
        sp = self._rows.get(row)
        return 0 if sp is None else sp.length

    def pages(self, row: int) -> List[int]:
        sp = self._rows.get(row)
        return [] if sp is None else list(sp.pages)

    def table_row(self, row: int) -> List[int]:
        """Full (max_pages,) table row: owned pages then null-page padding
        — a fixed shape, so the device-side row write never retraces."""
        pages = self.pages(row)
        return pages + [NULL_PAGE] * (self.max_pages - len(pages))

    def pages_needed(self, tokens: int) -> int:
        return pages_for(tokens, self.page_size)

    def can_admit(self, tokens: int) -> bool:
        return self.allocator.can_alloc(self.pages_needed(tokens))

    # ------------------------------------------------------------ mutation
    def alloc(self, row: int, tokens: int) -> List[int]:
        """Claim a free row and allocate pages for ``tokens`` entries
        (all-or-nothing; raises OutOfPages)."""
        if row in self._rows:
            raise ValueError(f"row {row} already allocated")
        tokens = int(tokens)
        if tokens > self.max_pages * self.page_size:
            raise OutOfPages(
                f"{tokens} tokens exceed the row maximum "
                f"{self.max_pages * self.page_size}")
        pages = self.allocator.alloc(self.pages_needed(tokens))
        self._rows[row] = SeqPages(pages=pages, length=tokens)
        self.version += 1
        return pages

    def alloc_alias(self, row: int, shared_pages: Sequence[int],
                    tokens: int) -> List[int]:
        """Claim a free row whose leading pages alias an already-resident
        prefix (prefix-cache hit). The caller must ALREADY hold one
        reference per shared page (``PageAllocator.share`` — the match-time
        pin); this call adopts those references as the row's ownership and
        allocates only the fresh tail pages, all-or-nothing. On
        ``OutOfPages`` nothing changes and the caller keeps its pins."""
        if row in self._rows:
            raise ValueError(f"row {row} already allocated")
        tokens = int(tokens)
        if tokens > self.max_pages * self.page_size:
            raise OutOfPages(
                f"{tokens} tokens exceed the row maximum "
                f"{self.max_pages * self.page_size}")
        shared = list(shared_pages)
        need = self.pages_needed(tokens) - len(shared)
        if need < 1:
            raise ValueError(
                f"aliased prefix ({len(shared)} pages) already covers "
                f"{tokens} tokens — nothing left to write")
        fresh = self.allocator.alloc(need)
        self._rows[row] = SeqPages(pages=shared + fresh, length=tokens)
        self.version += 1
        return fresh

    def append(self, row: int, n: int = 1) -> List[int]:
        """Advance row's length by ``n`` token slots, allocating new pages
        lazily as page boundaries are crossed. Returns the newly allocated
        pages (often empty — within-page appends are free). Raises
        OutOfPages with the row untouched when the pool can't cover it."""
        sp = self._rows.get(row)
        if sp is None:
            raise ValueError(f"row {row} has no pages (alloc first)")
        new_len = sp.length + int(n)
        if new_len > self.max_pages * self.page_size:
            raise OutOfPages(
                f"row {row}: {new_len} tokens exceed the row maximum "
                f"{self.max_pages * self.page_size}")
        need = pages_for(new_len, self.page_size) - len(sp.pages)
        fresh = self.allocator.alloc(need) if need > 0 else []
        if fresh:
            self.version += 1
        sp.pages.extend(fresh)
        sp.length = new_len
        return fresh

    def repoint(self, row: int, swaps: Sequence[Tuple[int, int]]) -> int:
        """Swap the row's page reference at each ``(index, new_page)``
        onto an already-allocated page holding identical content
        (cross-request prefix dedup): the row takes one reference on
        the new page and drops the one on the page it replaces.
        Returns how many replaced pages actually returned to the pool.
        The CALLER owns the equality argument (identical token prefix
        → bit-identical K/V) and must push the updated block-table row
        to the device afterwards."""
        sp = self._rows.get(row)
        if sp is None:
            raise ValueError(f"row {row} has no pages")
        freed = 0
        changed = False
        for idx, new in swaps:
            old = sp.pages[idx]
            if old == new:
                continue
            self.allocator.share([new])
            freed += self.allocator.release([old])
            sp.pages[idx] = int(new)
            changed = True
        if changed:
            self.version += 1
        return freed

    def free(self, row: int) -> int:
        """Drop the row's reference on every page it owns; returns how
        many pages actually returned to the pool (aliased prefix pages
        stay resident while the radix cache or another row still holds
        them). Idempotent for unknown rows (mirrors the engine's ``free``
        contract)."""
        sp = self._rows.pop(row, None)
        if sp is None:
            return 0
        self.version += 1
        return self.allocator.release(sp.pages)

    def reset(self) -> None:
        for row in list(self._rows):
            self.free(row)

    def check_invariants(self,
                         extra_refs: Optional[Dict[int, int]] = None) -> bool:
        """Audit row-level ownership on top of the allocator's free-list
        audit: every live row's page count matches its length, and page
        references are exactly conserved — for every allocated page, the
        number of rows holding it plus ``extra_refs`` (external holders:
        the prefix cache's ``page_refs()``) equals the allocator's
        refcount. Without sharing this degenerates to the historical
        contract (no page aliased by two rows, rows == allocated set);
        with sharing it is strictly stronger: a leaked reference, a
        dangling alias, and cross-request aliasing without a matching
        holder all trip it."""
        self.allocator.check_invariants()
        held: Dict[int, int] = dict(extra_refs or {})
        for row, sp in self._rows.items():
            assert sp.pages, f"live row {row} owns no pages"
            assert NULL_PAGE not in sp.pages, f"row {row} owns the null page"
            assert len(sp.pages) == pages_for(sp.length, self.page_size), (
                f"row {row}: {len(sp.pages)} pages for {sp.length} tokens")
            assert len(sp.pages) == len(set(sp.pages)), (
                f"row {row} lists a page twice")
            for p in sp.pages:
                held[p] = held.get(p, 0) + 1
        assert set(held) <= self.allocator._allocated, (
            "dangling alias: held pages not allocated "
            f"{sorted(set(held) - self.allocator._allocated)}")
        for p in self.allocator._allocated:
            refs = self.allocator.refcount(p)
            assert held.get(p, 0) == refs, (
                f"page {p}: {held.get(p, 0)} holders accounted "
                f"(rows + extra_refs) vs allocator refcount {refs}")
        return True
