"""Inference requests and SLO-aware batch assembly.

Mirrors the paper's workload model (§5/§7): requests arrive for a named
model at some rate; the batcher assembles up to ``batch_size`` requests, and
the scheduler must finish ``assembly + inference`` within the SLO (paper
Eq. 11), keeping inference itself under SLO/2 (Eq. 12).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional


@dataclasses.dataclass(order=True)
class Request:
    arrival: float
    rid: int = dataclasses.field(compare=False)
    model: str = dataclasses.field(compare=False)
    slo: float = dataclasses.field(compare=False)          # seconds
    # decode tokens this request wants. 0 means "scheduler default"
    # (ControllerConfig.gen_len); a positive value is honored as the
    # slot's per-request token budget — mixed values make runs ragged,
    # free slots early, and shrink the pages the request pins.
    n_tokens: int = dataclasses.field(compare=False, default=0)
    # prompt tokens this request carries. 0 means "caller default" (the
    # pool plane's uniform host prompt_len); a positive value lets the
    # tick plane (repro.serving.plan) synthesize per-request prompt
    # lengths — long prompts are what chunked prefill splits across ticks.
    prompt_len: int = dataclasses.field(compare=False, default=0)
    # lifecycle terminal cause:
    #   pending -> completed | cancelled | deadline_aborted | shed
    # "pending" covers queued/resident/requeued — a request has no
    # intermediate persisted state because preemption and engine resets
    # recompute from scratch. The queue's per-cause counters (not this
    # field) are the accounting source of truth; state is introspection.
    state: str = dataclasses.field(compare=False, default="pending")
    # streaming progress: virtual time the FIRST decode token was
    # observed (-1.0 = none yet) and tokens emitted so far. Reset on
    # every requeue (preemption / failed grow / engine reset) — recompute
    # discards emitted tokens, so TTFT is the time to the first token of
    # the attempt that actually completed, matching what a streaming
    # client replaying the stream would see.
    first_token: float = dataclasses.field(compare=False, default=-1.0)
    tokens_out: int = dataclasses.field(compare=False, default=0)
    # multi-tenant serving (ISSUE 10): the submitting tenant ("" = the
    # single-tenant planes, which never read it) and the priority tier.
    # Tier names are free-form; the planner's TieredAdmission maps them
    # to weights (interactive > standard > batch by default) and falls
    # back to the default tier's weight for unknown names.
    tenant: str = dataclasses.field(compare=False, default="")
    tier: str = dataclasses.field(compare=False, default="standard")
    # virtual/wall time the request completed (-1.0 = not completed) —
    # lets post-hoc analysis (the traffic bench's per-tier SLO
    # attainment) join finish vs deadline without replaying counters.
    finish: float = dataclasses.field(compare=False, default=-1.0)

    @property
    def deadline(self) -> float:
        return self.arrival + self.slo

    def reset_stream(self) -> None:
        """Forget streaming progress on requeue-for-recompute."""
        self.first_token = -1.0
        self.tokens_out = 0


class RequestQueue:
    """Per-model FIFO with SLO accounting."""

    def __init__(self, model: str, slo: float, track_latency: bool = True):
        self.model = model
        self.slo = slo
        self.track_latency = track_latency
        self._q: List[Request] = []
        self.completed = 0
        self.violated = 0      # dropped + late + aborted + shed
        self.dropped = 0       # expired before ever being scheduled
        self.late = 0          # served, but finished past the deadline
        # per-cause terminal counters (ISSUE 6): with `completed` and
        # `dropped` these partition every request that ever entered the
        # serving plane — the chaos suite asserts they sum to offered load
        self.cancelled = 0         # client cancel (not an SLO violation)
        self.deadline_aborted = 0  # evicted while resident, past deadline
        self.shed = 0              # refused at admission (overload)
        # arrival -> completion latency of every SERVED request — feeds
        # p50/p99 reporting (paper §7 tables). O(completed) memory, so the
        # analytic simulator (which never reads it) opts out.
        self.latencies: List[float] = []
        # TTFT (arrival → first token) per terminal cause, and mean
        # time-between-tokens for completed requests — the streaming
        # latency figures end-to-end latency hides (a chunked-prefill win
        # shows up here, not in `latencies`). Same track_latency opt-out.
        self.ttft_by_cause: Dict[str, List[float]] = {}
        self.tbts: List[float] = []

    def push(self, req: Request) -> None:
        # (re-)entering the queue always discards streaming progress:
        # requeued requests recompute from scratch, and test harnesses
        # re-serve the same Request objects across runs
        req.reset_stream()
        heapq.heappush(self._q, req)

    def __len__(self) -> int:
        return len(self._q)

    def oldest_deadline(self, default: float = float("inf")) -> float:
        return self._q[0].deadline if self._q else default

    def rids(self) -> set:
        """Rids currently queued — lets callers holding per-rid side
        state (the StepPlanner's prompt arrays) reclaim entries whose
        requests were dropped inside ``pop_batch``."""
        return {r.rid for r in self._q}

    def pop_batch(self, max_batch: int, now: float,
                  drop_expired: bool = True) -> List[Request]:
        """Pop up to ``max_batch`` requests; count already-expired as violations."""
        batch: List[Request] = []
        while self._q and len(batch) < max_batch:
            req = heapq.heappop(self._q)
            if drop_expired and req.deadline < now:
                req.state = "deadline_aborted"
                self.dropped += 1
                self.violated += 1
                continue
            batch.append(req)
        return batch

    def pop_pick(self, now: float, drop_expired: bool = True,
                 key=None) -> Optional[Request]:
        """Pop ONE request chosen by ``key`` (lowest key wins) instead of
        strict FIFO — the tiered-admission hook (ISSUE 10). Expired
        requests are dropped with the same accounting as ``pop_batch``
        regardless of key. ``key=None`` degenerates to ``pop_batch(1)``
        exactly (heap order: arrival). The keyed pick is an O(n) scan
        plus the same swap-with-last removal ``cancel`` uses — admission
        scans pop a handful per tick, so n stays small."""
        if key is None:
            got = self.pop_batch(1, now, drop_expired)
            return got[0] if got else None
        while self._q:
            best = min(range(len(self._q)), key=lambda i: key(self._q[i]))
            req = self._q[best]
            last = self._q.pop()
            if best < len(self._q):
                self._q[best] = last
                heapq.heapify(self._q)
            if drop_expired and req.deadline < now:
                req.state = "deadline_aborted"
                self.dropped += 1
                self.violated += 1
                continue
            return req
        return None

    def __iter__(self):
        """Iterate queued requests (heap order, NOT sorted) — read-only
        introspection for admission policies (starvation tracking)."""
        return iter(self._q)

    @property
    def ttfts(self) -> List[float]:
        """TTFT samples of COMPLETED requests (the headline figure)."""
        return self.ttft_by_cause.get("completed", [])

    def _record_ttft(self, cause: str, req: Request) -> None:
        if self.track_latency and req.first_token >= req.arrival:
            self.ttft_by_cause.setdefault(cause, []).append(
                req.first_token - req.arrival)

    # ------------------------------------------- lifecycle terminal causes
    def cancel(self, rid: int) -> Optional[Request]:
        """Remove a still-QUEUED request by rid (client disconnect before
        admission). Returns the request, or None if the rid is not queued
        — resident requests are cancelled through the planner/pool, which
        must also free their pages."""
        for i, r in enumerate(self._q):
            if r.rid == rid:
                last = self._q.pop()
                if i < len(self._q):
                    self._q[i] = last
                    heapq.heapify(self._q)
                self.mark_cancelled(r)
                return r
        return None

    def mark_cancelled(self, req: Request) -> None:
        """Terminal accounting for a client cancel. Not an SLO violation:
        the client walked away, the system didn't fail it."""
        req.state = "cancelled"
        self.cancelled += 1
        self._record_ttft("cancelled", req)

    def abort_deadline(self, req: Request) -> None:
        """Terminal accounting for a resident evicted past its deadline —
        an SLO violation (the system held it too long)."""
        req.state = "deadline_aborted"
        self.deadline_aborted += 1
        self.violated += 1
        self._record_ttft("deadline_aborted", req)

    def shed_request(self, req: Request) -> None:
        """Terminal accounting for a request refused at admission under
        overload — counted as a violation (the system couldn't serve it)
        but cheap: it failed fast instead of timing out resident."""
        req.state = "shed"
        self.shed += 1
        self.violated += 1

    def complete(self, batch: List[Request], finish_time: float) -> None:
        """Record served requests: completion latency (arrival→complete)
        always, and a violation for every late-but-served completion —
        serving a request past its deadline is an SLO miss just like
        dropping it (paper Eq. 11 counts end-to-end latency)."""
        for req in batch:
            req.state = "completed"
            req.finish = finish_time
            self.completed += 1
            if self.track_latency:
                self.latencies.append(finish_time - req.arrival)
                self._record_ttft("completed", req)
                if req.tokens_out > 1 and req.first_token >= 0:
                    self.tbts.append((finish_time - req.first_token)
                                     / (req.tokens_out - 1))
            if finish_time > req.deadline:
                self.late += 1
                self.violated += 1

    def latency_quantile(self, q: float,
                         default: float = float("nan")) -> float:
        """Nearest-rank quantile of served completion latencies (q in
        [0, 1]); ``default`` when nothing completed yet."""
        from repro.serving.metrics import percentile
        return percentile(self.latencies, q, default)


def materialize_arrivals(generators, horizon: float,
                         drain: bool = False) -> List[Request]:
    """Materialize every generator's arrivals in [0, horizon), sorted.

    Shared by the analytic simulator and the engine-pool controller so
    drain/horizon semantics cannot diverge: a drain run over rate-based
    generators that produced no arrivals is an error (the pre-fix
    simulator silently simulated an empty workload)."""
    arrivals: List[Request] = []
    for g in generators:
        arrivals.extend(g.until(max(horizon, 1e-9)))
    if drain and not arrivals and any(
            getattr(g, "rate", 0) > 0 for g in generators):
        raise ValueError(
            "drain=True with rate-based generators produced no arrivals; "
            "set arrival_horizon (or duration) > 0")
    arrivals.sort(key=lambda r: r.arrival)
    return arrivals


class RequestGenerator:
    """Deterministic arrival stream (uniform-jittered, like the paper §6.3).

    ``gen_tokens`` stamps each request's decode budget (``n_tokens``): an
    int for a uniform workload, a ``(lo, hi)`` pair for a mixed-length
    stream (budget drawn uniformly, inclusive, from the same seeded rng as
    the arrival jitter — fully reproducible), or None to leave requests on
    the scheduler default. ``prompt_tokens`` stamps ``prompt_len`` the
    same way — per-request prompt lengths are what make chunked prefill
    (``repro.serving.plan``) and packed ragged prefill earn their keep."""

    def __init__(self, model: str, rate_per_s: float, slo: float,
                 seed: int = 0, gen_tokens=None, prompt_tokens=None):
        import numpy as np
        self.model = model
        self.rate = rate_per_s
        self.slo = slo
        self.gen_tokens = gen_tokens
        self.prompt_tokens = prompt_tokens
        self._rng = np.random.default_rng(seed)
        self._next_id = 0
        self._t = 0.0

    def _draw(self, spec) -> int:
        if spec is None:
            return 0
        if isinstance(spec, int):
            return max(1, spec)
        lo, hi = spec
        return int(self._rng.integers(max(1, lo), max(1, hi) + 1))

    def _draw_tokens(self) -> int:
        return self._draw(self.gen_tokens)

    def until(self, t_end: float) -> List[Request]:
        """All requests arriving in [current position, t_end)."""
        out: List[Request] = []
        if self.rate <= 0:
            self._t = t_end
            return out
        mean_gap = 1.0 / self.rate
        while True:
            # uniformly-distributed inter-arrival in [0.5, 1.5]·mean (paper §6.3)
            gap = mean_gap * self._rng.uniform(0.5, 1.5)
            if self._t + gap >= t_end:
                self._t = t_end
                break
            self._t += gap
            out.append(Request(arrival=self._t, rid=self._next_id,
                               model=self.model, slo=self.slo,
                               n_tokens=self._draw_tokens(),
                               prompt_len=self._draw(self.prompt_tokens)))
            self._next_id += 1
        return out

    def set_rate(self, rate_per_s: float) -> None:
        self.rate = rate_per_s
