"""Inference requests and SLO-aware batch assembly.

Mirrors the paper's workload model (§5/§7): requests arrive for a named
model at some rate; the batcher assembles up to ``batch_size`` requests, and
the scheduler must finish ``assembly + inference`` within the SLO (paper
Eq. 11), keeping inference itself under SLO/2 (Eq. 12).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional


@dataclasses.dataclass(order=True)
class Request:
    arrival: float
    rid: int = dataclasses.field(compare=False)
    model: str = dataclasses.field(compare=False)
    slo: float = dataclasses.field(compare=False)          # seconds
    n_tokens: int = dataclasses.field(compare=False, default=1)

    @property
    def deadline(self) -> float:
        return self.arrival + self.slo


class RequestQueue:
    """Per-model FIFO with SLO accounting."""

    def __init__(self, model: str, slo: float):
        self.model = model
        self.slo = slo
        self._q: List[Request] = []
        self.completed = 0
        self.violated = 0
        self.dropped = 0

    def push(self, req: Request) -> None:
        heapq.heappush(self._q, req)

    def __len__(self) -> int:
        return len(self._q)

    def oldest_deadline(self, default: float = float("inf")) -> float:
        return self._q[0].deadline if self._q else default

    def pop_batch(self, max_batch: int, now: float,
                  drop_expired: bool = True) -> List[Request]:
        """Pop up to ``max_batch`` requests; count already-expired as violations."""
        batch: List[Request] = []
        while self._q and len(batch) < max_batch:
            req = heapq.heappop(self._q)
            if drop_expired and req.deadline < now:
                self.dropped += 1
                self.violated += 1
                continue
            batch.append(req)
        return batch

    def complete(self, batch: List[Request], finish_time: float) -> None:
        for req in batch:
            self.completed += 1
            if finish_time > req.deadline:
                self.violated += 1


class RequestGenerator:
    """Deterministic arrival stream (uniform-jittered, like the paper §6.3)."""

    def __init__(self, model: str, rate_per_s: float, slo: float, seed: int = 0):
        import numpy as np
        self.model = model
        self.rate = rate_per_s
        self.slo = slo
        self._rng = np.random.default_rng(seed)
        self._next_id = 0
        self._t = 0.0

    def until(self, t_end: float) -> List[Request]:
        """All requests arriving in [current position, t_end)."""
        out: List[Request] = []
        if self.rate <= 0:
            self._t = t_end
            return out
        mean_gap = 1.0 / self.rate
        while True:
            # uniformly-distributed inter-arrival in [0.5, 1.5]·mean (paper §6.3)
            gap = mean_gap * self._rng.uniform(0.5, 1.5)
            if self._t + gap >= t_end:
                self._t = t_end
                break
            self._t += gap
            out.append(Request(arrival=self._t, rid=self._next_id,
                               model=self.model, slo=self.slo))
            self._next_id += 1
        return out

    def set_rate(self, rate_per_s: float) -> None:
        self.rate = rate_per_s
