"""Controller: the serving loop that lets a Policy drive the EnginePool.

Discrete-event execution (paper §6): the controller owns a virtual clock;
events are request arrivals, engine decode steps, and policy session
wakeups. At every event it drains arrivals into the per-model queues, steps
the engines whose next decode is due (each step is ONE real jitted
dispatch over all of that engine's slots), and asks the policy to ``plan``
against the pool's SchedView — translating each ``RunRequest`` into an
admission on a pre-built standby engine via ``EnginePool.admit``.

Every data-plane action under this loop routes through the declarative
plan API (``repro.serving.plan``): admissions and topups are StepPlans
built by the model's ``StepPlanner`` (one shared admission gate — page
horizon, SLO expiry, head reservation) and decode steps execute as
``StepPlan(decodes=...)``, so the pool plane and the tick plane
(``TickServer``) cannot diverge in engine semantics. Pools built with
``lazy_kv=True`` additionally reserve pages lazily and preempt-and-
requeue on ``OutOfPages`` mid-run (``preemptions``/``requeues`` in
``PoolMetrics``) — see ``docs/serving_api.md``.

Virtual time advances by the profile roofline latency of each run at its
*granted* allocation, so SLO accounting, session boundaries, and policy
comparisons are deterministic and paper-comparable on a one-core host —
while the data plane underneath executes the real slot-batched decode hot
path. Wall-clock time of the whole schedule is reported alongside.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.eventloop import LoopConfig, run_event_loop
from repro.serving.metrics import PoolResult
from repro.serving.pool import EnginePool
from repro.serving.request import Request, RequestGenerator


@dataclasses.dataclass
class ControllerConfig:
    duration: float = 1.0           # virtual seconds (ignored when drain)
    gen_len: int = 4                # default decode tokens per request —
                                    # a request's own n_tokens overrides it
    drain: bool = False             # run until all queued work completes
    drop_expired: bool = True
    # mid-run re-admission: when ragged n_tokens budgets free a run's slot
    # early, refill it from the queue without waiting for the run (or the
    # policy). Uniform-budget workloads never trip it (no early frees).
    topup: bool = True
    # horizon up to which rate generators materialize arrivals; None ->
    # ``duration`` (drain runs MUST set one of them, like the simulator)
    arrival_horizon: Optional[float] = None
    max_steps: int = 500_000        # safety valve on real dispatches
    # virtual-time backstop (mirrors SimConfig.max_time): bounds drain
    # runs where a policy keeps waking but nothing is ever admitted
    max_time: float = 600.0


class Controller:
    def __init__(self, pool: EnginePool, policy,
                 generators: Sequence[RequestGenerator],
                 cfg: Optional[ControllerConfig] = None, on_plan=None):
        self.pool = pool
        self.policy = policy
        self.generators = list(generators)
        self.cfg = cfg or ControllerConfig()
        # scripting hook f(now, pool), called at every planning point
        # BEFORE topup/policy — the chaos harness drives pool-plane
        # cancellations and fault scheduling through it
        self.on_plan = on_plan
        # conformance hooks (tests/bench): peak allocation, invariant flag,
        # and the cumulative served count at every completion event
        self.max_alloc = 0.0
        self.oversubscribed = False
        self.served_timeline: List[Tuple[float, int]] = []
        self._makespan = 0.0
        self._heap: List[Tuple[float, int]] = []  # (next decode time, seq)
        self._last_served = 0

    @property
    def telemetry(self):
        """The pool's telemetry plane (read by the core event loop)."""
        return self.pool.telemetry

    # ------------------------------------------------------------------
    def _plan(self, now: float, heap: List[Tuple[float, int]]) -> None:
        for rr in self.policy.plan(now, self.pool) or []:
            run = self.pool.admit(rr, now, self.cfg.gen_len,
                                  self.cfg.drop_expired)
            if run is None:
                continue
            heapq.heappush(heap, (run.next_time, run.seq))
            # the pool maintains the aggregate incrementally — one source
            # of truth for the oversubscription invariant
            alloc = 1.0 - self.pool.free_frac(now)
            self.max_alloc = max(self.max_alloc, alloc)
            if not rr.oversubscribe and alloc > 1.0 + 1e-6:
                self.oversubscribed = True

    def _total_served(self) -> int:
        return sum(q.completed for q in self.pool.queues.values())

    # ----------------------------------------- EventLoopHooks (core loop)
    # The loop semantics live ONCE in ``repro.core.eventloop`` — the same
    # skeleton drives the analytic Simulator, so the two planes cannot
    # drift. These hooks are the real-engine machinery inside the events.
    def deliver(self, req: Request) -> None:
        self.pool.push(req)

    def next_completion(self) -> float:
        return self._heap[0][0] if self._heap else math.inf

    def next_wakeup(self, now: float) -> float:
        return (self.policy.next_wakeup(now)
                if hasattr(self.policy, "next_wakeup") else math.inf)

    def advance(self, t: float) -> None:
        self.pool.advance_time(t)

    def fire(self, now: float, epsilon: float = 1e-12) -> int:
        steps = 0
        while self._heap and self._heap[0][0] <= now + epsilon:
            _, seq = heapq.heappop(self._heap)
            run = self.pool._runs.get(seq)
            if run is None:
                continue
            finished = self.pool.step_run(run, now)  # real jitted dispatch
            steps += 1
            served = self._total_served()
            if served != self._last_served:     # ragged: slots complete
                self._last_served = served      # mid-run, not only at ends
                self._makespan = max(self._makespan, now)
                self.served_timeline.append((now, served))
            if not finished:
                heapq.heappush(self._heap, (run.next_time, seq))
        return steps

    def plan(self, now: float) -> None:
        if self.on_plan is not None:
            self.on_plan(now, self.pool)
        if self.cfg.topup:
            # continuous batching across run boundaries: refill slots that
            # ragged budgets freed early before asking the policy (the run
            # keeps its heap entry; only its contents grow)
            for run in self.pool.running:
                self.pool.topup(run, now, self.cfg.gen_len,
                                self.cfg.drop_expired)
        self._plan(now, self._heap)

    def drained(self) -> bool:
        return (not self.pool.running
                and all(len(q) == 0 for q in self.pool.queues.values()))

    # ------------------------------------------------------------------
    def run(self) -> PoolResult:
        cfg = self.cfg
        self._heap = []
        self._last_served = self._total_served()
        wall0 = time.perf_counter()
        out = run_event_loop(
            LoopConfig(duration=cfg.duration, drain=cfg.drain,
                       max_time=cfg.max_time,
                       arrival_horizon=cfg.arrival_horizon,
                       max_events=cfg.max_steps),
            self.generators, self)
        # a truncated non-drain run is normalized by the virtual time it
        # actually covered, not the full cfg.duration — and flagged, so it
        # can never masquerade as a complete measurement
        if cfg.drain:
            duration = self._makespan
        else:
            duration = (min(out.now, cfg.duration) if out.truncated
                        else cfg.duration)
        wall = time.perf_counter() - wall0
        res = self.pool.snapshot(getattr(self.policy, "name", "?"),
                                 duration or 1e-9, wall, out.events)
        res.truncated = out.truncated
        return res


# --------------------------------------------------------------------------
# convenience drivers (the thin-wrapper API used by examples/launch/bench)
# --------------------------------------------------------------------------
def make_generators(pool: EnginePool, rate: float, *, seed0: int = 0,
                    slo_scale: float = 1.0,
                    gen_tokens=None) -> List[RequestGenerator]:
    """One deterministic arrival stream per hosted model (sorted order so
    seeds are stable across runs and policies). ``gen_tokens``: None keeps
    every request on the controller's uniform ``gen_len``; an int or a
    (lo, hi) range stamps per-request ragged token budgets."""
    return [RequestGenerator(n, rate, pool.profiles[n].slo * slo_scale,
                             seed=seed0 + i, gen_tokens=gen_tokens)
            for i, n in enumerate(sorted(pool.profiles))]


def run_policy(pool: EnginePool, policy_name: str, *, rate: float,
               duration: float, gen_len: int = 4, seed0: int = 0,
               drain: bool = False, drop_expired: bool = True,
               slo_scale: float = 1.0, gen_tokens=None, topup: bool = True,
               policy_kwargs: Optional[Dict] = None) -> PoolResult:
    """Reset the pool, build the named policy over its profiles, and serve
    one deterministic workload through the real engines. ``gen_tokens``
    (int or (lo, hi)) makes the workload ragged: each request carries its
    own decode budget, slots free early, and the controller tops runs up
    mid-flight."""
    from repro.core.scheduler import POLICIES

    pool.reset()
    policy = POLICIES[policy_name](pool.profiles, **(policy_kwargs or {}))
    gens = make_generators(pool, rate, seed0=seed0, slo_scale=slo_scale,
                           gen_tokens=gen_tokens)
    cfg = ControllerConfig(duration=duration, gen_len=gen_len, drain=drain,
                           drop_expired=drop_expired, topup=topup,
                           arrival_horizon=duration if drain else None)
    return Controller(pool, policy, gens, cfg).run()
