"""Controller: the serving loop that lets a Policy drive the EnginePool.

Discrete-event execution (paper §6): the controller owns a virtual clock;
events are request arrivals, engine decode steps, and policy session
wakeups. At every event it drains arrivals into the per-model queues, steps
the engines whose next decode is due (each step is ONE real jitted
dispatch over all of that engine's slots), and asks the policy to ``plan``
against the pool's SchedView — translating each ``RunRequest`` into an
admission on a pre-built standby engine via ``EnginePool.admit``.

Virtual time advances by the profile roofline latency of each run at its
*granted* allocation, so SLO accounting, session boundaries, and policy
comparisons are deterministic and paper-comparable on a one-core host —
while the data plane underneath executes the real slot-batched decode hot
path. Wall-clock time of the whole schedule is reported alongside.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.metrics import PoolResult
from repro.serving.pool import EnginePool
from repro.serving.request import (Request, RequestGenerator,
                                   materialize_arrivals)


@dataclasses.dataclass
class ControllerConfig:
    duration: float = 1.0           # virtual seconds (ignored when drain)
    gen_len: int = 4                # decode tokens per admitted request
    drain: bool = False             # run until all queued work completes
    drop_expired: bool = True
    # horizon up to which rate generators materialize arrivals; None ->
    # ``duration`` (drain runs MUST set one of them, like the simulator)
    arrival_horizon: Optional[float] = None
    max_steps: int = 500_000        # safety valve on real dispatches
    # virtual-time backstop (mirrors SimConfig.max_time): bounds drain
    # runs where a policy keeps waking but nothing is ever admitted
    max_time: float = 600.0


class Controller:
    def __init__(self, pool: EnginePool, policy,
                 generators: Sequence[RequestGenerator],
                 cfg: Optional[ControllerConfig] = None):
        self.pool = pool
        self.policy = policy
        self.generators = list(generators)
        self.cfg = cfg or ControllerConfig()
        # conformance hooks (tests/bench): peak allocation, invariant flag,
        # and the cumulative served count at every completion event
        self.max_alloc = 0.0
        self.oversubscribed = False
        self.served_timeline: List[Tuple[float, int]] = []
        self._makespan = 0.0

    # ------------------------------------------------------------------
    def _plan(self, now: float, heap: List[Tuple[float, int]]) -> None:
        for rr in self.policy.plan(now, self.pool) or []:
            run = self.pool.admit(rr, now, self.cfg.gen_len,
                                  self.cfg.drop_expired)
            if run is None:
                continue
            heapq.heappush(heap, (run.next_time, run.seq))
            # the pool maintains the aggregate incrementally — one source
            # of truth for the oversubscription invariant
            alloc = 1.0 - self.pool.free_frac(now)
            self.max_alloc = max(self.max_alloc, alloc)
            if not rr.oversubscribe and alloc > 1.0 + 1e-6:
                self.oversubscribed = True

    def _total_served(self) -> int:
        return sum(q.completed for q in self.pool.queues.values())

    def run(self) -> PoolResult:
        cfg = self.cfg
        pool = self.pool
        horizon = (cfg.arrival_horizon if cfg.arrival_horizon is not None
                   else cfg.duration)
        arrivals: List[Request] = materialize_arrivals(
            self.generators, horizon, drain=cfg.drain)

        heap: List[Tuple[float, int]] = []   # (next decode time, run seq)
        ai = 0
        now = 0.0
        steps = 0
        truncated = False                    # hit a backstop, not the end
        wall0 = time.perf_counter()
        while ai < len(arrivals) and arrivals[ai].arrival <= now:
            pool.push(arrivals[ai]); ai += 1
        self._plan(now, heap)

        while steps < cfg.max_steps:
            if cfg.drain and ai >= len(arrivals) and not pool.running \
                    and all(len(q) == 0 for q in pool.queues.values()):
                break
            t_run = heap[0][0] if heap else math.inf
            t_arr = arrivals[ai].arrival if ai < len(arrivals) else math.inf
            t_wake = self.policy.next_wakeup(now) if hasattr(
                self.policy, "next_wakeup") else math.inf
            t = min(t_run, t_arr, t_wake)
            if math.isinf(t):
                break
            if t > cfg.max_time:
                truncated = True
                break
            if not cfg.drain and t > cfg.duration:
                pool.advance_time(cfg.duration)
                now = cfg.duration
                break
            pool.advance_time(t)
            now = t
            while ai < len(arrivals) and arrivals[ai].arrival <= now + 1e-12:
                pool.push(arrivals[ai]); ai += 1
            while heap and heap[0][0] <= now + 1e-12:
                _, seq = heapq.heappop(heap)
                run = pool._runs.get(seq)
                if run is None:
                    continue
                finished = pool.step_run(run, now)   # real jitted dispatch
                steps += 1
                if finished:
                    self._makespan = max(self._makespan, now)
                    self.served_timeline.append((now, self._total_served()))
                else:
                    heapq.heappush(heap, (run.next_time, seq))
            self._plan(now, heap)

        if steps >= cfg.max_steps:
            truncated = True
        # a truncated non-drain run is normalized by the virtual time it
        # actually covered, not the full cfg.duration — and flagged, so it
        # can never masquerade as a complete measurement
        if cfg.drain:
            duration = self._makespan
        else:
            duration = min(now, cfg.duration) if truncated else cfg.duration
        wall = time.perf_counter() - wall0
        res = pool.snapshot(getattr(self.policy, "name", "?"),
                            duration or 1e-9, wall, steps)
        res.truncated = truncated
        return res


# --------------------------------------------------------------------------
# convenience drivers (the thin-wrapper API used by examples/launch/bench)
# --------------------------------------------------------------------------
def make_generators(pool: EnginePool, rate: float, *, seed0: int = 0,
                    slo_scale: float = 1.0) -> List[RequestGenerator]:
    """One deterministic arrival stream per hosted model (sorted order so
    seeds are stable across runs and policies)."""
    return [RequestGenerator(n, rate, pool.profiles[n].slo * slo_scale,
                             seed=seed0 + i)
            for i, n in enumerate(sorted(pool.profiles))]


def run_policy(pool: EnginePool, policy_name: str, *, rate: float,
               duration: float, gen_len: int = 4, seed0: int = 0,
               drain: bool = False, drop_expired: bool = True,
               slo_scale: float = 1.0,
               policy_kwargs: Optional[Dict] = None) -> PoolResult:
    """Reset the pool, build the named policy over its profiles, and serve
    one deterministic workload through the real engines."""
    from repro.core.scheduler import POLICIES

    pool.reset()
    policy = POLICIES[policy_name](pool.profiles, **(policy_kwargs or {}))
    gens = make_generators(pool, rate, seed0=seed0, slo_scale=slo_scale)
    cfg = ControllerConfig(duration=duration, gen_len=gen_len, drain=drain,
                           drop_expired=drop_expired,
                           arrival_horizon=duration if drain else None)
    return Controller(pool, policy, gens, cfg).run()
