"""Modality frontend STUBS (the one sanctioned carve-out).

Audio: instead of mel-spectrogram + conv encoder, ``audio_frames`` emits
frame embeddings of shape (B, encoder_seq, d_model). VLM: instead of a
VQ-GAN tokenizer, ``image_tokens`` emits VQ code ids inside the shared
vocab. Both are deterministic in their seed so tests are reproducible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def audio_frames(cfg, batch: int, seed: int = 0, dtype=None):
    """Precomputed frame embeddings standing in for the conv frontend."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(
        key, (batch, cfg.encoder_seq, cfg.d_model), jnp.float32
    ).astype(dtype) * 0.02


def image_tokens(cfg, batch: int, n_tokens: int = 1024, seed: int = 0,
                 code_offset: int = None):
    """VQ image-token ids; chameleon reserves the top 8192 codes."""
    if code_offset is None:
        code_offset = max(0, cfg.vocab_size - 8192)
    key = jax.random.PRNGKey(seed)
    return jax.random.randint(
        key, (batch, n_tokens), code_offset, cfg.vocab_size, jnp.int32)


def interleave_multimodal(cfg, text_tokens, img_tokens):
    """Chameleon-style early fusion: [img tokens][text tokens]."""
    return jnp.concatenate([img_tokens, text_tokens], axis=1)
