"""Async streaming gateway over the tick plane (ISSUE 10).

The client-facing layer the ROADMAP's serving-plane item calls for:
clients ``submit(request, prompt)`` and get a ``TokenStream`` — an
async iterator yielding decode tokens as the planner emits them —
while one asyncio drive loop steps a ``TickServer`` underneath. The
loop is a line-for-line async mirror of ``core.eventloop
.run_event_loop`` (same epsilon, same deliver-then-fire-then-plan
order, same drain exit), which is what makes gateway-served streams
BIT-EXACT against driving ``serve_ticks`` directly on the same trace:
the planner sees identical (arrival, tick) interleavings, so it builds
identical plans. Between ticks the loop yields to the event loop once
(``asyncio.sleep(0)``), so client consumers interleave with serving
without perturbing it.

Lifecycle edges map onto the machinery PR 6 built — nothing new below
the gateway:

* client disconnect (``TokenStream.cancel`` / ``gateway.cancel``) →
  ``StepPlanner.cancel`` → a ``Cancel`` plan event frees the slot's
  pages (mid-chunked-prefill and mid-spec-round included);
* load shedding → ``planner.submit`` refuses → the gateway raises a
  typed ``ShedRejection`` (live) or closes the stream terminally
  (trace replay) — a shed request never held a page;
* a deadline already blown AT submit → typed ``DeadlineRejection``
  with the same dropped/violated accounting ``pop_batch`` would have
  charged; a deadline blown IN queue keeps the queue-side drop path.

Two clocks: virtual (default — time jumps event-to-event exactly like
``serve_ticks``) and **wall** (``wall_clock=True`` — the loop sleeps
until ``perf_counter`` reaches each event time and stamps ticks with
real elapsed seconds, so the planner's TTFT/TBT/deadline arithmetic
runs against the host clock and PR 7's ``StepTimers``/roofline report
validate measured-vs-modeled per step).

Every edge lands as a telemetry instant on the model's queue track
when a ``Telemetry`` plane is attached, and costs one ``is None``
check when not — the zero-cost-when-detached contract.
"""
from __future__ import annotations

import asyncio
import math
import time
from typing import Any, Dict, List, Optional, Sequence

from repro.serving.plan import StepPlanner, TickServer
from repro.serving.request import Request

_EPS = 1e-12
_DONE = object()


class GatewayRejection(Exception):
    """Base for typed submit-time rejections: the request is terminal
    (``req.state`` says why) and never held a slot, page, or queue
    entry past this call."""

    def __init__(self, req: Request, reason: str):
        super().__init__(f"request {req.rid} {reason} "
                         f"(tenant={req.tenant!r}, tier={req.tier!r})")
        self.req = req
        self.reason = reason


class ShedRejection(GatewayRejection):
    """Refused at admission by the planner's load-shed watermarks."""

    def __init__(self, req: Request):
        super().__init__(req, "shed at admission (overload)")


class DeadlineRejection(GatewayRejection):
    """Deadline already passed when the client submitted."""

    def __init__(self, req: Request):
        super().__init__(req, "submitted past its deadline")


class TokenStream:
    """One request's per-token stream.

    ``async for tok in stream`` yields each decode token once, in
    order, and ends when the request reaches a terminal state
    (``stream.state``: completed / cancelled / deadline_aborted /
    shed). ``stream.tokens`` accumulates everything delivered —
    after the run it equals ``planner.streams[rid]`` for completed
    requests, which is the bit-exactness surface the tests compare.

    Requeue-for-recompute (preemption, failed grow, engine reset)
    clears the planner's stream and replays it bit-exactly; the
    gateway's high-water mark (``_sent``) suppresses the replayed
    prefix, so a client sees every token exactly once even when the
    request recomputed mid-stream."""

    def __init__(self, gateway: "AsyncGateway", req: Request):
        self.req = req
        self.rid = req.rid
        self.tokens: List[int] = []
        self.state: Optional[str] = None      # terminal cause once closed
        self._gw = gateway
        self._sent = 0
        self._q: asyncio.Queue = asyncio.Queue()
        self._closed = False

    def cancel(self) -> bool:
        """Client disconnect: cancel the request wherever it lives
        (queued / resident / staged). The stream still closes through
        the normal pump — with state ``cancelled``."""
        return self._gw.cancel(self.rid)

    def __aiter__(self) -> "TokenStream":
        return self

    async def __anext__(self) -> int:
        if self._closed and self._q.empty():
            raise StopAsyncIteration
        item = await self._q.get()
        if item is _DONE:
            raise StopAsyncIteration
        return item

    async def collect(self) -> List[int]:
        """Drain the stream to its terminal state; returns tokens."""
        async for _ in self:
            pass
        return self.tokens

    # ------------------------------------------------ gateway internals
    def _emit(self, tok: int) -> None:
        self.tokens.append(tok)
        self._q.put_nowait(tok)

    def _finish(self, state: str) -> None:
        if self._closed:
            return
        self.state = state
        self._closed = True
        self._q.put_nowait(_DONE)


class AsyncGateway:
    """Asyncio serving frontend over one ``(planner, TickServer)``.

    Trace mode — ``schedule(requests)`` then ``await run()`` (or the
    sync ``serve_trace``): a seeded arrival trace replays exactly like
    ``serve_ticks``. Live mode — ``run(hold_open=True)`` keeps the
    loop alive while clients ``submit`` concurrently; ``close()`` lets
    it drain and exit. Both share one drive loop; ``faults``,
    ``on_tick`` and ``stall_limit`` pass through to the underlying
    ``TickServer``, so the chaos harness runs unchanged THROUGH the
    gateway."""

    def __init__(self, planner: StepPlanner, prompt_fn=None, *,
                 tick_dt: float = 1e-3, wall_clock: bool = False,
                 faults=None, on_tick=None,
                 stall_limit: Optional[int] = None,
                 max_ticks: int = 100_000):
        self.planner = planner
        self.wall_clock = wall_clock
        self.max_ticks = max_ticks
        self._batches: Dict[int, Any] = {}
        self.server = TickServer(
            planner, prompt_fn if prompt_fn is not None else self._batch_of,
            tick_dt=tick_dt, faults=faults, on_tick=on_tick,
            stall_limit=stall_limit)
        self.streams: Dict[int, TokenStream] = {}
        self._live: Dict[int, TokenStream] = {}
        self._pending: List[Request] = []     # scheduled trace arrivals
        self._wake = asyncio.Event()
        self._running = False
        self._closed = False
        self.now = 0.0
        self.events = 0
        self.truncated = False
        self._t0: Optional[float] = None      # wall-clock epoch

    # --------------------------------------------------------- plumbing
    def _batch_of(self, req: Request):
        return self._batches[req.rid]

    def _tel(self, name: str, req: Request, **args) -> None:
        tel = self.planner.telemetry
        if tel is not None:
            tel.request_event(req.model, name, rid=req.rid, **args)

    def _elapsed(self) -> float:
        return time.perf_counter() - (self._t0 or 0.0)

    # ----------------------------------------------------- client surface
    def schedule(self, requests: Sequence[Request], prompts=None) -> None:
        """Pre-schedule a trace: arrivals deliver at their stamped
        times, exactly like ``serve_ticks``. ``prompts`` (rid -> prompt
        pytree) feeds the default prompt_fn; with a custom prompt_fn it
        may be omitted. Streams exist immediately (``streams[rid]``) so
        consumers can start iterating before arrival."""
        for r in requests:
            if prompts is not None:
                self._batches[r.rid] = prompts[r.rid]
            st = TokenStream(self, r)
            self.streams[r.rid] = st
            self._live[r.rid] = st
        self._pending.extend(requests)
        self._pending.sort(key=lambda r: r.arrival)
        self._wake.set()

    def submit(self, req: Request, batch) -> TokenStream:
        """Live submission at the gateway's current clock. Returns the
        request's ``TokenStream``, or raises a typed rejection:
        ``DeadlineRejection`` when the deadline already passed (counted
        dropped+violated, the same accounting a queue-side expiry
        gets), ``ShedRejection`` when the planner's load-shed
        watermarks refuse it. Either way the request holds nothing."""
        now = self._elapsed() if (self.wall_clock and self._running) \
            else self.now
        self._tel("gw_submit", req, tenant=req.tenant, tier=req.tier)
        # req.arrival is the CLIENT's send stamp (the deadline anchor:
        # deadline = arrival + slo); the gateway never rewrites it —
        # failing fast here is the same judgement pop_batch would make
        # at the queue, just before the request holds anything
        if req.deadline < now:
            req.state = "deadline_aborted"
            q = self.planner.queue
            if q is not None:
                q.dropped += 1
                q.violated += 1
            self._tel("gw_reject_deadline", req)
            raise DeadlineRejection(req)
        self._batches[req.rid] = batch
        self._tel("arrival", req)
        if not self.planner.submit(req, batch):
            self._batches.pop(req.rid, None)
            raise ShedRejection(req)
        st = TokenStream(self, req)
        self.streams[req.rid] = st
        self._live[req.rid] = st
        self._wake.set()
        return st

    def cancel(self, rid: int) -> bool:
        """Client disconnect for ``rid`` — queued requests leave the
        queue immediately; resident/staged ones become a ``Cancel``
        plan event next tick (pages free before anything admits)."""
        st = self._live.get(rid)
        if st is not None:
            self._tel("gw_disconnect", st.req)
        ok = self.planner.cancel(rid)
        self._wake.set()
        return ok

    def close(self) -> None:
        """Stop accepting live submissions; ``run(hold_open=True)``
        exits once everything in flight drains."""
        self._closed = True
        self._wake.set()

    # --------------------------------------------------------- drive loop
    def _pump(self) -> None:
        """Move newly-emitted tokens from ``planner.streams`` into the
        client streams and close the terminal ones. The ``_sent``
        high-water mark makes requeue replays invisible: a cleared
        planner stream re-emits its (bit-exact) prefix below the mark
        and only genuinely new tokens reach the client."""
        done: List[int] = []
        for rid, st in self._live.items():
            toks = self.planner.streams.get(rid)
            if toks is not None and len(toks) > st._sent:
                for tok in toks[st._sent:]:
                    st._emit(tok)
                st._sent = len(toks)
            if st.req.state != "pending":
                self._tel("gw_stream_close", st.req, cause=st.req.state,
                          tokens=len(st.tokens))
                st._finish(st.req.state)
                done.append(rid)
        for rid in done:
            del self._live[rid]
            self._batches.pop(rid, None)

    def _deliver(self, req: Request) -> None:
        # mirrors run_event_loop's delivery: arrival instant, then the
        # hooks' deliver (planner.submit via TickServer.deliver — which
        # handles the shed branch and its accounting)
        self._tel("arrival", req)
        self.server.deliver(req)

    async def run(self, *, hold_open: bool = False) -> None:
        """Serve until drained (trace mode) or until ``close()`` then
        drained (``hold_open`` live mode). One invocation per gateway:
        the loop owns the server's clock."""
        if self._running:
            raise RuntimeError("gateway already running")
        self._running = True
        self._t0 = time.perf_counter()
        server = self.server
        now = 0.0
        # t=0 prologue, exactly like run_event_loop
        while self._pending and self._pending[0].arrival <= now:
            self._deliver(self._pending.pop(0))
        server.plan(now)
        self._pump()
        await asyncio.sleep(0)
        while True:
            if self.events >= self.max_ticks:
                self.truncated = True
                break
            t = min(server.next_completion(),
                    self._pending[0].arrival if self._pending else math.inf)
            if math.isinf(t):
                if hold_open and not self._closed:
                    self._wake.clear()
                    # idle live gateway: nothing scheduled, nothing
                    # resident — sleep until a submit/cancel/close
                    await self._wake.wait()
                    continue
                break
            if self.wall_clock:
                delay = t - self._elapsed()
                if delay > 0:
                    await asyncio.sleep(delay)
                now = max(t, self._elapsed())
            else:
                now = t
            self.now = now
            while (self._pending
                   and self._pending[0].arrival <= now + _EPS):
                self._deliver(self._pending.pop(0))
            self.events += server.fire(now, _EPS)
            server.plan(now)
            self._pump()
            # the one cooperative yield per event: queued consumers run
            # here, in FIFO order — deterministic interleaving
            await asyncio.sleep(0)
        self._pump()
        for rid in list(self._live):
            # truncated / never-drained remnants: close so consumers
            # terminate; state stays whatever the request reached
            st = self._live.pop(rid)
            st._finish(st.req.state)
        self._running = False

    def serve_trace(self, requests: Sequence[Request], prompts=None
                    ) -> Dict[int, TokenStream]:
        """Sync convenience mirroring ``serve_ticks``: schedule the
        trace, run to drain, return every stream (all closed). Shed /
        expired requests come back as terminally-closed streams rather
        than raising — a trace replay has no live client to reject."""
        self.schedule(requests, prompts)
        asyncio.run(self.run())
        return dict(self.streams)
