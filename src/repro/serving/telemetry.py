"""Serving-wide telemetry plane: tracing, step timers, metrics, roofline.

Four cooperating pieces, all optional and all zero-cost when detached
(every instrumentation site in the serving stack guards on
``telemetry is None`` — no context managers, no clock reads, no extra
dispatches on the disabled path; ``tests/test_telemetry.py`` proves
disabled runs bit-identical):

* :class:`TraceRecorder` — a bounded ring buffer of structured spans and
  instants, exported as Chrome-trace-event JSON (``to_chrome_trace`` /
  ``save``) loadable in Perfetto or ``chrome://tracing``. One track per
  engine (``engine/<model>@<chips>ch``), one per model queue
  (``queue/<model>``), one per tick server (``tick/<model>``). The
  deterministic projection ``key_sequence()`` (everything except
  wall-clock ``ts``/``dur``) is what the seeded-chaos determinism test
  compares.
* :class:`StepTimers` — ``perf_counter`` wall-clock samples around
  block-until-ready dispatches, keyed ``(model, chips, kind, bucket)``.
  Feeds :func:`roofline_report`, which joins measured dispatch latency
  against ``core/latency_model`` predictions and flags deviations (on
  CPU hosts the flags are the point: the rooflines model a TPU).
* :class:`MetricsRegistry` — labelled counters/gauges/histograms with
  Prometheus text exposition (``render``) and a matching parser for
  tests/CI. The ``export_*`` bridges register the existing ad-hoc
  counters (engine ``stats``, ``RequestQueue`` per-cause terminals,
  ``FaultInjector.injected``, pool occupancy/Jain) so
  ``PoolMetrics``/``ModelPoolMetrics`` become snapshot views over one
  coherent exposition.
* :class:`Telemetry` — the umbrella object the serving layers hold. The
  engine calls :meth:`Telemetry.dispatch_done` after each of its ≤3
  dispatches; planners/pools emit lifecycle instants
  (:meth:`request_event`); the event loop emits arrivals.

Request timelines (queued → admitted → chunk ticks → first token →
terminal) are reconstructible from the instants via
:func:`request_timelines`; TTFT/TBT themselves are recorded always-on in
``RequestQueue`` (they are cheap scalars, not telemetry).
"""
from __future__ import annotations

import collections
import dataclasses
import json
import math
import time
from contextlib import contextmanager
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "TraceRecorder", "StepTimers", "Telemetry", "MetricsRegistry",
    "Counter", "Gauge", "Histogram", "validate_chrome_trace",
    "parse_prometheus", "roofline_report", "format_roofline",
    "export_queue", "export_fault_injector", "export_engine_stats",
    "export_pool_result", "request_timelines",
]


# --------------------------------------------------------------------------
# Trace recorder (Chrome trace event format)
# --------------------------------------------------------------------------

class TraceRecorder:
    """Bounded ring buffer of trace events with Chrome-trace JSON export.

    Events carry ``ts``/``dur`` in microseconds relative to the
    recorder's construction (``perf_counter`` based). The ring
    (``capacity`` events) bounds memory on long serves; the validator is
    subset-closed, so dropping the oldest events never produces an
    invalid trace.
    """

    def __init__(self, capacity: int = 65536):
        self.capacity = int(capacity)
        self.events: collections.deque = collections.deque(maxlen=self.capacity)
        self._t0 = time.perf_counter()
        self._seq = 0
        self.dropped = 0

    # -- clocks ------------------------------------------------------------
    def now(self) -> float:
        """Absolute ``perf_counter`` time (pairs with :meth:`complete`)."""
        return time.perf_counter()

    def _us(self, t_abs: float) -> float:
        return (t_abs - self._t0) * 1e6

    # -- emission ----------------------------------------------------------
    def _push(self, ev: Dict[str, Any]) -> None:
        if len(self.events) == self.events.maxlen:
            self.dropped += 1
        ev["seq"] = self._seq
        self._seq += 1
        self.events.append(ev)

    @contextmanager
    def span(self, track: str, name: str, cat: str = "serving", **args):
        """Record a complete (``ph='X'``) span around the body."""
        t0 = time.perf_counter()
        try:
            yield self
        finally:
            t1 = time.perf_counter()
            self._push({"track": track, "ph": "X", "name": name,
                        "cat": cat, "ts": self._us(t0),
                        "dur": (t1 - t0) * 1e6, "args": dict(args)})

    def complete(self, track: str, name: str, start: float, dur_s: float,
                 cat: str = "serving", **args) -> None:
        """Record an already-measured span (``start`` is perf_counter)."""
        self._push({"track": track, "ph": "X", "name": name, "cat": cat,
                    "ts": self._us(start), "dur": dur_s * 1e6,
                    "args": dict(args)})

    def instant(self, track: str, name: str, cat: str = "serving",
                **args) -> None:
        self._push({"track": track, "ph": "i", "name": name, "cat": cat,
                    "ts": self._us(time.perf_counter()), "args": dict(args)})

    def counter(self, track: str, name: str, **values) -> None:
        """Chrome counter sample (rendered as a stacked area in Perfetto)."""
        self._push({"track": track, "ph": "C", "name": name, "cat": "counter",
                    "ts": self._us(time.perf_counter()),
                    "args": {k: float(v) for k, v in values.items()}})

    # -- export ------------------------------------------------------------
    def tracks(self) -> List[str]:
        """Track names in first-appearance order (stable tids)."""
        seen: Dict[str, None] = {}
        for ev in self.events:
            seen.setdefault(ev["track"], None)
        return list(seen)

    def to_chrome_trace(self) -> Dict[str, Any]:
        pid = 1
        tids = {t: i + 1 for i, t in enumerate(self.tracks())}
        out: List[Dict[str, Any]] = [{
            "ph": "M", "pid": pid, "tid": 0, "name": "process_name",
            "args": {"name": "dstack-serving"},
        }]
        for track, tid in tids.items():
            out.append({"ph": "M", "pid": pid, "tid": tid,
                        "name": "thread_name", "args": {"name": track}})
        for ev in self.events:
            e = {"ph": ev["ph"], "pid": pid, "tid": tids[ev["track"]],
                 "name": ev["name"], "cat": ev.get("cat", "serving"),
                 "ts": round(ev["ts"], 3), "args": ev.get("args", {})}
            if ev["ph"] == "X":
                e["dur"] = round(ev["dur"], 3)
            elif ev["ph"] == "i":
                e["s"] = "t"          # thread-scoped instant
            out.append(e)
        return {"traceEvents": out,
                "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def save(self, path: str) -> Dict[str, Any]:
        obj = self.to_chrome_trace()
        with open(path, "w") as f:
            json.dump(obj, f)
        return obj

    def key_sequence(self) -> List[Tuple]:
        """Deterministic projection: everything but wall-clock fields.

        Two seeded runs of the same workload must produce identical
        key sequences even though ``ts``/``dur`` differ.
        """
        out = []
        for ev in self.events:
            args = tuple(sorted(ev.get("args", {}).items()))
            out.append((ev["track"], ev["ph"], ev["name"],
                        ev.get("cat", "serving"), args))
        return out

    def clear(self) -> None:
        self.events.clear()
        self.dropped = 0
        self._seq = 0
        self._t0 = time.perf_counter()


def validate_chrome_trace(obj: Any) -> int:
    """Validate a Chrome trace object; return the number of span events.

    Checks Perfetto-loadability essentials: a ``traceEvents`` list, each
    event with a known phase, numeric non-negative ``ts`` (and ``dur``
    for spans), names everywhere, and — per (pid, tid) track — spans
    pairwise *nested or disjoint* (a small tolerance absorbs float
    rounding). Raises ``ValueError`` on the first violation.
    """
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("trace: missing traceEvents")
    events = obj["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("trace: traceEvents is not a list")
    spans_by_track: Dict[Tuple, List[Tuple[float, float, str]]] = {}
    n_spans = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"trace[{i}]: not an object")
        ph = ev.get("ph")
        if ph not in ("X", "i", "I", "C", "M", "B", "E"):
            raise ValueError(f"trace[{i}]: unknown phase {ph!r}")
        if not ev.get("name"):
            raise ValueError(f"trace[{i}]: missing name")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0 or not math.isfinite(ts):
            raise ValueError(f"trace[{i}]: bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if (not isinstance(dur, (int, float)) or dur < 0
                    or not math.isfinite(dur)):
                raise ValueError(f"trace[{i}]: bad dur {dur!r}")
            key = (ev.get("pid", 0), ev.get("tid", 0))
            spans_by_track.setdefault(key, []).append(
                (float(ts), float(dur), ev["name"]))
            n_spans += 1
    eps = 1e-3  # us; absorbs ts rounding in the exporter
    for key, spans in spans_by_track.items():
        # sort by start, longest first at equal start (parents first)
        spans.sort(key=lambda s: (s[0], -s[1]))
        stack: List[Tuple[float, float, str]] = []
        for ts, dur, name in spans:
            while stack and ts >= stack[-1][0] + stack[-1][1] - eps:
                stack.pop()
            if stack and ts + dur > stack[-1][0] + stack[-1][1] + eps:
                p_ts, p_dur, p_name = stack[-1]
                raise ValueError(
                    f"trace: span {name!r} [{ts:.1f},{ts + dur:.1f}] "
                    f"overlaps {p_name!r} [{p_ts:.1f},{p_ts + p_dur:.1f}] "
                    f"on track {key}")
            stack.append((ts, dur, name))
    return n_spans


# --------------------------------------------------------------------------
# Wall-clock step timers
# --------------------------------------------------------------------------

class StepTimers:
    """Wall-clock dispatch samples keyed ``(model, chips, kind, bucket)``.

    ``kind`` is the dispatch family (``admission_prefill``,
    ``chunk_prefill``, ``decode``, ``grow``); ``bucket`` is the jit
    bucket the dispatch ran at (packed token bucket for prefills, batch
    size for decode). These are the per-(model, allocation, bucket)
    latency histograms the roofline report joins against predictions.
    """

    def __init__(self):
        self.samples: Dict[Tuple[str, int, str, int], List[float]] = {}

    def record(self, model: str, chips: int, kind: str, bucket: int,
               seconds: float) -> None:
        self.samples.setdefault((str(model), int(chips), str(kind),
                                 int(bucket)), []).append(float(seconds))

    @property
    def total_samples(self) -> int:
        return sum(len(v) for v in self.samples.values())

    def summary(self) -> List[Dict[str, Any]]:
        from repro.serving.metrics import percentile
        rows = []
        for (model, chips, kind, bucket), xs in sorted(self.samples.items()):
            rows.append({"model": model, "chips": chips, "kind": kind,
                         "bucket": bucket, "n": len(xs),
                         "p50_s": percentile(xs, 0.5),
                         "p99_s": percentile(xs, 0.99),
                         "mean_s": sum(xs) / len(xs)})
        return rows


# --------------------------------------------------------------------------
# Telemetry umbrella
# --------------------------------------------------------------------------

class Telemetry:
    """What the serving layers hold: a trace (optional) plus timers.

    Attach with ``EnginePool.attach_telemetry`` /
    ``InferenceEngine.attach_telemetry`` / ``StepPlanner.telemetry``.
    When ``trace`` is None only the wall-clock timers run (used by
    ``bench_pool`` for the roofline report without trace export).
    """

    def __init__(self, trace: Optional[TraceRecorder] = None,
                 timers: Optional[StepTimers] = None):
        self.trace = trace
        self.timers = timers if timers is not None else StepTimers()

    # -- track names -------------------------------------------------------
    @staticmethod
    def engine_track(engine) -> str:
        chips = getattr(engine, "alloc_chips", 0) or 0
        return f"engine/{engine.cfg.name}@{chips}ch"

    @staticmethod
    def queue_track(model: str) -> str:
        return f"queue/{model}"

    # -- emission helpers --------------------------------------------------
    def t0(self) -> float:
        return time.perf_counter()

    def dispatch_done(self, engine, kind: str, bucket: int, t0: float,
                      sync=None, **args) -> None:
        """Close a timed dispatch: block until device-done, record.

        ``sync`` is whatever the dispatch produced (arrays / pytrees);
        blocking on it makes the ``perf_counter`` window cover device
        execution, not just Python-side enqueue. Only ever called when
        telemetry is attached, so the disabled path never blocks.
        """
        if sync is not None:
            import jax
            jax.block_until_ready(sync)
        dt = time.perf_counter() - t0
        chips = getattr(engine, "alloc_chips", 0) or 0
        self.timers.record(engine.cfg.name, chips, kind, bucket, dt)
        if self.trace is not None:
            self.trace.complete(self.engine_track(engine), kind, t0, dt,
                                cat="dispatch", bucket=int(bucket), **args)

    def instant(self, track: str, name: str, **args) -> None:
        if self.trace is not None:
            self.trace.instant(track, name, **args)

    def request_event(self, model: str, name: str, **args) -> None:
        """Lifecycle instant on the model's queue track."""
        if self.trace is not None:
            self.trace.instant(self.queue_track(model), name,
                               cat="request", **args)


# --------------------------------------------------------------------------
# Metrics registry (Prometheus text exposition)
# --------------------------------------------------------------------------

def _label_key(labels: Dict[str, Any]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _render_labels(key: Tuple[Tuple[str, str], ...]) -> str:
    if not key:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in key)
    return "{" + body + "}"


def _fmt(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


class Counter:
    def __init__(self, name: str, help: str = ""):
        self.name, self.help, self.kind = name, help, "counter"
        self.values: Dict[Tuple, float] = {}

    def inc(self, amount: float = 1.0, **labels) -> None:
        k = _label_key(labels)
        self.values[k] = self.values.get(k, 0.0) + float(amount)

    def render(self) -> List[str]:
        return [f"{self.name}{_render_labels(k)} {_fmt(v)}"
                for k, v in sorted(self.values.items())]


class Gauge:
    def __init__(self, name: str, help: str = ""):
        self.name, self.help, self.kind = name, help, "gauge"
        self.values: Dict[Tuple, float] = {}

    def set(self, value: float, **labels) -> None:
        self.values[_label_key(labels)] = float(value)

    def render(self) -> List[str]:
        return [f"{self.name}{_render_labels(k)} {_fmt(v)}"
                for k, v in sorted(self.values.items())]


DEFAULT_BUCKETS = (1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2,
                   5e-2, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, math.inf)


class Histogram:
    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        self.name, self.help, self.kind = name, help, "histogram"
        bs = sorted(float(b) for b in buckets)
        if not bs or bs[-1] != math.inf:
            bs.append(math.inf)
        self.buckets = tuple(bs)
        # labelset -> (bucket counts, sum, count)
        self.values: Dict[Tuple, Tuple[List[int], float, int]] = {}

    def observe(self, value: float, **labels) -> None:
        k = _label_key(labels)
        counts, total, n = self.values.get(
            k, ([0] * len(self.buckets), 0.0, 0))
        for i, le in enumerate(self.buckets):
            if value <= le:
                counts[i] += 1
        self.values[k] = (counts, total + float(value), n + 1)

    def render(self) -> List[str]:
        lines = []
        for k, (counts, total, n) in sorted(self.values.items()):
            for le, c in zip(self.buckets, counts):
                lk = k + (("le", _fmt(le)),)
                lines.append(f"{self.name}_bucket{_render_labels(lk)} {c}")
            lines.append(f"{self.name}_sum{_render_labels(k)} {_fmt(total)}")
            lines.append(f"{self.name}_count{_render_labels(k)} {n}")
        return lines


class MetricsRegistry:
    """Named metric family registry with Prometheus text exposition."""

    def __init__(self):
        self.metrics: Dict[str, Any] = {}

    def _get(self, cls, name: str, help: str, **kw):
        m = self.metrics.get(name)
        if m is None:
            m = cls(name, help, **kw)
            self.metrics[name] = m
        elif not isinstance(m, cls):
            raise ValueError(f"metric {name!r} already registered as "
                             f"{type(m).__name__}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def render(self) -> str:
        lines = []
        for name in sorted(self.metrics):
            m = self.metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            lines.extend(m.render())
        return "\n".join(lines) + "\n"


def parse_prometheus(text: str) -> Dict[Tuple[str, Tuple], float]:
    """Parse exposition text back to ``{(name, labelkey): value}``.

    Covers the subset :meth:`MetricsRegistry.render` emits — enough for
    the round-trip assertions in tests and CI.
    """
    out: Dict[Tuple[str, Tuple], float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        head, _, val = line.rpartition(" ")
        if "{" in head:
            name, _, rest = head.partition("{")
            body = rest.rstrip("}")
            labels = []
            for part in _split_labels(body):
                k, _, v = part.partition("=")
                labels.append((k, v.strip('"')))
            key = tuple(sorted(labels))
        else:
            name, key = head, ()
        out[(name, key)] = float(val.replace("+Inf", "inf"))
    return out


def _split_labels(body: str) -> List[str]:
    parts, cur, inq = [], "", False
    for ch in body:
        if ch == '"':
            inq = not inq
            cur += ch
        elif ch == "," and not inq:
            parts.append(cur)
            cur = ""
        else:
            cur += ch
    if cur:
        parts.append(cur)
    return parts


# --------------------------------------------------------------------------
# Registry bridges for the existing ad-hoc counters
# --------------------------------------------------------------------------

def export_queue(reg: MetricsRegistry, queue, model: Optional[str] = None
                 ) -> None:
    """Register a ``RequestQueue``'s per-cause terminals and TTFT/TBT."""
    model = model if model is not None else queue.model
    term = reg.counter("dstack_requests_total",
                       "requests by terminal cause")
    for cause in ("completed", "cancelled", "deadline_aborted", "shed",
                  "dropped"):
        term.inc(getattr(queue, cause), model=model, cause=cause)
    reg.counter("dstack_slo_violations_total",
                "completions past their SLO").inc(queue.violated, model=model)
    lat = reg.histogram("dstack_latency_seconds",
                        "end-to-end completion latency")
    for v in queue.latencies:
        lat.observe(v, model=model)
    ttft = reg.histogram("dstack_ttft_seconds", "time to first token")
    for cause, xs in sorted(queue.ttft_by_cause.items()):
        for v in xs:
            ttft.observe(v, model=model, cause=cause)
    tbt = reg.histogram("dstack_tbt_seconds",
                        "mean time between tokens (completed requests)")
    for v in queue.tbts:
        tbt.observe(v, model=model)


def export_fault_injector(reg: MetricsRegistry, injector) -> None:
    c = reg.counter("dstack_faults_injected_total",
                    "injected faults by site")
    for site, n in sorted(injector.injected.items()):
        c.inc(n, site=site)


def export_engine_stats(reg: MetricsRegistry, stats, model: str,
                        chips: int = 0) -> None:
    labels = {"model": model, "chips": str(chips)}
    for field, name in (
            ("prefills", "dstack_prefills_total"),
            ("packed_prefills", "dstack_packed_prefills_total"),
            ("chunk_prefills", "dstack_chunk_prefills_total"),
            ("prefill_tokens", "dstack_prefill_tokens_total"),
            ("decode_steps", "dstack_decode_steps_total"),
            ("tokens_out", "dstack_tokens_out_total"),
            ("grows", "dstack_page_grows_total"),
            ("engine_retries", "dstack_engine_retries_total"),
            ("engine_resets", "dstack_engine_resets_total"),
            ("prefix_hits", "dstack_prefix_hits_total"),
            ("prefix_hit_tokens", "dstack_prefix_hit_tokens_total"),
            ("cow_copies", "dstack_cow_copies_total"),
            ("forced_catchup_tokens", "dstack_prefix_catchup_tokens_total"),
            ("incr_chunks", "dstack_incr_chunks_total"),
            ("draft_tokens", "dstack_draft_tokens_total"),
            ("accepted_tokens", "dstack_accepted_tokens_total"),
            ("spec_rounds", "dstack_spec_rounds_total"),
            ("rollbacks", "dstack_spec_rollbacks_total")):
        reg.counter(name).inc(getattr(stats, field, 0), **labels)


def export_pool_result(reg: MetricsRegistry, result,
                       injector=None) -> None:
    """Register a ``PoolResult`` snapshot (the ``ModelPoolMetrics`` view).

    ``PoolMetrics``/``ModelPoolMetrics`` stay the in-process snapshot
    structs; this bridge is what turns one into the exposition format.
    """
    reg.gauge("dstack_pool_throughput_rps",
              "completed requests per virtual second").set(
        result.throughput(), policy=result.policy)
    reg.gauge("dstack_pool_fairness_jain", "Jain index over model shares"
              ).set(result.fairness(), policy=result.policy)
    reg.gauge("dstack_pool_occupancy", "mean chip occupancy").set(
        result.occupancy, policy=result.policy)
    reg.gauge("dstack_pool_page_occupancy",
              "time-averaged KV page occupancy").set(
        result.page_occupancy, policy=result.policy)
    term = reg.counter("dstack_requests_total",
                       "requests by terminal cause")
    thr = reg.gauge("dstack_model_throughput_rps",
                    "per-model completed requests per virtual second")
    lat = reg.histogram("dstack_latency_seconds",
                        "end-to-end completion latency")
    ttft = reg.histogram("dstack_ttft_seconds", "time to first token")
    tbt = reg.histogram("dstack_tbt_seconds",
                        "mean time between tokens (completed requests)")
    dur = max(result.duration, 1e-12)
    for name, m in sorted(result.per_model.items()):
        for cause in ("completed", "cancelled", "deadline_aborted", "shed",
                      "dropped"):
            term.inc(getattr(m, cause, 0), model=name, cause=cause)
        thr.set(m.completed / dur, model=name)
        reg.counter("dstack_slo_violations_total",
                    "completions past their SLO").inc(m.violated, model=name)
        for c, n in (("preemptions", m.preemptions),
                     ("requeues", m.requeues), ("topups", m.topups)):
            reg.counter(f"dstack_{c}_total").inc(n, model=name)
        reg.counter("dstack_engine_retries_total").inc(
            m.engine_retries, model=name)
        reg.counter("dstack_engine_resets_total").inc(
            m.engine_resets, model=name)
        reg.counter("dstack_prefix_hits_total").inc(
            getattr(m, "prefix_hits", 0), model=name)
        reg.counter("dstack_prefix_hit_tokens_total").inc(
            getattr(m, "prefix_hit_tokens", 0), model=name)
        reg.counter("dstack_cow_copies_total").inc(
            getattr(m, "cow_copies", 0), model=name)
        for v in m.latencies:
            lat.observe(v, model=name)
        for v in getattr(m, "ttfts", ()):
            ttft.observe(v, model=name, cause="completed")
        for v in getattr(m, "tbts", ()):
            tbt.observe(v, model=name)
    if injector is not None:
        export_fault_injector(reg, injector)


# --------------------------------------------------------------------------
# Roofline validation
# --------------------------------------------------------------------------

@dataclasses.dataclass
class RooflineRow:
    model: str
    chips: int
    kind: str
    bucket: int
    n: int
    measured_p50_s: float
    predicted_s: Optional[float]
    ratio: Optional[float]
    flagged: bool

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def roofline_report(timers: StepTimers, profiles: Dict[str, Any],
                    tol: float = 4.0) -> List[RooflineRow]:
    """Join measured dispatch wall-clock against latency-model predictions.

    ``profiles`` maps model name → ``ModelProfile`` (as on
    ``EnginePool.profiles``). Decode dispatches are predicted by a
    decode-mode ``LatencyModel`` at ``batch=bucket``; prefill dispatches
    by a prefill-mode model at ``seq=bucket`` (the packed token bucket),
    batch 1. ``grow`` dispatches (block-table updates) have no analytic
    model and get no prediction. A row is flagged when measured/predicted
    falls outside ``[1/tol, tol]`` — on CPU hosts essentially every row
    flags, which is exactly the signal: the rooflines model a TPU, the
    host is not one.
    """
    from repro.core.latency_model import LatencyModel
    from repro.serving.metrics import percentile

    lm_cache: Dict[Tuple, Any] = {}
    rows: List[RooflineRow] = []
    for (model, chips, kind, bucket), xs in sorted(timers.samples.items()):
        prof = profiles.get(model)
        predicted = None
        if prof is not None and chips >= 1:
            if kind == "decode":
                key = (model, "decode")
                lm = lm_cache.get(key)
                if lm is None:
                    lm = LatencyModel(prof.cfg, mode="decode", seq=1,
                                      hw=prof.hw)
                    lm_cache[key] = lm
                predicted = lm.latency(chips, max(1, bucket))
            elif kind in ("admission_prefill", "chunk_prefill"):
                key = (model, "prefill", bucket)
                lm = lm_cache.get(key)
                if lm is None:
                    lm = LatencyModel(prof.cfg, mode="prefill",
                                      seq=max(1, bucket), hw=prof.hw)
                    lm_cache[key] = lm
                predicted = lm.latency(chips, 1)
        p50 = percentile(xs, 0.5)
        ratio = (p50 / predicted) if predicted else None
        flagged = ratio is not None and not (1.0 / tol <= ratio <= tol)
        rows.append(RooflineRow(model=model, chips=chips, kind=kind,
                                bucket=int(bucket), n=len(xs),
                                measured_p50_s=p50, predicted_s=predicted,
                                ratio=ratio, flagged=flagged))
    return rows


def format_roofline(rows: Iterable[RooflineRow]) -> List[str]:
    out = ["model         chips kind              bucket    n "
           "measured_p50 predicted    ratio flag"]
    for r in rows:
        pred = f"{r.predicted_s * 1e6:9.1f}us" if r.predicted_s else \
            "        --"
        ratio = f"{r.ratio:8.1f}" if r.ratio is not None else "      --"
        out.append(f"{r.model:<13} {r.chips:>5} {r.kind:<17} "
                   f"{r.bucket:>6} {r.n:>4} "
                   f"{r.measured_p50_s * 1e6:9.1f}us {pred} {ratio}"
                   f" {'DEV' if r.flagged else 'ok'}")
    return out


# --------------------------------------------------------------------------
# Per-request timelines from trace instants
# --------------------------------------------------------------------------

def request_timelines(rec: TraceRecorder) -> Dict[Tuple[str, int],
                                                  List[Tuple[float, str]]]:
    """Reconstruct per-request event timelines from queue-track instants.

    Returns ``{(model, rid): [(ts_us, event), ...]}`` in emission order —
    the queued → admitted → chunk ticks → first token → terminal view.
    """
    out: Dict[Tuple[str, int], List[Tuple[float, str]]] = {}
    for ev in rec.events:
        if ev.get("cat") != "request":
            continue
        rid = ev.get("args", {}).get("rid")
        if rid is None:
            continue
        model = ev["track"].split("/", 1)[-1]
        out.setdefault((model, int(rid)), []).append(
            (ev["ts"], ev["name"]))
    return out
