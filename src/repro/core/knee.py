"""The paper's analytical DNN-parallelism model (§4.3, Eqs. 1–6).

A DNN is a sequence of kernels K_1..K_max whose parallelizable work N_i
decays linearly (Eq. 1); execution time of each kernel is bounded by
min(S, N_i) compute units (Eq. 2); memory stalls scale with data size and
allocated units (Eq. 3); serialized overheads accumulate per kernel (Eq. 4);
total time is Eq. 5. The most efficient allocation maximizes work per unit
time per unit ("utility" 1/(E_t·S)), located via the first-order derivative
(Eq. 6).

This module is hardware-agnostic (units = SMs on GPU, chips on TPU) and is
validated against the paper's own simulation results (Fig. 4a/4b) in
``tests/test_knee.py`` and ``benchmarks/fig4_analytic.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class AnalyticalDNN:
    """Paper Table 4 notation."""
    kmax: int = 50              # number of kernels
    p: int = 40                 # concurrent ops of the 1st kernel (per batch item)
    b: int = 1                  # batch size
    t_p: float = 40.0           # time per parallel op
    t_np: float = 10.0          # serialized (launch) time per kernel
    mem_bw_per_unit: float = 0.0   # M: Eq. 3's per-unit bandwidth (0 = ignore)
    data_per_kernel: float = 0.0   # d_i (constant across kernels for simplicity)
    repetitions: int = 1           # R_i
    # sub-knee contention: with far fewer units than inherent parallelism,
    # wave quantization/cache thrash make the slowdown super-linear — the
    # "exponential increase" the paper measures in Fig. 2 at low GPU%.
    contention: float = 0.25

    # Eq. 1 — parallelizable ops per kernel, decaying to ~0 at K_max
    def parallel_ops(self) -> np.ndarray:
        n1 = self.p * self.b
        dec = n1 / self.kmax
        n = n1 - dec * np.arange(self.kmax)
        return np.maximum(n, 1.0)

    # Eqs. 2–5 — total execution time given S allocated units
    def execution_time(self, s: int | np.ndarray) -> np.ndarray:
        s = np.asarray(s, dtype=np.float64)
        n = self.parallel_ops()                                   # (K,)
        w = n * self.t_p                                          # W_i
        su = np.maximum(s, 1.0)
        eff = np.maximum(1.0, np.minimum(su[..., None], n[None, :]))
        # Eq. 2 plus the sub-knee superlinear contention factor
        factor = 1.0 + self.contention * np.maximum(
            0.0, (n[None, :] - su[..., None]) / su[..., None])
        e_par = (w[None, :] / eff * factor).sum(-1) * self.repetitions
        if self.mem_bw_per_unit > 0:
            # Eq. 3 verbatim: E_m = d_i·S/M — memory stalls GROW with the
            # allocation (per-unit bandwidth share contention)
            e_m = self.data_per_kernel * su / self.mem_bw_per_unit
        else:
            e_m = 0.0
        # Eq. 4 (one launch per *batched* kernel, not per item — deviation
        # from the paper's b× factor, recorded in DESIGN.md §7)
        w_se = self.kmax * self.repetitions * (self.t_np + e_m)
        return w_se + e_par                                       # Eq. 5

    # Eq. 6 — utility and its derivative
    def utility(self, s) -> np.ndarray:
        s = np.asarray(s, dtype=np.float64)
        return 1.0 / (self.execution_time(s) * np.maximum(s, 1))

    def derivative_curve(self, s_range: Sequence[int]) -> np.ndarray:
        """d/dS of inverse latency — the curve the paper plots in Fig. 4b."""
        s = np.asarray(s_range, dtype=np.float64)
        inv = 1.0 / self.execution_time(s)
        return np.gradient(inv, s)

    def knee(self, s_max: int = 128) -> int:
        """Most efficient allocation: the maximum of the first derivative
        of inverse latency (paper Fig. 4b / Fig. 6)."""
        s = np.arange(1, s_max + 1)
        return int(s[np.argmax(self.derivative_curve(s))])


def knee_of_latency(latency_fn, fractions: Sequence[float],
                    rel_tol: float = 0.05) -> float:
    """Generic knee finder for a measured/derived latency curve.

    The knee is the smallest allocation whose latency is within ``rel_tol``
    of the best achievable latency — matching the paper's definition
    ("latency remains unchanged above the knee").
    """
    lats = np.asarray([latency_fn(f) for f in fractions], dtype=np.float64)
    best = lats.min()
    for f, lat in zip(fractions, lats):
        if lat <= best * (1 + rel_tol):
            return float(f)
    return float(fractions[-1])


def knee_binary_search(latency_fn, fractions: Sequence[float],
                       rel_tol: float = 0.05) -> float:
    """§3.3's online procedure for an unprofiled model: start at a nominal
    allocation and binary-search the knee from live latency readings."""
    fr = sorted(fractions)
    lo, hi = 0, len(fr) - 1
    best = latency_fn(fr[-1])
    while lo < hi:
        mid = (lo + hi) // 2
        if latency_fn(fr[mid]) <= best * (1 + rel_tol):
            hi = mid
        else:
            lo = mid + 1
    return float(fr[lo])
