"""Roofline latency model f_L(chips, batch) — the TPU analogue of the
paper's profiled latency function f_L(GPU%, batch) (§5, Table 5).

The paper profiles each DNN on a V100 at every (GPU%, batch) grid point.
We cannot wall-clock a v5e from this container, so f_L is *derived*: the
three roofline terms (compute / HBM / ICI-collective) computed from
per-architecture operation counts, with the paper's parallelism-limit
(Eq. 2's ``min(S, N_i)``) appearing as two TPU-native clamps:

  * shard-granularity clamp: tensor-parallel splitting beyond
    d_ff / mxu_tile chips yields no further useful parallelism;
  * MXU-occupancy clamp: the matmul M-dim (tokens in flight) below the MXU
    tile runs the systolic array at M/tile occupancy.

Both clamps *flatten* E_t(chips) exactly like the paper's Fig. 4a, and the
growing collective term adds the TPU-specific reason more chips eventually
*hurt*. ``CostOverride`` lets the dry-run's compiled cost analysis replace
the analytic counts (used by §Roofline calibration).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.hardware import Hardware, V5E

CHIP_LEVELS = (1, 2, 4, 8, 16, 32, 64, 128, 256)


@dataclasses.dataclass(frozen=True)
class CostOverride:
    """Measured (dry-run) costs for one (arch, mode, seq, batch) point."""
    flops: float
    hbm_bytes: float
    ar_bytes: float                 # all-reduce'd activation bytes
    a2a_bytes: float = 0.0          # all-to-all (MoE dispatch) bytes
    batch: int = 1                  # batch the measurement was taken at


@dataclasses.dataclass
class LatencyModel:
    cfg: ModelConfig
    mode: str = "prefill"           # decode | prefill | train
    seq: int = 128                  # context / prompt length
    hw: Hardware = V5E
    override: Optional[CostOverride] = None

    # ------------------------------------------------------------ op counts
    def _attn_layers(self) -> int:
        if self.cfg.family == "ssm":
            return 0
        if self.cfg.family == "hybrid":
            return self.cfg.num_layers // self.cfg.attn_every
        return self.cfg.num_layers

    def _ssm_layers(self) -> int:
        return self.cfg.num_layers if self.cfg.family in ("ssm", "hybrid") else 0

    def costs(self, batch: int):
        """Returns (flops, hbm_bytes, ar_bytes, a2a_bytes) for one step.

        ar_bytes: activation bytes entering tensor-parallel all-reduces
        (summed over layers, for the *full* token set — the per-chip time in
        ``latency`` rescales by the allocation's data/model split).
        a2a_bytes: MoE expert-dispatch all-to-all traffic.
        """
        if self.override is not None:
            scale = batch / self.override.batch
            return (self.override.flops * scale,
                    self.override.hbm_bytes * scale,
                    self.override.ar_bytes * scale,
                    self.override.a2a_bytes * scale)

        cfg = self.cfg
        d, hd = cfg.d_model, cfg.resolved_head_dim
        la = self._attn_layers()
        ls = self._ssm_layers()
        n_active = cfg.active_param_count()
        bpe = 2                                          # bf16
        ctx = min(self.seq, cfg.sliding_window) if cfg.sliding_window else self.seq

        if self.mode == "decode":
            tokens = batch
            flops = 2.0 * n_active * tokens
            flops += 4.0 * la * cfg.num_heads * hd * ctx * batch
            if ls:
                ssd = 6.0 * cfg.ssm_heads * cfg.ssm_state * cfg.ssm_head_dim
                flops += ls * batch * ssd
            hbm = n_active * bpe
            hbm += 2.0 * la * batch * ctx * cfg.num_kv_heads * hd * bpe   # KV read
            if ls:
                hbm += 2.0 * ls * batch * cfg.ssm_heads * cfg.ssm_state \
                    * cfg.ssm_head_dim * 4                                # state rw
            coll = 2.0 * cfg.num_layers * tokens * d * bpe
        else:
            tokens = batch * self.seq
            mult = 3.0 if self.mode == "train" else 1.0
            flops = 2.0 * n_active * tokens * mult
            # causal attention: S·ctx/2 effective context per token
            flops += mult * 2.0 * la * cfg.num_heads * hd * tokens * min(ctx, self.seq)
            if ls:
                # SSD chunked: ~2x the recurrent op count (dual quadratic form)
                ssd = 12.0 * cfg.ssm_heads * cfg.ssm_state * cfg.ssm_head_dim
                flops += mult * ls * tokens * ssd
            hbm = n_active * bpe * (3.0 if self.mode == "train" else 1.0)
            hbm += 4.0 * cfg.num_layers * tokens * d * bpe                # activations
            coll = 2.0 * cfg.num_layers * tokens * d * bpe
            if self.mode == "train":
                coll += 2.0 * cfg.param_count() * 4                       # grad AR
        a2a = 0.0
        if cfg.num_experts:
            # expert-parallel all-to-all: each routed token crosses twice
            a2a = 2.0 * cfg.num_layers * tokens * d * bpe \
                * cfg.experts_per_token
        return flops, hbm, coll, a2a

    # ------------------------------------------------------------- latency
    def max_useful_chips(self) -> int:
        """Shard-granularity clamp (paper Eq. 2's min(S, N_i))."""
        cfg = self.cfg
        widest = max(cfg.d_ff or 0, cfg.d_inner if cfg.ssm_state else 0,
                     cfg.num_heads * cfg.resolved_head_dim, cfg.d_model)
        return max(1, min(self.hw.chips_per_pod, widest // 128))

    def _widest(self) -> int:
        cfg = self.cfg
        return max(cfg.d_ff or 0, cfg.d_inner if cfg.ssm_state else 0,
                   cfg.num_heads * cfg.resolved_head_dim, cfg.d_model)

    def tp_width(self, chips: int) -> int:
        """Default tensor-parallel width (``latency`` searches over
        candidate widths; this is the cap). Wider models support wider TP
        (>=512 of the widest dim per chip keeps the MXU fed)."""
        return max(1, min(chips, self._widest() // 512, 32))

    def _tp_candidates(self, chips: int):
        cap = self.tp_width(chips)
        m = 1
        while m <= cap:
            yield m
            m *= 2

    def _batch_parallelism(self, batch: int) -> int:
        """How many data/sequence shards the workload can actually feed —
        the paper Eq. 2's inherent-parallelism limit N_i, TPU flavoured."""
        if self.mode == "decode":
            return max(1, batch)
        return max(1, batch * max(1, self.seq // 512))

    def usable_chips(self, chips: int, batch: int) -> int:
        m = self.tp_width(chips)
        return max(1, min(chips, m * self._batch_parallelism(batch),
                          self.max_useful_chips()))

    def min_chips_to_fit(self, batch: int = 1) -> int:
        """HBM feasibility floor — the TPU-native low-allocation wall (on
        GPU the paper sees exponential latency below the knee; on TPU the
        model simply does not fit)."""
        cfg = self.cfg
        bytes_needed = cfg.param_count() * 2.0
        if self.mode == "decode" and not cfg.is_attention_free:
            ctx = min(self.seq, cfg.sliding_window) if cfg.sliding_window else self.seq
            bytes_needed += (2.0 * self._attn_layers() * batch * ctx
                             * cfg.num_kv_heads * cfg.resolved_head_dim * 2)
        if self.mode == "train":
            bytes_needed = cfg.param_count() * 16.0      # fp32 master + adam + grads
        usable = self.hw.hbm_bytes * 0.9
        return max(1, int(np.ceil(bytes_needed / usable)))

    def latency(self, chips: int, batch: int) -> float:
        """min over tensor-parallel widths — the launcher picks the best
        (data × model) split for each allocation size."""
        chips = max(1, int(chips))
        if chips < self.min_chips_to_fit(batch):
            return float("inf")
        flops, hbm, ar_bytes, a2a_bytes = self.costs(batch)
        return min(self._latency_with_m(chips, batch, m, flops, hbm,
                                        ar_bytes, a2a_bytes)
                   for m in self._tp_candidates(chips))

    def _latency_with_m(self, chips, batch, m, flops, hbm, ar_bytes,
                        a2a_bytes) -> float:
        bp = self._batch_parallelism(batch)
        c_use = max(1, min(chips, m * bp, self.max_useful_chips()))

        # MXU occupancy: decode has `batch` rows in flight vs the 256 tile
        occupancy = (min(1.0, batch / self.hw.mxu_tile)
                     if self.mode == "decode" else 1.0)
        t_compute = flops / (c_use * self.hw.peak_flops * max(occupancy, 1e-3))
        t_memory = hbm / (c_use * self.hw.hbm_bw)

        # collectives: bandwidth term — ring all-reduce inside the TP group
        # on each data shard; latency term — 2 collectives per layer pay the
        # (m-1)-hop ring setup, the analogue of the paper's Eq.3 memory term
        # that *grows* with allocation size.
        links = self.hw.ici_bw * 2                      # 2 usable directions
        d_par = max(1, c_use // m)
        t_ar = 2.0 * (ar_bytes / d_par) * (m - 1) / max(m, 1) / links
        t_hop = 2.0 * self.cfg.num_layers * (m - 1) * 1e-6
        t_a2a = a2a_bytes / (c_use * links)
        t_serial = self.hw.dispatch_overhead * self.cfg.num_layers

        return max(t_compute, t_memory) + t_ar + t_hop + t_a2a + t_serial

    def latency_frac(self, frac: float, batch: int) -> float:
        return self.latency(round(frac * self.hw.chips_per_pod), batch)

    def throughput(self, chips: int, batch: int) -> float:
        """Inferences (batch items) per second."""
        return batch / self.latency(chips, batch)

    # ---------------------------------------------------------------- knee
    def knee_chips(self, batch: int, rel_tol: float = 0.05,
                   levels: Sequence[int] = CHIP_LEVELS) -> int:
        """Right-sizing knee (paper §3.1): the smallest feasible allocation
        whose latency is within ``rel_tol`` of the best achievable —
        "latency remains unchanged above the knee"."""
        lats = np.array([self.latency(c, batch) for c in levels])
        finite = lats[np.isfinite(lats)]
        if finite.size == 0:
            return levels[-1]
        best = finite.min()
        for c, lat in zip(levels, lats):
            if np.isfinite(lat) and lat <= best * (1 + rel_tol):
                return int(c)
        return levels[-1]

    def knee_frac(self, batch: int, rel_tol: float = 0.05) -> float:
        return self.knee_chips(batch, rel_tol) / self.hw.chips_per_pod

    def utility_curve(self, batch: int, levels: Sequence[int] = CHIP_LEVELS):
        """1/(E_t·S) per allocation — paper Eq. 6's maximization target."""
        return np.array([1.0 / (self.latency(c, batch) * c) for c in levels])
