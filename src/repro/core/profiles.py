"""Per-model serving profiles — the paper's Table 6 for our 10-arch zoo.

A profile bundles everything the scheduler needs about one hosted model:
the roofline latency function f_L(chips, batch), the knee allocation, the
SLO, and the efficacy-optimal (batch, chips) operating point. SLOs follow
the paper's construction (§6.1): latency-critical models get 25 ms,
mid-size 50 ms, compute-heavy 100/200 ms — all ≥ 2·f_L(knee, b_opt) so a
feasible operating point exists (Eq. 12).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence

from repro.configs import ARCHS, get_config
from repro.configs.base import ModelConfig
from repro.core import efficacy as eff
from repro.core.hardware import V5E, Hardware
from repro.core.latency_model import CHIP_LEVELS, LatencyModel

# paper-style SLO classes (seconds)
DEFAULT_SLOS = {
    "qwen2-0.5b": 0.025,
    "whisper-small": 0.025,
    "mamba2-1.3b": 0.025,
    "olmo-1b": 0.025,
    "granite-moe-3b-a800m": 0.050,
    "deepseek-7b": 0.050,
    "phi3.5-moe-42b-a6.6b": 0.100,
    "yi-9b": 0.100,
    "zamba2-7b": 0.100,
    "chameleon-34b": 0.200,
}


@dataclasses.dataclass
class ModelProfile:
    name: str
    cfg: ModelConfig
    lm: LatencyModel
    slo: float
    knee_chips: int
    opt_batch: int
    opt_chips: int
    max_batch: int = 64
    hw: Hardware = V5E

    @property
    def knee_frac(self) -> float:
        return self.knee_chips / self.hw.chips_per_pod

    @property
    def opt_frac(self) -> float:
        return self.opt_chips / self.hw.chips_per_pod

    def latency(self, chips: int, batch: int, multiplexed: bool = True) -> float:
        lat = self.lm.latency(chips, batch)
        if multiplexed:
            lat *= 1.0 + self.hw.multiplex_dilation
        return lat

    def runtime(self, batch: Optional[int] = None,
                chips: Optional[int] = None) -> float:
        """Paper Table 6 'Runtime': latency at the chosen operating point."""
        return self.latency(chips or self.opt_chips, batch or self.opt_batch)

    def min_chips(self, batch: Optional[int] = None) -> int:
        return self.lm.min_chips_to_fit(batch or self.opt_batch)

    def feasible_batch_for(self, budget_s: float, chips: int,
                           queue_len: int) -> int:
        """Largest batch <= queue_len finishing within ``budget_s``."""
        best = 0
        for b in range(1, min(self.max_batch, max(queue_len, 0)) + 1):
            if self.latency(chips, b) <= budget_s:
                best = b
            else:
                break
        return best


def build_profile(name: str, *, mode: str = "prefill", seq: int = 128,
                  slo: Optional[float] = None,
                  request_rate: float = 500.0,
                  hw: Hardware = V5E) -> ModelProfile:
    cfg = get_config(name)
    lm = LatencyModel(cfg, mode=mode, seq=seq, hw=hw)
    slo = slo if slo is not None else DEFAULT_SLOS.get(cfg.name, 0.1)
    knee = lm.knee_chips(16)
    pt = eff.optimize(lm, slo=slo, request_rate=request_rate,
                      total_chips=hw.chips_per_pod)
    # paper §5: pick from the high-efficacy region, then over-provision 5-10%
    opt_chips = pt.chips
    idx = CHIP_LEVELS.index(opt_chips) if opt_chips in CHIP_LEVELS else None
    if pt.feasible and idx is not None and idx + 1 < len(CHIP_LEVELS):
        # one level of headroom if it still fits the knee budget
        if CHIP_LEVELS[idx + 1] <= max(knee, opt_chips):
            opt_chips = CHIP_LEVELS[idx + 1]
    return ModelProfile(
        name=cfg.name, cfg=cfg, lm=lm, slo=slo, knee_chips=knee,
        opt_batch=pt.batch, opt_chips=opt_chips, hw=hw)


def default_zoo(names: Optional[Sequence[str]] = None,
                rates: Optional[Dict[str, float]] = None,
                hw: Hardware = V5E) -> Dict[str, ModelProfile]:
    names = list(names or ARCHS.keys())
    out = {}
    for n in names:
        rate = (rates or {}).get(n, 500.0)
        prof = build_profile(n, request_rate=rate, hw=hw)
        out[prof.name] = prof
    return out
