"""Target-hardware constants (TPU v5e pod) used by the roofline latency
model, the knee analysis, and EXPERIMENTS.md §Roofline.

These are the constants mandated by the brief: 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI. The dispatch overhead is the TPU analogue
of the paper's kernel-launch time t_np (XLA executable dispatch + ICI
collective launch)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    ici_bw: float = 50e9                # bytes/s per link
    ici_links: int = 4                  # 2D torus: 4 links per chip
    hbm_bytes: float = 16e9             # per chip
    chips_per_pod: int = 256
    dispatch_overhead: float = 6e-6     # s per fused layer step (t_np analogue)
    mxu_tile: int = 256                 # MXU-efficient per-dim tile
    # host-side contention when many engines multiplex one pod (paper §4.2
    # finds <3% with SM isolation; sub-mesh isolation behaves the same)
    multiplex_dilation: float = 0.02


V5E = Hardware()


# paper-comparison GPU (for the analytic-model benchmarks reproducing Fig. 2-4)
@dataclasses.dataclass(frozen=True)
class GPULike:
    name: str = "v100-like"
    n_units: int = 80                   # SMs
    t_p: float = 40.0                   # model units (paper Fig. 4 uses 40/10)
    t_np: float = 10.0


V100_LIKE = GPULike()
