"""Discrete-event serving simulator — the control plane testbed.

The simulator owns virtual time; run durations come from each model's
roofline latency function (``ModelProfile.latency``). Scheduler policies
(``repro.core.scheduler``) decide, at every event (arrival burst, run
completion, session boundary), which (model, chips, batch) runs to start —
with the invariant that aggregate allocated chip-fraction never exceeds 1.0
(paper: "the GPU must not be over-subscribed"), except for policies that
explicitly model uncontrolled sharing (Fixed-Batch MPS).
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Sequence

from repro.core.eventloop import LoopConfig, run_event_loop
from repro.core.profiles import ModelProfile
from repro.serving.request import Request, RequestGenerator, RequestQueue


@dataclasses.dataclass
class RunRequest:
    model: str
    chips: int
    batch: int
    dilation: float = 1.0           # >1 models interference (FB-MPS only)
    oversubscribe: bool = False


@dataclasses.dataclass
class Run:
    model: str
    chips: int
    frac: float
    batch: int
    start: float
    end: float
    requests: List[Request]


@dataclasses.dataclass
class SimConfig:
    duration: float = 10.0
    total_chips: int = 256
    drain: bool = False             # run until all work completes (Table 1)
    drop_expired: bool = True
    dispatch_gap: float = 100e-6    # engine-switch gap (paper §1: <100 µs)
    max_time: float = 600.0
    # horizon up to which rate-based generators materialize arrivals; None
    # -> ``duration``. Drain runs with rate generators MUST set this (or a
    # nonzero duration): the pre-fix behavior materialized arrivals up to
    # t=0 and silently simulated an empty workload.
    arrival_horizon: Optional[float] = None


@dataclasses.dataclass
class ModelMetrics:
    completed: int = 0
    violated: int = 0
    runtime: float = 0.0
    runs: int = 0

    def throughput(self, duration: float) -> float:
        return self.completed / duration if duration > 0 else 0.0


@dataclasses.dataclass
class SimResult:
    duration: float
    utilization: float
    per_model: Dict[str, ModelMetrics]
    makespan: float

    @property
    def total_completed(self) -> int:
        return sum(m.completed for m in self.per_model.values())

    @property
    def total_violated(self) -> int:
        return sum(m.violated for m in self.per_model.values())

    def throughput(self, model: Optional[str] = None) -> float:
        if model:
            return self.per_model[model].throughput(self.duration)
        return self.total_completed / self.duration


class Simulator:
    def __init__(self, profiles: Dict[str, ModelProfile], policy,
                 generators: Sequence[RequestGenerator],
                 sim: Optional[SimConfig] = None):
        self.profiles = profiles
        self.policy = policy
        self.sim = sim or SimConfig()
        # latencies untracked: SimResult never reads them, and production
        # rates complete 10^5-10^6 requests per run
        self.queues: Dict[str, RequestQueue] = {
            name: RequestQueue(name, p.slo, track_latency=False)
            for name, p in profiles.items()}
        self.generators = list(generators)
        # Hot-path state: runs live in a dict keyed by a start sequence
        # number, completions in a min-heap of (end, seq), and the
        # allocated / knee-credited fractions are maintained incrementally
        # — each event is O(log n) instead of the O(n) full scans that made
        # fig9/fig11 at 256 chips O(n^2) overall.
        self._running: Dict[int, Run] = {}
        self._end_heap: List = []
        self._run_seq = 0
        self._alloc_frac = 0.0      # sum of frac over in-flight runs
        self._busy_knee = 0.0       # sum of min(frac, knee_frac)
        self.metrics: Dict[str, ModelMetrics] = {
            name: ModelMetrics() for name in profiles}
        self._util_area = 0.0
        self._last_t = 0.0
        self._makespan = 0.0

    # ------------------------------------------------------------------
    @property
    def running(self) -> List[Run]:
        """Snapshot of in-flight runs (list view kept for policies/tests)."""
        return list(self._running.values())

    def free_frac(self, now: float) -> float:
        # completions are drained before every planning point, so the
        # incremental accumulator is exact here
        return 1.0 - self._alloc_frac

    def _advance(self, t: float) -> None:
        # paper §6.1: utilization credits each model only up to its knee —
        # allocation beyond the knee is waste, not utilization
        self._util_area += min(self._busy_knee, 1.0) * (t - self._last_t)
        self._last_t = t

    def _start_runs(self, now: float, reqs: List[RunRequest]) -> None:
        for rr in reqs:
            prof = self.profiles[rr.model]
            q = self.queues[rr.model]
            batch = q.pop_batch(rr.batch, now, self.sim.drop_expired)
            if not batch:
                continue
            frac = rr.chips / self.sim.total_chips
            if not rr.oversubscribe and frac > self.free_frac(now) + 1e-9:
                for req in batch:       # shouldn't happen: put back
                    q.push(req)
                continue
            lat = prof.latency(rr.chips, len(batch)) * rr.dilation
            run = Run(rr.model, rr.chips, frac, len(batch), now,
                      now + lat + self.sim.dispatch_gap, batch)
            seq = self._run_seq
            self._run_seq += 1
            self._running[seq] = run
            heapq.heappush(self._end_heap, (run.end, seq))
            self._alloc_frac += frac
            self._busy_knee += min(frac, prof.knee_frac)
            m = self.metrics[rr.model]
            m.runs += 1
            m.runtime += lat

    def _pop_done(self, now: float, epsilon: float = 1e-12) -> List[Run]:
        done = []
        while self._end_heap and self._end_heap[0][0] <= now + epsilon:
            _, seq = heapq.heappop(self._end_heap)
            run = self._running.pop(seq)
            self._alloc_frac -= run.frac
            self._busy_knee -= min(run.frac,
                                   self.profiles[run.model].knee_frac)
            done.append(run)
        if not self._running:           # re-zero: no float-drift build-up
            self._alloc_frac = 0.0
            self._busy_knee = 0.0
        return done

    def _finish(self, run: Run, now: float) -> None:
        q = self.queues[run.model]
        q.complete(run.requests, now)
        m = self.metrics[run.model]
        m.completed += len(run.requests)
        m.violated = q.violated
        self._makespan = max(self._makespan, now)

    # ----------------------------------------- EventLoopHooks (core loop)
    # The arrival / epsilon / cutoff / drain semantics live ONCE in
    # ``repro.core.eventloop`` — the same skeleton drives the real-engine
    # Controller, so the two planes cannot drift. These hooks are the
    # analytic machinery the skeleton calls into.
    def deliver(self, req: Request) -> None:
        self.queues[req.model].push(req)

    def next_completion(self) -> float:
        return self._end_heap[0][0] if self._end_heap else math.inf

    def next_wakeup(self, now: float) -> float:
        return (self.policy.next_wakeup(now)
                if hasattr(self.policy, "next_wakeup") else math.inf)

    def advance(self, t: float) -> None:
        self._advance(t)

    def fire(self, now: float, epsilon: float = 1e-12) -> int:
        # completions (heap pop + incremental accumulator update); atomic
        # analytic runs dispatch nothing real, so the event cost is 0
        for r in self._pop_done(now, epsilon):
            self._finish(r, now)
        return 0

    def plan(self, now: float) -> None:
        reqs = self.policy.plan(now, self)
        if reqs:
            self._start_runs(now, reqs)

    def drained(self) -> bool:
        return (not self._running
                and all(len(q) == 0 for q in self.queues.values()))

    # ------------------------------------------------------------------
    def run(self) -> SimResult:
        sim = self.sim
        run_event_loop(
            LoopConfig(duration=sim.duration, drain=sim.drain,
                       max_time=sim.max_time,
                       arrival_horizon=sim.arrival_horizon),
            self.generators, self)
        duration = (self._makespan if sim.drain else sim.duration) or 1e-9
        for name, q in self.queues.items():
            self.metrics[name].violated = q.violated + len(q)  # unserved count
        return SimResult(
            duration=duration,
            utilization=self._util_area / duration,
            per_model=self.metrics,
            makespan=self._makespan)
