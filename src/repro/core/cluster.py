"""Multi-pod cluster serving (paper §7.1, Fig. 12).

Three deployment modes over ``n_pods`` pods:
  * ``exclusive``  — one model per pod (the paper's 1-GPU-per-DNN baseline),
  * ``temporal``   — every model on every pod, temporal sharing per pod,
  * ``dstack``     — every model on every pod, D-STACK per pod.
Requests are routed to the least-loaded eligible pod (shortest queue+work).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.core.profiles import ModelProfile
from repro.core.scheduler import DStackPolicy, TemporalPolicy
from repro.core.simulator import SimConfig, SimResult, Simulator
from repro.serving.request import Request, RequestGenerator


@dataclasses.dataclass
class ClusterResult:
    per_pod: List[SimResult]

    @property
    def total_throughput(self) -> float:
        return sum(r.throughput() for r in self.per_pod)

    def model_throughput(self, name: str) -> float:
        return sum(r.per_model[name].throughput(r.duration)
                   for r in self.per_pod if name in r.per_model)

    @property
    def utilization(self) -> float:
        return sum(r.utilization for r in self.per_pod) / len(self.per_pod)

    @property
    def total_violated(self) -> int:
        return sum(r.total_violated for r in self.per_pod)


class _Replay:
    """Feeds a pre-routed arrival list through the generator interface."""

    def __init__(self, requests: List[Request]):
        self._reqs = sorted(requests, key=lambda r: r.arrival)

    def until(self, t_end: float) -> List[Request]:
        out = [r for r in self._reqs if r.arrival < t_end]
        self._reqs = [r for r in self._reqs if r.arrival >= t_end]
        return out


def run_cluster(profiles: Dict[str, ModelProfile],
                generators: Sequence[RequestGenerator],
                mode: str = "dstack", n_pods: int = 4,
                duration: float = 10.0,
                sim_cfg: Optional[SimConfig] = None) -> ClusterResult:
    sim_cfg = sim_cfg or SimConfig(duration=duration)
    names = list(profiles)
    arrivals: List[Request] = []
    for g in generators:
        arrivals.extend(g.until(duration))
    arrivals.sort(key=lambda r: r.arrival)

    if mode == "exclusive":
        pod_models = [[names[i % len(names)]] for i in range(n_pods)]
    else:
        pod_models = [names for _ in range(n_pods)]

    # least-loaded routing: track outstanding work routed per pod
    load = [0.0] * n_pods
    routed: List[List[Request]] = [[] for _ in range(n_pods)]
    for req in arrivals:
        eligible = [i for i in range(n_pods) if req.model in pod_models[i]]
        tgt = min(eligible, key=lambda i: load[i])
        routed[tgt].append(req)
        load[tgt] += profiles[req.model].runtime() / max(
            profiles[req.model].opt_batch, 1)

    results = []
    for i in range(n_pods):
        profs = {n: profiles[n] for n in pod_models[i]}
        if mode == "dstack":
            policy = DStackPolicy(profs)
        else:
            policy = TemporalPolicy(profs)
        sim = Simulator(profs, policy, [_Replay(routed[i])],
                        dataclasses.replace(sim_cfg))
        results.append(sim.run())
    return ClusterResult(per_pod=results)
