"""Optimal (batch, chip-fraction) via the paper's Efficacy metric (§5).

  η = Throughput / (Latency · GPU%)          (Eq. 7)
    = b / (f_L(p, b)² · p)                   (Eq. 9)

subject to 1 <= b <= MaxBatch (Eq. 10), f_L + C <= SLO (Eq. 11, C = batch
assembly time = b/request_rate) and f_L <= SLO/2 (Eq. 12).

The paper solves this with MATLAB ``fmincon``; our decision lattice is tiny
(9 chip levels × ~10 batch levels) so exhaustive search is *exact*.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.latency_model import CHIP_LEVELS, LatencyModel

BATCH_LEVELS = (1, 2, 4, 8, 12, 16, 24, 32, 48, 64)


@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    batch: int
    chips: int
    frac: float
    latency: float
    throughput: float
    efficacy: float
    feasible: bool


def efficacy(batch: int, latency: float, frac: float) -> float:
    if latency <= 0 or frac <= 0:
        return 0.0
    return batch / (latency ** 2 * frac)                      # Eq. 9


def feasible(latency: float, batch: int, slo: float,
             request_rate: float) -> bool:
    assembly = batch / request_rate if request_rate > 0 else 0.0
    return (latency + assembly <= slo) and (latency <= slo / 2)   # Eqs. 11–12


def optimize(lm: LatencyModel, *, slo: float, request_rate: float,
             max_batch: int = 64,
             chip_levels: Sequence[int] = CHIP_LEVELS,
             batch_levels: Sequence[int] = BATCH_LEVELS,
             total_chips: int = 256) -> OperatingPoint:
    """Exhaustive search of the (batch, chips) lattice for max efficacy.

    In addition to the paper's Eqs. 10-12 we require queueing stability
    (service rate b/f_L >= arrival rate) whenever a sustainable point
    exists — without it the "optimal" engine can be overrun at high rates.
    """
    best: Optional[OperatingPoint] = None
    best_unsust: Optional[OperatingPoint] = None
    fallback: Optional[OperatingPoint] = None
    for b in batch_levels:
        if b > max_batch:
            continue
        for c in chip_levels:
            lat = lm.latency(c, b)
            if not np.isfinite(lat):
                continue
            frac = c / total_chips
            pt = OperatingPoint(
                batch=b, chips=c, frac=frac, latency=lat,
                throughput=b / lat, efficacy=efficacy(b, lat, frac),
                feasible=feasible(lat, b, slo, request_rate))
            sustainable = (request_rate <= 0) or (b / lat >= request_rate)
            if pt.feasible and sustainable and (
                    best is None or pt.efficacy > best.efficacy):
                best = pt
            if pt.feasible and (best_unsust is None
                                or pt.efficacy > best_unsust.efficacy):
                best_unsust = pt
            if fallback is None or pt.throughput > fallback.throughput:
                fallback = pt
    if best is not None:
        return best
    if best_unsust is not None:
        return best_unsust
    # nothing feasible: best-effort max-throughput point, flagged infeasible
    return fallback


def efficacy_surface(lm: LatencyModel, *,
                     chip_levels: Sequence[int] = CHIP_LEVELS,
                     batch_levels: Sequence[int] = BATCH_LEVELS,
                     total_chips: int = 256) -> np.ndarray:
    """(len(batch_levels), len(chip_levels)) η grid — paper Fig. 7."""
    grid = np.zeros((len(batch_levels), len(chip_levels)))
    for i, b in enumerate(batch_levels):
        for j, c in enumerate(chip_levels):
            grid[i, j] = efficacy(b, lm.latency(c, b), c / total_chips)
    return grid
