"""D-STACK: dynamic, fair, opportunistic spatio-temporal scheduling (§6).

Faithful mechanics:
  * **Sessions** — period = largest SLO among hosted models; a model with
    SLO_i must be scheduled ≥ session/SLO_i times per session (§6.1).
  * **EDF mandatory pass** — models whose oldest queued deadline is at risk
    start first, at their efficacy-optimal chips (reduced toward the
    min-fit if capacity is short — "D-STACK can schedule a model below its
    knee, albeit with higher latency").
  * **Fair opportunistic pass** — leftover capacity backfills inactive
    models, prioritized by a scoreboard of least GPU runtime over the last
    ``window`` sessions (proportional-fairness, CFS-like); batch is sized
    to the time budget (feasible_batch_for).
  * **No oversubscription** — aggregate chip-fraction ≤ 1 always.
  * Runs are never preempted; consecutive runs of the tightest-SLO model
    are spread as far apart as its SLO allows to open room for long runs.
"""
from __future__ import annotations

import math
from typing import Dict, List

from repro.core.scheduler.base import running_models
from repro.core.simulator import RunRequest


class DStackPolicy:
    name = "dstack"

    def __init__(self, profiles, max_batch: int = 16, window: int = 10,
                 slack: float = 1.25):
        self.max_batch = max_batch
        self.window = window
        self.slack = slack
        self.session = max(p.slo for p in profiles.values())
        self._session_idx = -1
        # scoreboard: runtime per model over the last `window` sessions
        self._score: Dict[str, List[float]] = {n: [0.0] for n in profiles}
        self._last_start: Dict[str, float] = {n: -math.inf for n in profiles}

    # ------------------------------------------------------------ helpers
    def _roll_session(self, now: float) -> None:
        idx = int(now / self.session)
        while self._session_idx < idx:
            self._session_idx += 1
            for hist in self._score.values():
                hist.append(0.0)
                if len(hist) > self.window:
                    hist.pop(0)

    def _runtime_score(self, name: str) -> float:
        return sum(self._score[name])

    def next_wakeup(self, now: float) -> float:
        return (int(now / self.session) + 1) * self.session

    def _want_chips(self, prof, queue_len: int) -> int:
        """Dynamic adaptation (§6.1.2): scale toward the knee under queue
        pressure; stay at the efficacy optimum when keeping up."""
        if queue_len > 4 * max(prof.opt_batch, 1):
            return max(prof.opt_chips, prof.knee_chips)
        if queue_len > 2 * max(prof.opt_batch, 1):
            return min(max(prof.opt_chips * 2, prof.opt_chips),
                       max(prof.knee_chips, prof.opt_chips))
        return prof.opt_chips

    def _fit_chips(self, prof, want: int, free_chips: int,
                   total: int) -> int:
        """Largest power-of-two allocation <= min(want, free, pod), >= min
        fit — steps derive from the pod size, not a hard-coded 256-chip
        table (pods are not always 256 chips)."""
        cap = min(want, free_chips, total)
        if cap < 1:
            return 0
        c = 1 << (int(cap).bit_length() - 1)
        return c if c >= prof.min_chips() else 0

    # ---------------------------------------------------------------- plan
    def plan(self, now: float, sim) -> List[RunRequest]:
        self._roll_session(now)
        out: List[RunRequest] = []
        active = running_models(sim)
        total = sim.sim.total_chips
        free_chips = int(round(sim.free_frac(now) * total))

        # ---- mandatory pass: EDF over models with deadline pressure
        cands = []
        for n, prof in sim.profiles.items():
            if n in active or len(sim.queues[n]) == 0:
                continue
            ddl = sim.queues[n].oldest_deadline()
            runtime = prof.runtime()
            urgent = ddl <= now + self.slack * runtime + sim.sim.dispatch_gap
            cands.append((ddl, n, urgent))
        cands.sort()

        started = set()
        for ddl, n, urgent in cands:
            if not urgent:
                continue
            prof = sim.profiles[n]
            want = self._want_chips(prof, len(sim.queues[n]))
            chips = self._fit_chips(prof, want, free_chips, total)
            if chips == 0:
                continue
            budget = max(ddl - now, prof.slo / 2)
            b = prof.feasible_batch_for(budget, chips, len(sim.queues[n]))
            b = max(1, min(b if b else 1, self.max_batch))
            out.append(RunRequest(n, chips, b))
            free_chips -= chips
            started.add(n)
            self._book(n, prof.latency(chips, b), now)

        # ---- opportunistic pass: fairness-ordered backfill
        avail = [(self._runtime_score(n), n) for _, n, _ in cands
                 if n not in started]
        avail.sort()
        for _, n in avail:
            prof = sim.profiles[n]
            want = self._want_chips(prof, len(sim.queues[n]))
            chips = self._fit_chips(prof, want, free_chips, total)
            if chips == 0:
                continue
            # budget: must clear before this model's own deadline AND leave
            # the tightest-SLO model room for its next mandatory run
            budget = min(prof.slo / 2,
                         sim.queues[n].oldest_deadline() - now)
            b = prof.feasible_batch_for(budget, chips, len(sim.queues[n]))
            if b < 1:
                continue
            b = min(b, self.max_batch)
            out.append(RunRequest(n, chips, b))
            free_chips -= chips
            self._book(n, prof.latency(chips, b), now)
        return out

    def _book(self, name: str, runtime: float, now: float) -> None:
        self._score[name][-1] += runtime
        self._last_start[name] = now
