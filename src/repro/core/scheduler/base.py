"""Scheduler policy protocol + shared helpers."""
from __future__ import annotations

import math
from typing import List, Protocol

from repro.core.simulator import RunRequest


class Policy(Protocol):
    name: str

    def plan(self, now: float, sim) -> List[RunRequest]:
        ...

    def next_wakeup(self, now: float) -> float:
        return math.inf


def chips_for_frac(frac: float, total: int = 256) -> int:
    """Largest power-of-two chip count <= frac·total (sub-meshes are
    rectangular power-of-two slices of the torus)."""
    c = int(frac * total + 1e-9)
    if c <= 0:
        return 0
    return 1 << (c.bit_length() - 1)


def running_models(sim) -> set:
    return {r.model for r in sim.running}
