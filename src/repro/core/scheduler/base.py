"""Scheduler policy protocol + shared helpers."""
from __future__ import annotations

import math
from typing import Any, Dict, List, Protocol, runtime_checkable

from repro.core.simulator import RunRequest


@runtime_checkable
class SchedView(Protocol):
    """What a ``Policy`` may observe at a planning point — the adapter
    between the control plane's policy objects and whichever data plane is
    underneath. Both the analytic ``repro.core.simulator.Simulator`` and the
    real-engine ``repro.serving.pool.EnginePool`` implement this, so the
    same policy instances drive either without modification:

      profiles    name -> ModelProfile (latency fn, knee, SLO, operating pt)
      queues      name -> RequestQueue (len, oldest_deadline)
      running     in-flight runs; each exposes at least ``.model``/``.frac``
      free_frac   1 - aggregate allocated chip fraction at ``now``
      sim         capacity config: ``.total_chips`` and ``.dispatch_gap``
    """

    profiles: Dict[str, Any]
    queues: Dict[str, Any]
    sim: Any

    @property
    def running(self) -> List[Any]: ...

    def free_frac(self, now: float) -> float: ...


class Policy(Protocol):
    name: str

    def plan(self, now: float, sim: SchedView) -> List[RunRequest]:
        ...

    def next_wakeup(self, now: float) -> float:
        return math.inf


def chips_for_frac(frac: float, total: int) -> int:
    """Largest power-of-two chip count <= frac·total (sub-meshes are
    rectangular power-of-two slices of the torus). ``total`` is the hosting
    pod's chip count — pass the profile's ``hw.chips_per_pod`` rather than
    assuming a 256-chip pod."""
    c = int(frac * total + 1e-9)
    if c <= 0:
        return 0
    return 1 << (c.bit_length() - 1)


def running_models(sim) -> set:
    return {r.model for r in sim.running}
