"""Scheduler policy protocol + shared helpers."""
from __future__ import annotations

import math
from typing import Any, Dict, List, Protocol, runtime_checkable

from repro.core.simulator import RunRequest


@runtime_checkable
class SchedView(Protocol):
    """What a ``Policy`` may observe at a planning point — the adapter
    between the control plane's policy objects and whichever data plane is
    underneath. Both the analytic ``repro.core.simulator.Simulator`` and the
    real-engine ``repro.serving.pool.EnginePool`` implement this, so the
    same policy instances drive either without modification:

      profiles    name -> ModelProfile (latency fn, knee, SLO, operating pt)
      queues      name -> RequestQueue (len, oldest_deadline)
      running     in-flight runs; each exposes at least ``.model``/``.frac``
      free_frac   1 - aggregate allocated chip fraction at ``now``
      sim         capacity config: ``.total_chips`` and ``.dispatch_gap``
    """

    profiles: Dict[str, Any]
    queues: Dict[str, Any]
    sim: Any

    @property
    def running(self) -> List[Any]: ...

    def free_frac(self, now: float) -> float: ...


@runtime_checkable
class PageView(Protocol):
    """What the tick-granular ``repro.serving.plan.StepPlanner`` may
    observe of a data plane's KV-memory state when building a
    ``StepPlan`` — the page-pool leg of the scheduler/data-plane
    boundary, as ``SchedView`` is the chip-capacity leg. Implemented by
    ``repro.serving.engine.InferenceEngine``; an unpaged plane (ring
    slots, pure-SSM state) reports ``paged == False`` with zero pages
    and fully-backed slots, so planners never branch on architecture:

      paged                 whether KV memory is the admission gate
      page_size             tokens per page (meaningful when paged)
      free_pages/total_pages   pool headroom (0 when unpaged)
      free_slots/slot_len      batch-lane headroom and per-lane horizon
      slot_pos(slot)           tokens written to a resident lane
      reserved_tokens(slot)    horizon its pages currently cover (grows
                               lazily under PlannerConfig.lazy)
      slot_page_count(slot)    pages the lane owns (0 when unpaged)
      kv_pages_needed(tokens)  page arithmetic for an admission horizon
    """

    paged: bool
    page_size: int
    slot_len: int

    @property
    def free_pages(self) -> int: ...

    @property
    def total_pages(self) -> int: ...

    @property
    def free_slots(self) -> int: ...

    def slot_pos(self, slot: int) -> int: ...

    def reserved_tokens(self, slot: int) -> int: ...

    def slot_page_count(self, slot: int) -> int: ...

    def kv_pages_needed(self, tokens: int) -> int: ...


class Policy(Protocol):
    name: str

    def plan(self, now: float, sim: SchedView) -> List[RunRequest]:
        ...

    def next_wakeup(self, now: float) -> float:
        return math.inf


def chips_for_frac(frac: float, total: int) -> int:
    """Largest power-of-two chip count <= frac·total (sub-meshes are
    rectangular power-of-two slices of the torus). ``total`` is the hosting
    pod's chip count — pass the profile's ``hw.chips_per_pod`` rather than
    assuming a 256-chip pod."""
    c = int(frac * total + 1e-9)
    if c <= 0:
        return 0
    return 1 << (c.bit_length() - 1)


def running_models(sim) -> set:
    return {r.model for r in sim.running}


def speculation_worthwhile(decode_batch: int,
                           knee_batch: "int | None") -> bool:
    """Acceptance-independent speculation gate: drafting pays only while
    decode is MEMORY-bound — below the roofline knee, a verify dispatch
    over k+1 tokens streams the same weights/KV bytes as the single-token
    step it replaces, so the extra FLOPs are free. At or past the knee
    the accelerator is compute-bound and verification FLOPs displace
    decode FLOPs one-for-one (speculation can only break even, and loses
    whenever a draft is rejected). ``knee_batch`` is the decode batch
    size at the knee — the same knee D-STACK's scheduler derives per
    model from its latency profile (§3.1) — or None to always speculate
    (CPU-scale tests, where the knee is not meaningful)."""
    if knee_batch is None:
        return True
    return int(decode_batch) < int(knee_batch)
