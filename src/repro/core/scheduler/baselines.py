"""Baseline multiplexing policies the paper compares against (§6.3/§7):

  * ``TemporalPolicy``      — pure temporal sharing, full pod per model,
                              time slices ∝ SLO, Clipper/Nexus-style
                              adaptive batching.
  * ``FixedBatchMPSPolicy`` — uncontrolled spatial sharing (default MPS):
                              every model runs when it has work, fixed
                              batch 16, interference dilates latency.
  * ``GSLICEPolicy``        — static spatial partitions at (normalized)
                              knee fractions, adaptive batching, no
                              temporal scheduling.
  * ``TritonPolicy``        — Triton-like: temporal occupancy with dynamic
                              batching, EDF model pick.
  * ``MaxMinPolicy``        — max-min fair spatial allocation (smallest
                              demand first).
  * ``MaxThroughputPolicy`` — packs runs by predicted throughput/chip,
                              fairness-blind.
"""
from __future__ import annotations

import math
from typing import Dict, List

from repro.core.scheduler.base import chips_for_frac, running_models
from repro.core.simulator import RunRequest


class TemporalPolicy:
    name = "temporal"

    def __init__(self, profiles, max_batch: int = 16):
        self.max_batch = max_batch
        total_slo = sum(p.slo for p in profiles.values())
        self._order = sorted(profiles, key=lambda n: profiles[n].slo)
        self._idx = 0

    def plan(self, now: float, sim) -> List[RunRequest]:
        if sim.running:
            return []
        total = sim.sim.total_chips
        for _ in range(len(self._order)):
            name = self._order[self._idx % len(self._order)]
            self._idx += 1
            prof = sim.profiles[name]
            q = sim.queues[name]
            if len(q) == 0:
                continue
            # adaptive batching (Clipper/Nexus): largest batch meeting SLO/2
            b = prof.feasible_batch_for(prof.slo / 2, total, len(q))
            b = max(1, min(b, self.max_batch))
            return [RunRequest(name, total, b)]
        return []


class FixedBatchMPSPolicy:
    name = "fixed_batch_mps"

    def __init__(self, profiles, batch: int = 16, interference: float = 0.15):
        self.batch = batch
        self.interference = interference

    def plan(self, now: float, sim) -> List[RunRequest]:
        out = []
        active = running_models(sim)
        waiting = [n for n in sim.profiles
                   if n not in active and len(sim.queues[n]) > 0]
        k = len(active) + len(waiting)
        if k == 0:
            return []
        total = sim.sim.total_chips
        share = max(1, total // max(k, 1))
        dilation = 1.0 + self.interference * max(0, k - 1)
        for n in waiting:
            prof = sim.profiles[n]
            chips = max(share, prof.min_chips())
            out.append(RunRequest(n, chips, self.batch,
                                  dilation=dilation, oversubscribe=True))
        return out


class GSLICEPolicy:
    name = "gslice"

    def __init__(self, profiles, max_batch: int = 16):
        self.max_batch = max_batch
        total_knee = sum(p.knee_frac for p in profiles.values())
        scale = min(1.0, 1.0 / total_knee) if total_knee > 0 else 1.0
        # static partition, normalized when over-committed (paper's GSLICE
        # critique: each model may get less than its knee)
        self.partition: Dict[str, int] = {}
        for n, p in profiles.items():
            self.partition[n] = max(1, chips_for_frac(p.knee_frac * scale,
                                                      p.hw.chips_per_pod))

    def plan(self, now: float, sim) -> List[RunRequest]:
        out = []
        active = running_models(sim)
        for n, prof in sim.profiles.items():
            if n in active or len(sim.queues[n]) == 0:
                continue
            chips = self.partition[n]
            if prof.min_chips() > chips:
                # model cannot even fit its slice — GSLICE failure mode
                chips = prof.min_chips()
            b = prof.feasible_batch_for(prof.slo / 2, chips, len(sim.queues[n]))
            b = max(1, min(b, self.max_batch))
            out.append(RunRequest(n, chips, b))
        return out


class TritonPolicy:
    name = "triton"

    def __init__(self, profiles, max_batch: int = 16):
        self.max_batch = max_batch

    def plan(self, now: float, sim) -> List[RunRequest]:
        if sim.running:
            return []
        # EDF over models with work; dynamic batcher takes what's queued
        cands = [(sim.queues[n].oldest_deadline(), n)
                 for n in sim.profiles if len(sim.queues[n]) > 0]
        if not cands:
            return []
        _, name = min(cands)
        prof = sim.profiles[name]
        b = min(len(sim.queues[name]), self.max_batch)
        return [RunRequest(name, sim.sim.total_chips, max(1, b))]


class MaxMinPolicy:
    """Max-min fair spatial schedule: maximize the placement of the
    smallest demand first (paper §6.3, [9])."""
    name = "maxmin"

    def __init__(self, profiles, max_batch: int = 16):
        self.max_batch = max_batch

    def plan(self, now: float, sim) -> List[RunRequest]:
        out = []
        active = running_models(sim)
        free = sim.free_frac(now)
        total = sim.sim.total_chips
        # smallest knee demand first
        for n in sorted(sim.profiles, key=lambda n: sim.profiles[n].knee_chips):
            if n in active or len(sim.queues[n]) == 0:
                continue
            prof = sim.profiles[n]
            chips = max(prof.knee_chips, prof.min_chips())
            if chips / total <= free + 1e-9:
                b = prof.feasible_batch_for(prof.slo / 2, chips,
                                            len(sim.queues[n]))
                b = max(1, min(b, self.max_batch))
                out.append(RunRequest(n, chips, b))
                free -= chips / total
        return out


class MaxThroughputPolicy:
    """Packs whatever maximizes aggregate predicted throughput — the
    fairness-blind upper bound of paper Fig. 10."""
    name = "max_throughput"

    def __init__(self, profiles, max_batch: int = 16):
        self.max_batch = max_batch

    def plan(self, now: float, sim) -> List[RunRequest]:
        out = []
        active = set(running_models(sim))
        free = sim.free_frac(now)
        total = sim.sim.total_chips
        cands = []
        for n, prof in sim.profiles.items():
            if n in active or len(sim.queues[n]) == 0:
                continue
            chips = max(prof.opt_chips, prof.min_chips())
            b = min(len(sim.queues[n]), prof.opt_batch, self.max_batch)
            thr_per_chip = b / prof.latency(chips, b) / chips
            cands.append((-thr_per_chip, n, chips, b))
        for _, n, chips, b in sorted(cands):
            if chips / total <= free + 1e-9:
                out.append(RunRequest(n, chips, max(1, b)))
                free -= chips / total
        return out
