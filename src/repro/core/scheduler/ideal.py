"""The paper's *ideal* spatio-temporal scheduler (§6.2): a theoretical
slot-quantized schedule at per-kernel granularity with free preemption,
exact per-kernel knee knowledge, and instantaneous allocation changes.

Any real non-preemptive system under-utilizes relative to this bound;
paper Fig. 9d shows D-STACK reaching ~86% utilization vs ~95% ideal and
>90% of its throughput. ``benchmarks/fig9_schedulers.py`` reproduces that
comparison for our model zoo.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

from repro.core.profiles import ModelProfile
from repro.serving.request import Request, RequestGenerator, RequestQueue
from repro.core.simulator import ModelMetrics, SimResult


@dataclasses.dataclass
class Kernel:
    knee_frac: float           # allocation at which it saturates
    remaining: float           # seconds of work at-or-above the knee


@dataclasses.dataclass
class Job:
    model: str
    deadline: float
    kernels: List[Kernel]
    requests: List[Request]

    @property
    def done(self) -> bool:
        return not self.kernels


def best_operating_point(prof: ModelProfile, max_batch: int = 16):
    """The ideal scheduler knows each model's most chip-efficient feasible
    point: minimize chip-seconds per request s.t. latency <= SLO/2."""
    from repro.core.latency_model import CHIP_LEVELS
    best = None
    for b in (1, 2, 4, 8, 16, 32, 64):
        if b > max_batch:
            continue
        for c in CHIP_LEVELS:
            lat = prof.latency(c, b, multiplexed=False)
            if not math.isfinite(lat) or lat > prof.slo / 2:
                continue
            cost = lat * c / b                       # chip-seconds / request
            if best is None or cost < best[0]:
                best = (cost, b, c, lat)
    if best is None:       # SLO unreachable: fall back to knee/batch-16
        b = max_batch
        c = prof.knee_chips
        return b, c, prof.latency(c, b, multiplexed=False)
    return best[1], best[2], best[3]


def kernel_decomposition(prof: ModelProfile, batch: int, chips: int,
                         runtime: float, kmax: int = 24) -> List[Kernel]:
    """Split a model run into kernels with decaying parallelism (paper
    Eq. 1 / Fig. 5): early kernels demand more than the operating-point
    allocation, the long tail demands less — mirroring the Mobilenet
    NVPROF analysis."""
    per = runtime / kmax
    base = chips / prof.hw.chips_per_pod
    kernels = []
    for i in range(kmax):
        # decaying N_i: frac from 2·base down to 0.1·base
        frac = base * (2.0 - 1.9 * i / max(kmax - 1, 1))
        kernels.append(Kernel(knee_frac=min(max(frac, 0.004), 1.0),
                              remaining=per))
    return kernels


class IdealSimulator:
    """Slot-stepped preemptive packing (exhaustive within-slot greedy)."""

    def __init__(self, profiles: Dict[str, ModelProfile],
                 generators: Sequence[RequestGenerator],
                 duration: float = 10.0, slot: float = 1e-4,
                 max_batch: int = 16, drain: bool = False,
                 op_mode: str = "knee"):
        self.profiles = profiles
        self.generators = list(generators)
        self.duration = duration
        self.slot = slot
        self.max_batch = max_batch
        self.drain = drain
        if op_mode == "efficient":
            self._op = {n: best_operating_point(p, max_batch)
                        for n, p in profiles.items()}
        else:
            # paper Fig. 9d setting: same knee/batch operating point as the
            # non-preemptive schedulers — isolates the *scheduling* gain
            self._op = {
                n: (max_batch, p.knee_chips,
                    p.latency(p.knee_chips, max_batch, multiplexed=False))
                for n, p in profiles.items()}

    def run(self) -> SimResult:
        arrivals: List[Request] = []
        for g in self.generators:
            arrivals.extend(g.until(self.duration))
        arrivals.sort(key=lambda r: r.arrival)
        ai = 0
        queues = {n: RequestQueue(n, p.slo) for n, p in self.profiles.items()}
        jobs: Dict[str, Optional[Job]] = {n: None for n in self.profiles}
        metrics = {n: ModelMetrics() for n in self.profiles}
        util_area = 0.0
        t = 0.0
        makespan = 0.0
        n_slots = int(math.ceil(self.duration / self.slot))
        max_slots = n_slots * 4 if self.drain else n_slots

        for si in range(max_slots):
            t = si * self.slot
            while ai < len(arrivals) and arrivals[ai].arrival <= t:
                queues[arrivals[ai].model].push(arrivals[ai]); ai += 1
            # start jobs for idle models with work
            for n, prof in self.profiles.items():
                if jobs[n] is None and len(queues[n]) > 0:
                    b_opt, c_opt, _ = self._op[n]
                    batch = queues[n].pop_batch(
                        b_opt, t, drop_expired=not self.drain)
                    if batch:
                        runtime = prof.latency(c_opt, len(batch),
                                               multiplexed=False)
                        jobs[n] = Job(
                            model=n,
                            deadline=min(r.deadline for r in batch),
                            kernels=kernel_decomposition(
                                prof, len(batch), c_opt, runtime),
                            requests=batch)
                        metrics[n].runs += 1
            # pack this slot: EDF order, grant knee% where possible,
            # partial allocation for the first kernel that doesn't fit
            order = sorted((j for j in jobs.values() if j is not None),
                           key=lambda j: j.deadline)
            cap = 1.0
            for job in order:
                k = job.kernels[0]
                grant = min(k.knee_frac, cap)
                if grant <= 1e-9:
                    continue
                cap -= grant
                speed = min(1.0, grant / k.knee_frac)
                k.remaining -= self.slot * speed
                metrics[job.model].runtime += self.slot
                if k.remaining <= 1e-12:
                    job.kernels.pop(0)
            util_area += (1.0 - cap) * self.slot
            # completions
            for n, job in list(jobs.items()):
                if job is not None and job.done:
                    queues[n].complete(job.requests, t + self.slot)
                    metrics[n].completed += len(job.requests)
                    jobs[n] = None
                    makespan = max(makespan, t + self.slot)
            if self.drain and ai >= len(arrivals) \
                    and all(j is None for j in jobs.values()) \
                    and all(len(q) == 0 for q in queues.values()):
                break

        duration = makespan if self.drain else self.duration
        for n, q in queues.items():
            metrics[n].violated = q.violated + len(q)
        return SimResult(duration=duration or 1e-9,
                         utilization=util_area / (duration or 1e-9),
                         per_model=metrics, makespan=makespan)
