from repro.core.scheduler.base import (
    Policy, SchedView, chips_for_frac, speculation_worthwhile)
from repro.core.scheduler.baselines import (
    FixedBatchMPSPolicy, GSLICEPolicy, MaxMinPolicy, MaxThroughputPolicy,
    TemporalPolicy, TritonPolicy)
from repro.core.scheduler.dstack import DStackPolicy
from repro.core.scheduler.ideal import IdealSimulator

POLICIES = {
    "temporal": TemporalPolicy,
    "fixed_batch_mps": FixedBatchMPSPolicy,
    "gslice": GSLICEPolicy,
    "triton": TritonPolicy,
    "maxmin": MaxMinPolicy,
    "max_throughput": MaxThroughputPolicy,
    "dstack": DStackPolicy,
}

__all__ = [
    "Policy", "SchedView", "chips_for_frac", "speculation_worthwhile",
    "POLICIES", "TemporalPolicy",
    "FixedBatchMPSPolicy", "GSLICEPolicy", "TritonPolicy", "MaxMinPolicy",
    "MaxThroughputPolicy", "DStackPolicy", "IdealSimulator",
]
