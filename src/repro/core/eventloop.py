"""Shared discrete-event loop skeleton for every serving control plane.

``repro.core.simulator.Simulator`` (analytic runs, atomic completions),
``repro.serving.controller.Controller`` (real engines, per-token dispatch
events), and ``repro.serving.plan.TickServer`` (step-plan ticks: one
StepPlan built and executed per due tick) used to each own — or would
each have grown — a ~30-line event loop with identical arrival-pop /
epsilon / cutoff / drain semantics and different machinery inside the
events. Duplicated semantics meant they could drift — a horizon or drain
fix applied to one loop and not the others silently changes what the
planes measure. This module owns the semantics once; the planes plug in
their machinery through ``EventLoopHooks``.

Loop contract (identical for both planes):

* arrivals are materialized up front over ``arrival_horizon`` (default:
  ``duration``) via ``request.materialize_arrivals`` — drain runs with
  rate-based generators must set a horizon, enforced there;
* time jumps to the earliest of (next completion, next arrival, next
  policy wakeup); accumulators advance BEFORE events at the new time fire;
* arrivals within ``epsilon`` of ``now`` are delivered before completions
  fire, and ``plan`` runs after every event batch (including once at t=0);
* a non-drain run cut at ``duration`` advances accumulators exactly to the
  cutoff; a drain run exits when arrivals are exhausted and the plane
  reports itself drained;
* backstops: ``max_time`` (virtual) and ``max_events`` (real dispatches)
  stop the loop BEFORE the offending event and flag the outcome
  ``truncated`` so a partial run can never masquerade as a complete one.
  The ``max_time`` boundary is INCLUSIVE — an event exactly AT max_time
  fires; only events strictly past it truncate — and truncation advances
  accumulators to the backstop (like the duration cutoff), so
  ``out.now`` always equals the window the integrals cover.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Protocol, Sequence

from repro.serving.request import materialize_arrivals


@dataclasses.dataclass
class LoopConfig:
    duration: float
    drain: bool = False
    max_time: float = 600.0
    arrival_horizon: Optional[float] = None
    epsilon: float = 1e-12
    max_events: Optional[int] = None     # cap on Σ fire() costs (None = ∞)


@dataclasses.dataclass
class LoopOutcome:
    now: float = 0.0          # virtual time the loop actually covered
    events: int = 0           # Σ fire() return values (real dispatches)
    truncated: bool = False   # a backstop fired — partial measurement


class EventLoopHooks(Protocol):
    """What a control plane plugs into the shared loop."""

    def deliver(self, req) -> None:
        """An arrival reached its queue."""

    def next_completion(self) -> float:
        """Virtual time of the earliest pending completion (inf if none)."""

    def next_wakeup(self, now: float) -> float:
        """Earliest policy session wakeup (inf if the policy has none)."""

    def advance(self, t: float) -> None:
        """Accumulate integrals (utilization/occupancy) up to ``t``."""

    def fire(self, now: float, epsilon: float) -> int:
        """Process every completion due at <= now + epsilon (the loop's
        one epsilon — the same tolerance arrivals are delivered with);
        return how many capped events (real dispatches) that cost — 0 for
        analytic planes."""

    def plan(self, now: float) -> None:
        """Let the policy start new work against the current state."""

    def drained(self) -> bool:
        """Nothing running and every queue empty (drain-mode exit)."""


def run_event_loop(cfg: LoopConfig, generators: Sequence,
                   hooks: EventLoopHooks) -> LoopOutcome:
    horizon = (cfg.arrival_horizon if cfg.arrival_horizon is not None
               else cfg.duration)
    arrivals = materialize_arrivals(generators, horizon, drain=cfg.drain)
    out = LoopOutcome()
    ai = 0
    now = 0.0
    # optional telemetry plane on the hooks object (Controller/TickServer
    # expose the one attached to their pool/planner): arrival instants on
    # the per-model queue tracks. None = zero-cost.
    tel = getattr(hooks, "telemetry", None)
    while ai < len(arrivals) and arrivals[ai].arrival <= now:
        if tel is not None:
            tel.request_event(arrivals[ai].model, "arrival",
                              rid=arrivals[ai].rid)
        hooks.deliver(arrivals[ai])
        ai += 1
    hooks.plan(now)

    while True:
        if cfg.max_events is not None and out.events >= cfg.max_events:
            out.truncated = True
            break
        if cfg.drain and ai >= len(arrivals) and hooks.drained():
            break
        t = min(hooks.next_completion(),
                arrivals[ai].arrival if ai < len(arrivals) else math.inf,
                hooks.next_wakeup(now))
        if math.isinf(t):
            break
        if t > cfg.max_time:
            # backstop boundary is INCLUSIVE: an event exactly AT max_time
            # fires (this branch only trips for t strictly past it), and
            # truncation advances accumulators to the backstop — like the
            # duration cutoff below — so partial integrals cover exactly
            # the window reported in out.now (regression-tested in
            # tests/test_paged_kv.py::test_event_loop_max_time_boundary)
            if cfg.max_time > now:
                hooks.advance(cfg.max_time)
                now = cfg.max_time
            out.truncated = True
            break
        if not cfg.drain and t > cfg.duration:
            hooks.advance(cfg.duration)
            now = cfg.duration
            break
        hooks.advance(t)
        now = t
        while ai < len(arrivals) and arrivals[ai].arrival <= now + cfg.epsilon:
            if tel is not None:
                tel.request_event(arrivals[ai].model, "arrival",
                                  rid=arrivals[ai].rid)
            hooks.deliver(arrivals[ai])
            ai += 1
        out.events += hooks.fire(now, cfg.epsilon)
        hooks.plan(now)

    out.now = now
    return out
