"""Training driver (CPU-scale smoke; production shapes go through dryrun).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
      --steps 100 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.registry import build_model
from repro.training import checkpoint
from repro.training.optimizer import AdamW
from repro.training.train_step import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (default: reduced smoke size)")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = cfg.reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M")

    opt = AdamW(lr=args.lr, warmup_steps=max(10, args.steps // 10),
                total_steps=args.steps)
    step_fn = jax.jit(make_train_step(api, opt))
    state = opt.init(params)
    pipe = iter(TokenPipeline(cfg, DataConfig(args.batch, args.seq)))

    t0 = time.time()
    for i in range(args.steps):
        params, state, m = step_fn(params, state, next(pipe))
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.3f} "
                  f"lr={float(m['lr']):.2e} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    if args.ckpt:
        checkpoint.save(args.ckpt, params)
        print(f"saved checkpoint to {args.ckpt}")


if __name__ == "__main__":
    main()
