"""Extract roofline terms from compiled XLA artifacts.

``cost_analysis`` gives HLO FLOPs and HBM bytes; collective traffic is NOT
in cost_analysis, so we parse the post-SPMD optimized HLO text and sum
operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g.:  %all-reduce.5 = bf16[8,2048]{1,0} all-reduce(%x), replica_groups=...
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
# tuple-shaped collectives: = (bf16[..], bf16[..]) all-to-all(...)
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def ar_bytes(self) -> int:
        return self.bytes_by_kind.get("all-reduce", 0) \
            + self.bytes_by_kind.get("reduce-scatter", 0) \
            + self.bytes_by_kind.get("all-gather", 0) \
            + self.bytes_by_kind.get("collective-permute", 0)

    @property
    def a2a_bytes(self) -> int:
        return self.bytes_by_kind.get("all-to-all", 0)


def collective_stats(hlo_text: str) -> CollectiveStats:
    by_kind: Dict[str, int] = {k: 0 for k in COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done(" in line:           # async pair: count the -start only
            continue
        m = _OP_RE.search(line)
        if m:
            dtype, dims, kind = m.groups()
            by_kind[kind] += _shape_bytes(dtype, dims)
            counts[kind] += 1
            continue
        m = _TUPLE_RE.search(line)
        if m:
            shapes, kind = m.groups()
            total = sum(_shape_bytes(dt, dm)
                        for dt, dm in _SHAPE_RE.findall(shapes))
            by_kind[kind] += total
            counts[kind] += 1
    return CollectiveStats(by_kind, counts)


# --------------------------------------------------------------------------
# trip-count-weighted cost model
#
# XLA's cost_analysis() counts a while-loop body ONCE, so scan-over-layers
# models under-report FLOPs / bytes / collective traffic by ~num_layers.
# We reconstruct honest totals from the optimized HLO text: split it into
# computations, find `while` ops with known_trip_count, propagate multipliers
# from ENTRY, and weight each computation's dots/collectives/fusions.
# --------------------------------------------------------------------------
_COMP_START = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s+\(")
_WHILE_BODY = re.compile(r"body=%([\w\.\-]+)")
_TRIP = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CALLS = re.compile(r"(?:calls=|to_apply=|condition=|true_computation=|"
                    r"false_computation=|branch_computations=\{)%?([\w\.\-]+)")
_DEF = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*([a-z0-9]+)\[([0-9,]*)\]")
_DOT_LINE = re.compile(r"\s(?:dot|convolution)\(")
_OPERANDS = re.compile(r"%([\w\.\-]+)")
_ANYOP_RE = re.compile(r"=\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+([\w\-]+)\(")


def _split_computations(hlo_text: str):
    """Yield (name, list_of_lines) per computation in the module."""
    current, buf = None, []
    for line in hlo_text.splitlines():
        m = _COMP_START.match(line)
        if m and "->" in line:
            if current is not None:
                yield current, buf
            current, buf = m.group(1), [line]
        elif current is not None:
            buf.append(line)
    if current is not None:
        yield current, buf


def _entry_name(hlo_text: str) -> Optional[str]:
    m = re.search(r"^ENTRY\s+%([\w\.\-]+)", hlo_text, re.M)
    return m.group(1) if m else None


def computation_multipliers(hlo_text: str) -> Dict[str, float]:
    """Effective execution count per computation (trip-count products).

    ``while`` bodies multiply by known_trip_count; fusions/calls/branches
    inherit the caller's multiplier."""
    comps = {name: lines for name, lines in _split_computations(hlo_text)}
    entry = _entry_name(hlo_text)
    mult: Dict[str, float] = {}

    def visit(name: str, factor: float, depth: int = 0) -> None:
        if name not in comps or depth > 32:
            return
        mult[name] = mult.get(name, 0.0) + factor
        for line in comps[name]:
            if " while(" in line:
                mb = _WHILE_BODY.search(line)
                mt = _TRIP.search(line)
                trips = int(mt.group(1)) if mt else 1
                if mb:
                    visit(mb.group(1), factor * trips, depth + 1)
                # the condition computation also runs `trips` times, but we
                # exclude it (negligible) by not recursing on condition=
                continue
            for mc in _CALLS.finditer(line):
                if mc.group(1) != name:
                    visit(mc.group(1), factor, depth + 1)

    if entry:
        visit(entry, 1.0)
    else:
        mult = {name: 1.0 for name in comps}
    return mult


@dataclasses.dataclass
class WeightedCost:
    flops: float              # 2·(out elements)·K summed over dots, weighted
    bytes_accessed: float     # operand+output bytes of memory-touching ops
    collective_bytes: Dict[str, float]
    collective_counts: Dict[str, float]

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    @property
    def ar_bytes(self) -> float:
        return sum(self.collective_bytes.get(k, 0) for k in
                   ("all-reduce", "reduce-scatter", "all-gather",
                    "collective-permute"))

    @property
    def a2a_bytes(self) -> float:
        return self.collective_bytes.get("all-to-all", 0)


# only ops whose outputs plausibly materialize in HBM: fusion boundaries,
# matmuls, cache updates, data movement. Elementwise/layout ops (broadcast,
# iota, reshape, convert, select, transpose) are fused by XLA and counting
# them inflated the memory term ~50x.
_BYTES_OPS = ("fusion", "dot", "convolution", "dynamic-update-slice",
              "scatter", "gather", "copy", "reduce", "concatenate")


def weighted_cost(hlo_text: str) -> WeightedCost:
    mults = computation_multipliers(hlo_text)
    flops = 0.0
    byts = 0.0
    coll_b: Dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    coll_n: Dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    for name, lines in _split_computations(hlo_text):
        w = mults.get(name, 0.0)
        if w == 0.0:
            continue
        # local symbol table: op name -> (dtype, dims string)
        sym = {}
        for line in lines:
            md = _DEF.search(line)
            if md:
                sym[md.group(1)] = (md.group(2), md.group(3))
        for line in lines:
            if "-done(" in line:
                continue
            md = _DEF.search(line)
            if md and _DOT_LINE.search(line):
                _, odt, odims = md.groups()
                out_n = 1
                for dd in odims.split(","):
                    if dd:
                        out_n *= int(dd)
                # contraction size from the lhs operand's recorded shape
                args = line.split("dot(", 1)[-1] if "dot(" in line \
                    else line.split("convolution(", 1)[-1]
                ops_ = _OPERANDS.findall(args.split(")", 1)[0])
                k = 1
                mK = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
                if ops_ and ops_[0] in sym and mK and mK.group(1):
                    ldims = [int(x) for x in sym[ops_[0]][1].split(",") if x]
                    for ci in mK.group(1).split(","):
                        if ci and int(ci) < len(ldims):
                            k *= ldims[int(ci)]
                flops += w * 2.0 * out_n * k
            mo = _OP_RE.search(line)
            if mo:
                dt, dims, kind = mo.groups()
                coll_b[kind] += w * _shape_bytes(dt, dims)
                coll_n[kind] += w
                continue
            mt = _TUPLE_RE.search(line)
            if mt:
                shapes, kind = mt.groups()
                coll_b[kind] += w * sum(_shape_bytes(a, b)
                                        for a, b in _SHAPE_RE.findall(shapes))
                coll_n[kind] += w
                continue
            ma = _ANYOP_RE.search(line)
            if ma and ma.group(3) in _BYTES_OPS:
                total = sum(_shape_bytes(a, b)
                            for a, b in _SHAPE_RE.findall(line))
                byts += w * total
    return WeightedCost(flops, byts, coll_b, coll_n)


def scan_trip_counts(hlo_text: str) -> int:
    """Total while-loop trip count (sanity signal for scan-heavy models)."""
    trips = re.findall(r'trip_count="?(\d+)', hlo_text)
    return sum(int(t) for t in trips)


def cost_summary(compiled) -> Dict[str, float]:
    """Normalize compiled.cost_analysis() across jax versions."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }


def memory_summary(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    if isinstance(ma, list):
        ma = ma[0]
    out = {}
    for key in ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes"):
        out[key] = float(getattr(ma, key, 0.0))
    out["total_per_device"] = (out["argument_size_in_bytes"]
                               + out["output_size_in_bytes"]
                               + out["temp_size_in_bytes"]
                               - out["alias_size_in_bytes"])
    return out
