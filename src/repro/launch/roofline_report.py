"""Generate the EXPERIMENTS.md §Roofline table from results/dryrun/."""
from __future__ import annotations

import sys

from benchmarks.roofline import load_records, model_flops, roofline_terms
from repro.configs import INPUT_SHAPES, get_config


def main(mesh: str = "16x16") -> None:
    print(f"| arch | shape | compute | memory | collective | dominant "
          f"| MODEL/HLO flops | HBM GB/dev | fits |")
    print("|---|---|---|---|---|---|---|---|---|")
    for rec in load_records(mesh):
        rt = roofline_terms(rec)
        if rt is None:
            print(f"| {rec['arch']} | {rec['shape']} | — | — | — | skipped "
                  f"| — | — | — |")
            continue
        print(f"| {rec['arch']} | {rec['shape']} "
              f"| {rt['compute_s']*1e3:.2f} ms | {rt['memory_s']*1e3:.2f} ms "
              f"| {rt['collective_s']*1e3:.2f} ms | {rt['dominant']} "
              f"| {rt['useful_ratio']:.2f} | {rt['mem_gb_per_device']:.1f} "
              f"| {'Y' if rt['fits_hbm'] else 'N'} |")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "16x16")
