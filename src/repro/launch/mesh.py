"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — jax locks the device count on first use, and the
dry-run must set XLA_FLAGS before that happens.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = one 256-chip v5e pod; (2,16,16) = two pods over DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_submesh(chips: int, *, model_axis: int = None):
    """A (data, model) mesh over the first ``chips`` local devices — the
    spatial-multiplexing unit: one D-STACK allocation = one sub-mesh."""
    devs = jax.devices()[:chips]
    if model_axis is None:
        model_axis = min(chips, 16)
    data_axis = max(1, chips // model_axis)
    import numpy as np
    from jax.sharding import Mesh
    arr = np.array(devs[: data_axis * model_axis]).reshape(data_axis, model_axis)
    return Mesh(arr, ("data", "model"))


def make_cpu_mesh():
    """Single-device mesh for smoke tests."""
    import numpy as np
    from jax.sharding import Mesh
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
