"""Serving driver: D-STACK multiplexed inference.

Two modes:
  * ``--mode sim``  — full-fidelity control-plane simulation on the
    roofline latency model (any subset of the 10 archs, production rates).
  * ``--mode real`` — end-to-end on this host: reduced-config models, real
    jitted prefill/decode through the InferenceEngine, D-STACK making the
    run decisions with wall-clock latencies.

Examples:
  PYTHONPATH=src python -m repro.launch.serve --mode sim \
      --models qwen2-0.5b,mamba2-1.3b,deepseek-7b,yi-9b --duration 5
  PYTHONPATH=src python -m repro.launch.serve --mode real \
      --models qwen2-0.5b,olmo-1b --requests 64
"""
from __future__ import annotations

import argparse
import time


def run_sim(model_names, duration: float, policy_name: str, rate: float):
    from repro.core.profiles import build_profile
    from repro.core.scheduler import POLICIES
    from repro.core.simulator import SimConfig, Simulator
    from repro.serving.request import RequestGenerator

    profiles, gens = {}, []
    for i, n in enumerate(model_names):
        p = build_profile(n, request_rate=rate)
        profiles[p.name] = p
        gens.append(RequestGenerator(p.name, rate, p.slo, seed=i))
        print(f"  {p.name:26s} knee={p.knee_chips:3d}ch "
              f"opt=(b={p.opt_batch},c={p.opt_chips}) slo={p.slo*1e3:.0f}ms")
    policy = POLICIES[policy_name](profiles)
    res = Simulator(profiles, policy, gens, SimConfig(duration=duration)).run()
    print(f"policy={policy_name} throughput={res.throughput():.1f}/s "
          f"utilization={res.utilization:.3f} violations={res.total_violated}")
    for n, m in res.per_model.items():
        print(f"  {n:26s} thr={m.throughput(res.duration):8.1f}/s "
              f"violated={m.violated:5d} runtime={m.runtime:.2f}s")
    return res


def run_real(model_names, n_requests: int, prompt_len: int = 32,
             gen_len: int = 8):
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.serving.engine import make_engine

    engines = {}
    for n in model_names:
        cfg = get_config(n).reduced()
        engines[n] = make_engine(cfg, cache_len=prompt_len + gen_len + 8)
        print(f"  built engine for {cfg.name} (reduced)")
    t0 = time.time()
    served = 0
    for n, eng in engines.items():
        batch = {"tokens": jnp.ones((4, prompt_len), jnp.int32)}
        if eng.cfg.has_encoder:
            from repro.serving import frontend
            batch["enc_embeds"] = frontend.audio_frames(eng.cfg, 4)
        for _ in range(max(1, n_requests // 4)):
            out = eng.generate(batch, gen_len)
            served += out.shape[0]
    dt = time.time() - t0
    print(f"served {served} requests across {len(engines)} models "
          f"in {dt:.2f}s ({served/dt:.1f} req/s on CPU)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mode", choices=["sim", "real"], default="sim")
    ap.add_argument("--models",
                    default="qwen2-0.5b,mamba2-1.3b,deepseek-7b,yi-9b")
    ap.add_argument("--policy", default="dstack")
    ap.add_argument("--duration", type=float, default=5.0)
    ap.add_argument("--rate", type=float, default=2000.0)
    ap.add_argument("--requests", type=int, default=32)
    args = ap.parse_args()
    names = args.models.split(",")
    if args.mode == "sim":
        run_sim(names, args.duration, args.policy, args.rate)
    else:
        run_real(names, args.requests)


if __name__ == "__main__":
    main()
