"""Serving driver: D-STACK multiplexed inference.

Two modes:
  * ``--mode sim``  — full-fidelity control-plane simulation on the
    roofline latency model (any subset of the 10 archs, production rates).
  * ``--mode real`` — end-to-end on this host through the engine pool
    (``repro.serving.pool``): reduced-config models, real jitted
    prefill/decode through standby InferenceEngines, the chosen policy
    making every run decision (chips, batch, order).

Examples:
  PYTHONPATH=src python -m repro.launch.serve --mode sim \
      --models qwen2-0.5b,mamba2-1.3b,deepseek-7b,yi-9b --duration 5
  PYTHONPATH=src python -m repro.launch.serve --mode real \
      --models qwen2-0.5b,olmo-1b --duration 0.05 --policy dstack
"""
from __future__ import annotations

import argparse


def run_sim(model_names, duration: float, policy_name: str, rate: float):
    from repro.core.profiles import build_profile
    from repro.core.scheduler import POLICIES
    from repro.core.simulator import SimConfig, Simulator
    from repro.serving.request import RequestGenerator

    profiles, gens = {}, []
    for i, n in enumerate(model_names):
        p = build_profile(n, request_rate=rate)
        profiles[p.name] = p
        gens.append(RequestGenerator(p.name, rate, p.slo, seed=i))
        print(f"  {p.name:26s} knee={p.knee_chips:3d}ch "
              f"opt=(b={p.opt_batch},c={p.opt_chips}) slo={p.slo*1e3:.0f}ms")
    policy = POLICIES[policy_name](profiles)
    res = Simulator(profiles, policy, gens, SimConfig(duration=duration)).run()
    print(f"policy={policy_name} throughput={res.throughput():.1f}/s "
          f"utilization={res.utilization:.3f} violations={res.total_violated}")
    for n, m in res.per_model.items():
        print(f"  {n:26s} thr={m.throughput(res.duration):8.1f}/s "
              f"violated={m.violated:5d} runtime={m.runtime:.2f}s")
    return res


def run_real(model_names, duration: float, policy_name: str, rate: float,
             gen_len: int = 4, lazy_kv: bool = False,
             trace_path=None, metrics: bool = False):
    """Thin wrapper over the engine pool: the named policy drives real
    jitted slot engines end to end (standby allocations compiled once).
    ``lazy_kv`` switches admission to prompt-only page reservation with
    preempt-and-requeue on OutOfPages (see docs/serving_api.md).
    ``trace_path`` arms the telemetry plane and writes a Perfetto-
    loadable Chrome trace there; ``metrics`` prints a Prometheus text
    snapshot of the run (see docs/observability.md)."""
    from repro.serving.controller import run_policy
    from repro.serving.pool import build_pool

    pool = build_pool(model_names, request_rate=rate, base_slots=4,
                      cache_len=32, lazy_kv=lazy_kv)
    for n, host in sorted(pool.hosts.items()):
        allocs = ", ".join(f"{a.chips}ch/{a.n_slots}sl"
                           for a in host.allocations.values())
        print(f"  {n:26s} standby engines: {allocs}")
    tel = None
    if trace_path or metrics:
        from repro.serving.telemetry import Telemetry, TraceRecorder
        tel = Telemetry(trace=TraceRecorder() if trace_path else None)
        pool.attach_telemetry(tel)
    try:
        res = run_policy(pool, policy_name, rate=rate, duration=duration,
                         gen_len=gen_len)
    finally:
        if tel is not None:
            pool.attach_telemetry(None)
    for line in res.table_rows():
        print(line)
    if trace_path:
        tel.trace.save(trace_path)
        print(f"trace: {len(tel.trace.events)} events -> {trace_path} "
              f"(load in https://ui.perfetto.dev)")
    if metrics:
        from repro.serving.telemetry import (MetricsRegistry,
                                             export_pool_result)
        reg = MetricsRegistry()
        export_pool_result(reg, res)
        print(reg.render(), end="")
    return res


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mode", choices=["sim", "real"], default="sim")
    ap.add_argument("--models",
                    default="qwen2-0.5b,mamba2-1.3b,deepseek-7b,yi-9b")
    ap.add_argument("--policy", default="dstack")
    ap.add_argument("--duration", type=float, default=None,
                    help="virtual seconds (default: 5.0 sim, 0.05 real)")
    ap.add_argument("--rate", type=float, default=2000.0)
    ap.add_argument("--gen-len", type=int, default=4)
    ap.add_argument("--lazy-kv", action="store_true",
                    help="(real mode) lazy page reservation with "
                         "preempt-and-requeue on OutOfPages")
    ap.add_argument("--trace", nargs="?", const="trace.json", default=None,
                    metavar="PATH",
                    help="(real mode) record a Chrome/Perfetto trace of "
                         "the serve and write it to PATH "
                         "(default trace.json)")
    ap.add_argument("--metrics", action="store_true",
                    help="(real mode) print a Prometheus text snapshot "
                         "of the run")
    args = ap.parse_args()
    names = args.models.split(",")
    if args.mode == "sim":
        dur = args.duration if args.duration is not None else 5.0
        run_sim(names, dur, args.policy, args.rate)
    else:
        # real mode defaults to a CPU-sized virtual duration
        dur = args.duration if args.duration is not None else 0.05
        run_real(names, dur, args.policy, args.rate, gen_len=args.gen_len,
                 lazy_kv=args.lazy_kv, trace_path=args.trace,
                 metrics=args.metrics)


if __name__ == "__main__":
    main()
