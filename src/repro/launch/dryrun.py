"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input shape × mesh) this lowers + compiles the
appropriate step function (train_step / prefill / serve_step) against
ShapeDtypeStruct stand-ins — no allocation — and records
``memory_analysis`` (fits?), ``cost_analysis`` (FLOPs/bytes) and the
collective schedule (parsed from post-SPMD HLO) for §Roofline.

MUST be run as a module entry point: the XLA_FLAGS line below has to
execute before jax initializes devices.
"""
# The VERY FIRST lines — before ANY other import (jax locks device count
# on first init). Do NOT set this globally; only the dry-run needs 512
# placeholder devices.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, INPUT_SHAPES, get_config, get_shape
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models.registry import build_model
from repro.training.optimizer import AdamW, AdamWState
from repro.training.train_step import make_train_step
from repro.utils.sharding import resolve_spec

SLIDING_WINDOW_500K = 8192   # sub-quadratic variant for dense archs


def effective_config(cfg, shape):
    """long_500k needs sub-quadratic attention: dense/vlm archs run the
    sliding-window variant; ssm/hybrid run natively; whisper skips."""
    if shape.name == "long_500k":
        if cfg.family == "audio":
            return None   # skip — recorded in DESIGN.md §4
        if cfg.family in ("dense", "moe", "vlm"):
            return dataclasses.replace(cfg, sliding_window=SLIDING_WINDOW_500K)
    return cfg


def cache_len_for(cfg, shape) -> int:
    if cfg.sliding_window:
        return min(shape.seq_len, cfg.sliding_window)
    return shape.seq_len


def prepare(cfg, shape, mesh):
    """Returns (fn, abstract_args, in_shardings)."""
    api = build_model(cfg)
    batch_sds = api.input_specs(shape)
    batch_spec = api.input_shardings(shape, mesh)
    batch_sh = {k: NamedSharding(mesh, s) for k, s in batch_spec.items()}
    pspecs = api.param_specs(mesh)
    param_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)

    if shape.kind == "train":
        params = api.abstract_params(jnp.float32)
        opt = AdamW()
        step = make_train_step(api, opt, remat=True)
        opt_state = AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            m=params, v=params)
        opt_sh = AdamWState(step=NamedSharding(mesh, P()),
                            m=param_sh, v=param_sh)
        return (step, (params, opt_state, batch_sds),
                (param_sh, opt_sh, batch_sh))

    params = api.abstract_params(jnp.dtype(cfg.dtype))
    clen = cache_len_for(cfg, shape)
    cache = api.abstract_cache(shape.global_batch, clen)
    cache_specs = api.cache_specs(mesh, shape.global_batch, clen)
    cache_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), cache_specs)

    if shape.kind == "prefill":
        fn = lambda p, batch: api.prefill(p, batch, clen)
        return fn, (params, batch_sds), (param_sh, batch_sh)

    # decode: serve_step — ONE new token against a seq_len-sized cache
    fn = lambda p, token, cache: api.decode_step(p, token, cache)
    tok_sh = batch_sh["token"]
    return (fn, (params, batch_sds["token"], cache),
            (param_sh, tok_sh, cache_sh))


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
            verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": cfg.name, "shape": shape.name, "mesh": mesh_name,
           "kind": shape.kind, "ok": False}
    eff = effective_config(cfg, shape)
    if eff is None:
        rec.update(ok=True, skipped="full-attention enc-dec: 500k decode "
                   "outside model family (DESIGN.md §4)")
        _save(rec, out_dir)
        return rec
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        t0 = time.time()
        fn, args, in_sh = prepare(eff, shape, mesh)
        # donate the state that is consumed: train step donates params +
        # opt state; decode donates the cache (in-place update); prefill
        # takes no cache argument (it builds one)
        donate = {"train": (0, 1), "decode": (2,), "prefill": ()}[shape.kind]
        with mesh:
            jitted = jax.jit(fn, in_shardings=in_sh, donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = hlo_analysis.memory_summary(compiled)
        cost = hlo_analysis.cost_summary(compiled)
        hlo_text = compiled.as_text()
        colls = hlo_analysis.collective_stats(hlo_text)
        # trip-count-weighted costs: XLA cost_analysis counts while bodies
        # once, under-reporting scan-over-layers models by ~num_layers
        wc = hlo_analysis.weighted_cost(hlo_text)
        rec.update(
            ok=True,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            flops_per_device=cost["flops"],
            bytes_per_device=cost["bytes_accessed"],
            weighted_flops_per_device=wc.flops,
            weighted_bytes_per_device=wc.bytes_accessed,
            weighted_collective_bytes=wc.collective_bytes,
            weighted_collective_counts=wc.collective_counts,
            memory=mem,
            collective_bytes=colls.bytes_by_kind,
            collective_counts=colls.count_by_kind,
            sliding_window=eff.sliding_window,
            n_devices=mesh.size,
        )
        if verbose:
            print(f"  mem/device = {mem['total_per_device']/1e9:.2f} GB, "
                  f"flops = {cost['flops']:.3g}, "
                  f"coll = {colls.total_bytes/1e6:.1f} MB "
                  f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"  FAILED: {rec['error']}")
    _save(rec, out_dir)
    return rec


def _save(rec: dict, out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(
        out_dir, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=float)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all' (see repro.configs.ARCHS)")
    ap.add_argument("--shape", default="all",
                    help="input-shape id or 'all'")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} × {shape} × {'2x16x16' if mp else '16x16'}"
                print(f"[dryrun] {tag}", flush=True)
                rec = run_one(arch, shape, mp, args.out)
                n_fail += 0 if rec["ok"] else 1
    print(f"[dryrun] done, failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
