"""Whisper-small [arXiv:2212.04356] — enc-dec transformer backbone.

The mel-spectrogram + conv frontend is a STUB per the brief: ``input_specs``
provides precomputed frame embeddings of shape (batch, encoder_seq, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    source="arXiv:2212.04356",
    num_layers=12,             # decoder layers
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    norm="layernorm",
    encoder_layers=12,
    encoder_seq=1536,   # 1500 mel-frames padded to a 512-divisible stub length
    learned_pos_emb=True,
    tie_embeddings=True,
)
