"""Model / input-shape configuration dataclasses.

Every assigned architecture is expressed as a single frozen ``ModelConfig``.
The same dataclass drives:
  * model construction (``repro.models.registry.build_model``),
  * parameter counting for roofline MODEL_FLOPS,
  * the knee / efficacy analysis (``repro.core``),
  * the dry-run input specs (``repro.launch.dryrun``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity -----------------------------------------------------------
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    source: str                      # citation (arXiv id / model card)

    # transformer backbone ------------------------------------------------
    num_layers: int
    d_model: int
    num_heads: int                   # query heads; 0 => attention-free
    num_kv_heads: int
    d_ff: int                        # per-expert ffn width for MoE
    vocab_size: int
    head_dim: int = 0                # 0 => d_model // num_heads

    # attention flavour ----------------------------------------------------
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0          # 0 = full attention; >0 = window size
    norm: str = "rmsnorm"            # rmsnorm | layernorm | layernorm_nonparam

    # mixture-of-experts ---------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0

    # state-space (mamba2) --------------------------------------------------
    ssm_state: int = 0               # N — SSD state dimension
    ssm_head_dim: int = 64           # P — SSD head dim
    ssm_expand: int = 2              # d_inner = expand * d_model
    ssm_chunk: int = 128             # SSD chunk length
    ssm_conv_width: int = 4

    # hybrid (zamba2-style): one *shared* full-attention block applied
    # every ``attn_every`` mamba layers.
    attn_every: int = 0

    # encoder-decoder (whisper) ---------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0             # precomputed frame-embedding length
    learned_pos_emb: bool = False

    # misc -------------------------------------------------------------------
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------ api
    @property
    def padded_vocab(self) -> int:
        """Vocab padded (Megatron-style) so the vocab dim always shards over
        a 16-way tensor-parallel axis; padded logit rows are masked to -inf
        in the unembedding. Already-divisible vocabs are left alone."""
        if self.vocab_size % 16 == 0:
            return self.vocab_size
        return -(-self.vocab_size // 256) * 256

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads:
            return self.d_model // self.num_heads
        return 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_encoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    # ---------------------------------------------------------- param count
    def param_count(self) -> int:
        """Exact dense parameter count of the model we construct."""
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        n = v * d                                   # embedding
        if not self.tie_embeddings:
            n += v * d                              # lm head
        norm_params = d if self.norm != "layernorm_nonparam" else 0
        if self.norm == "layernorm":
            norm_params *= 2                        # scale + bias

        def attn_params() -> int:
            p = d * (self.num_heads * hd)           # q
            p += 2 * d * (self.num_kv_heads * hd)   # k, v
            p += (self.num_heads * hd) * d          # o
            if self.qkv_bias:
                p += (self.num_heads + 2 * self.num_kv_heads) * hd
            return p

        def mlp_params(ff: int) -> int:
            return 3 * d * ff                       # gate, up, down

        if self.family == "ssm":
            # mamba2 block: in_proj (z,x,B,C,dt), conv, A, D, norm, out_proj
            di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
            per = d * (2 * di + 2 * ns + nh)        # in_proj
            per += self.ssm_conv_width * (di + 2 * ns)
            per += 2 * nh                           # A_log, D
            per += di                               # gated norm
            per += di * d                           # out_proj
            per += norm_params
            return n + self.num_layers * per
        if self.family == "hybrid":
            di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
            per = d * (2 * di + 2 * ns + nh)
            per += self.ssm_conv_width * (di + 2 * ns)
            per += 2 * nh + di + di * d + norm_params
            total = n + self.num_layers * per
            # one shared attention block (+ its mlp)
            total += attn_params() + mlp_params(self.d_ff) + 2 * norm_params
            return total
        per = attn_params() + 2 * norm_params
        if self.num_experts:
            per += d * self.num_experts             # router
            per += self.num_experts * mlp_params(self.d_ff)
        else:
            per += mlp_params(self.d_ff)
        total = n + self.num_layers * per
        if self.has_encoder:
            # encoder layers: self-attn + mlp; decoder additionally has
            # cross-attn (already counted once per layer above? no — add).
            total += self.encoder_layers * (attn_params() + mlp_params(self.d_ff) + 2 * norm_params)
            total += self.num_layers * attn_params()      # cross attention
            if self.learned_pos_emb:
                total += (self.encoder_seq + 32768) * d
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE uses experts_per_token)."""
        if not self.num_experts:
            return self.param_count()
        dense_like = dataclasses.replace(self, num_experts=0, experts_per_token=0)
        per_expert = 3 * self.d_model * self.d_ff
        n = dense_like.param_count() - self.num_layers * per_expert
        n += self.num_layers * (self.experts_per_token * per_expert
                                + self.d_model * self.num_experts)
        return n

    # ------------------------------------------------------------- variants
    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, 256)
        heads = min(self.num_heads, 4) if self.num_heads else 0
        kv = min(self.num_kv_heads, heads) if heads else 0
        # preserve GQA ratio flavour where possible
        if heads and self.num_kv_heads < self.num_heads:
            kv = max(1, heads // 2)
        return dataclasses.replace(
            self,
            num_layers=2,
            d_model=d,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=64 if heads else 0,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            experts_per_token=min(self.experts_per_token, 2) if self.num_experts else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else self.ssm_head_dim,
            ssm_chunk=32 if self.ssm_state else self.ssm_chunk,
            attn_every=2 if self.attn_every else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_seq=16 if self.encoder_seq else 0,
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}
