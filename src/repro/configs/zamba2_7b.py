"""Zamba2-7B [arXiv:2411.15242] — Mamba2 backbone + shared attention blocks.

81 mamba2 layers; one *shared* (weight-tied) full-attention block applied
every ``attn_every`` layers.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    source="arXiv:2411.15242",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    attn_every=6,
)
