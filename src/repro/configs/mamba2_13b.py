"""Mamba2-1.3B [arXiv:2405.21060] — attention-free SSD (state-space duality)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    source="arXiv:2405.21060",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    tie_embeddings=True,
)
