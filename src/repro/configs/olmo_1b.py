"""OLMo-1B [arXiv:2402.00838] — dense, MHA, non-parametric LayerNorm."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    source="arXiv:2402.00838",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    norm="layernorm_nonparam",
    tie_embeddings=True,
)
