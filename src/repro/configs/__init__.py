"""Architecture registry: ``--arch <id>`` resolves through ``get_config``."""
from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.configs.chameleon_34b import CONFIG as _chameleon
from repro.configs.deepseek_7b import CONFIG as _deepseek
from repro.configs.granite_moe import CONFIG as _granite
from repro.configs.mamba2_13b import CONFIG as _mamba2
from repro.configs.olmo_1b import CONFIG as _olmo
from repro.configs.phi35_moe import CONFIG as _phi35
from repro.configs.qwen2_05b import CONFIG as _qwen2
from repro.configs.whisper_small import CONFIG as _whisper
from repro.configs.yi_9b import CONFIG as _yi
from repro.configs.zamba2_7b import CONFIG as _zamba2

ARCHS = {
    c.name: c
    for c in [
        _olmo, _phi35, _yi, _zamba2, _qwen2,
        _deepseek, _whisper, _granite, _chameleon, _mamba2,
    ]
}

# convenience aliases (filesystem-safe ids)
ALIASES = {
    "olmo-1b": "olmo-1b",
    "phi3.5-moe": "phi3.5-moe-42b-a6.6b",
    "phi35-moe": "phi3.5-moe-42b-a6.6b",
    "yi-9b": "yi-9b",
    "zamba2-7b": "zamba2-7b",
    "qwen2-0.5b": "qwen2-0.5b",
    "deepseek-7b": "deepseek-7b",
    "whisper-small": "whisper-small",
    "granite-moe": "granite-moe-3b-a800m",
    "chameleon-34b": "chameleon-34b",
    "mamba2-1.3b": "mamba2-1.3b",
}


def get_config(name: str) -> ModelConfig:
    key = ALIASES.get(name, name)
    if key not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[key]


def get_shape(name: str) -> InputShape:
    if name not in INPUT_SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(INPUT_SHAPES)}")
    return INPUT_SHAPES[name]


__all__ = [
    "ARCHS", "ALIASES", "INPUT_SHAPES", "InputShape", "ModelConfig",
    "get_config", "get_shape",
]
