"""Chameleon-34B [arXiv:2405.09818] — early-fusion VLM.

VQ image tokens live in the shared 65536 vocab, so the backbone is a plain
dense decoder; the VQ-GAN image tokenizer is a STUB frontend per the brief.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    source="arXiv:2405.09818",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    norm="layernorm",
)
