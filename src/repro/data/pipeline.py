"""Synthetic data pipeline: deterministic token streams + request workloads.

Offline container — no real corpora. The LM stream is a mixture of (a) a
Zipfian unigram process and (b) short copy/induction motifs, so a model
trained a few hundred steps shows a clearly decreasing loss (the e2e driver
asserts this). For audio/VLM archs the pipeline splices in stub frontend
outputs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.serving import modality


@dataclasses.dataclass
class DataConfig:
    batch_size: int = 8
    seq_len: int = 128
    seed: int = 0
    zipf_a: float = 1.2
    motif_prob: float = 0.3


class TokenPipeline:
    def __init__(self, cfg: ModelConfig, dcfg: DataConfig):
        self.cfg = cfg
        self.dcfg = dcfg
        self._rng = np.random.default_rng(dcfg.seed)

    def _sequence(self) -> np.ndarray:
        d = self.dcfg
        v = self.cfg.vocab_size
        seq = np.minimum(self._rng.zipf(d.zipf_a, size=d.seq_len + 1) - 1, v - 1)
        # splice copy motifs (induction-head food)
        i = 0
        while i < d.seq_len - 8:
            if self._rng.random() < d.motif_prob:
                span = self._rng.integers(2, 5)
                seq[i + span: i + 2 * span] = seq[i: i + span]
                i += 2 * span
            else:
                i += 4
        return seq.astype(np.int32)

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        return self

    def __next__(self) -> Dict[str, jnp.ndarray]:
        d = self.dcfg
        arr = np.stack([self._sequence() for _ in range(d.batch_size)])
        batch = {
            "tokens": jnp.asarray(arr[:, :-1]),
            "labels": jnp.asarray(arr[:, 1:]),
        }
        if self.cfg.has_encoder:
            batch["enc_embeds"] = modality.audio_frames(
                self.cfg, d.batch_size, seed=int(self._rng.integers(1 << 30)))
        return batch


def eval_batch(cfg: ModelConfig, batch_size: int, seq_len: int, seed: int = 1234):
    pipe = TokenPipeline(cfg, DataConfig(batch_size, seq_len, seed))
    return next(iter(pipe))
