"""Incremental chunk attention over paged K/V as a Pallas kernel.

The prefill-continuation sibling of ``repro.kernels.paged_attention``: a
segment of R *new* tokens attends (a) the K/V its sequence already wrote
into the shared page pool — looked up through the segment's block-table
row, exactly like paged decode — and (b) the chunk's own K/V causally.
One kernel powers two serving paths: chunked-prefill continuations (only
the new chunk is computed, dropping continuation cost from O(L²/chunk)
to O(chunk)) and speculative-decoding verification (the k draft tokens
are the chunk; their logits score the draft in one dispatch).

TPU design mirrors the paged decode kernel: grid ``(segments, kv_heads,
max_pages + 1)`` with the page dim innermost. Iterations ``j <
max_pages`` stream history pages HBM→VMEM with the same block-table
index map — past-history lookups clamp onto the last live page so
revisit-elision never DMAs dead pages; the final iteration ``j ==
max_pages`` attends the chunk's own rows under a local causal mask and
finalizes the online softmax. Block tables, history lengths, and segment
lengths all ride in via scalar prefetch so the index maps can page.

Rows r >= the segment's length are unspecified (padding); callers slice
the valid region. History length 0 (a fresh sequence) is fine — the
chunk's causal part always has at least the query itself.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _chunk_kernel(tbl_ref, hist_ref, slen_ref, q_ref, kh_ref, vh_ref,
                  kc_ref, vc_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, page_size: int, n_pages: int, rep: int,
                  chunk: int, window: int):
    si = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    hist = hist_ref[si]
    slen = slen_ref[si]
    # query rows flatten to (chunk * rep, D); row f belongs to chunk
    # position f // rep at absolute position hist + f // rep
    qrow = jax.lax.broadcasted_iota(jnp.int32, (chunk * rep, 1), 0) // rep
    qpos = hist + qrow

    def _online(s, v):
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(jnp.logical_and(j < n_pages, j * page_size < hist))
    def _history():
        q = q_ref[0, :, 0, :].astype(jnp.float32)       # (chunk*rep, D)
        k = kh_ref[0, :, 0, :].astype(jnp.float32)      # (page_size, D)
        v = vh_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = (j * page_size
                + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1))
        mask = kpos < hist
        if window:
            mask = jnp.logical_and(mask, qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)
        _online(s, v)

    @pl.when(j == n_pages)
    def _local():
        q = q_ref[0, :, 0, :].astype(jnp.float32)       # (chunk*rep, D)
        k = kc_ref[0, :, 0, :].astype(jnp.float32)      # (chunk, D)
        v = vc_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kcol = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.logical_and(kcol <= qrow, kcol < slen)
        if window:
            mask = jnp.logical_and(mask, qrow - kcol < window)
        s = jnp.where(mask, s, NEG_INF)
        _online(s, v)
        o_ref[0, :, 0, :] = (acc_scr[...]
                             / jnp.maximum(l_scr[...], 1e-30)
                             ).astype(o_ref.dtype)


def paged_chunk_attention(q, k_pages, v_pages, k_chunk, v_chunk,
                          block_tables, hist_lens, seg_lens, *,
                          window: int = 0, interpret: bool = False):
    """q/k_chunk/v_chunk: (S, R, H|KV, D) — R chunk rows per segment;
    pages: (P, page_size, KV, D); block_tables: (S, max_pages) int32;
    hist_lens/seg_lens: (S,) int32.

    Chunk row r of segment s sits at absolute position hist_lens[s] + r;
    it attends paged history [0, hist_lens[s]) plus chunk rows [0, r]
    with r < seg_lens[s]. Returns (S, R, H, D); rows r >= seg_lens[s]
    are unspecified padding. Table entries at or past the last live
    history page are never dereferenced (the index map clamps)."""
    s_, r, h, d = q.shape
    _, page_size, kvh, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    rep = h // kvh
    # flatten queries to (R*rep, D) rows per kv head: row p*rep + u is
    # chunk position p's u-th grouped query (matches the kernel's // rep)
    qg = q.reshape(s_, r, kvh, rep, d).transpose(0, 2, 1, 3, 4)
    qg = qg.reshape(s_, kvh, r * rep, d).transpose(0, 2, 1, 3)
    hist_lens = jnp.asarray(hist_lens, jnp.int32).reshape(-1)
    seg_lens = jnp.asarray(seg_lens, jnp.int32).reshape(-1)
    block_tables = jnp.asarray(block_tables, jnp.int32)
    kernel = functools.partial(
        _chunk_kernel, scale=1.0 / np.sqrt(d), page_size=page_size,
        n_pages=max_pages, rep=rep, chunk=r, window=window)

    def hist_map(s_i, g, j, tbl_ref, hist_ref, slen_ref):
        # clamp past-history logical pages onto the last live one so the
        # repeated block index elides the DMA (dead pages stay in HBM);
        # the j == max_pages iteration reuses the last page harmlessly
        last = jnp.maximum(
            (hist_ref[s_i] + page_size - 1) // page_size, 1) - 1
        page = tbl_ref[s_i, jnp.minimum(j, last)]
        return (page, 0, g, 0)

    def chunk_map(s_i, g, j, tbl_ref, hist_ref, slen_ref):
        return (s_i, 0, g, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(s_, kvh, max_pages + 1),
        in_specs=[
            pl.BlockSpec((1, r * rep, 1, d), chunk_map),
            pl.BlockSpec((1, page_size, 1, d), hist_map),
            pl.BlockSpec((1, page_size, 1, d), hist_map),
            pl.BlockSpec((1, r, 1, d), chunk_map),
            pl.BlockSpec((1, r, 1, d), chunk_map),
        ],
        out_specs=pl.BlockSpec((1, r * rep, 1, d), chunk_map),
        scratch_shapes=[
            pltpu.VMEM((r * rep, 1), jnp.float32),
            pltpu.VMEM((r * rep, 1), jnp.float32),
            pltpu.VMEM((r * rep, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s_, r * rep, kvh, d), q.dtype),
        interpret=interpret,
    )(block_tables, hist_lens, seg_lens, qg, k_pages, v_pages,
      k_chunk, v_chunk)
    out = out.transpose(0, 2, 1, 3).reshape(s_, kvh, r, rep, d)
    return out.transpose(0, 2, 1, 3, 4).reshape(s_, r, h, d)
