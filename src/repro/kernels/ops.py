"""Jit'd public wrappers around the Pallas kernels, with backend dispatch.

Backends:
  * ``pallas``    — compiled Pallas TPU kernel (TARGET hardware),
  * ``interpret`` — same kernel body executed in Python on CPU (validation),
  * ``jnp``       — pure-jnp chunked implementation (used on the CPU build
                    machine and inside the multi-device dry-run, where XLA
                    cost analysis of standard HLO is what the roofline reads).

``default_backend()`` picks ``pallas`` on real TPUs and ``jnp`` elsewhere.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import ssd_scan as _ssd
from repro.kernels import ref as _ref


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 512, block_k: int = 512,
                    backend: Optional[str] = None):
    backend = backend or default_backend()
    if backend in ("pallas", "interpret"):
        return _fa.flash_attention(
            q, k, v, causal=causal, window=window, block_q=block_q,
            block_k=block_k, interpret=(backend == "interpret"))
    if backend == "jnp":
        from repro.models.layers import attention_chunked
        return attention_chunked(q, k, v, causal=causal, window=window,
                                 chunk_q=block_q, chunk_k=block_k)
    if backend == "ref":
        return _ref.attention_ref(q, k, v, causal=causal, window=window)
    raise ValueError(backend)


def segment_flash_attention(q, k, v, seg_ids, *, window: int = 0,
                            block_q: int = 512, block_k: int = 512,
                            backend: Optional[str] = None):
    """Segment-masked causal attention over a packed ragged-prefill row."""
    backend = backend or default_backend()
    if backend in ("pallas", "interpret"):
        return _fa.segment_flash_attention(
            q, k, v, seg_ids, window=window, block_q=block_q,
            block_k=block_k, interpret=(backend == "interpret"))
    if backend == "ref":
        return _ref.packed_attention_ref(q, k, v, seg_ids, window=window)
    raise ValueError(backend)


# --------------------------------------------------------------------------
# SSD (mamba2)
# --------------------------------------------------------------------------
def _ssd_chunked_jnp(x, dt, a, b, c, chunk: int, initial_state=None):
    """Vectorized chunked SSD in plain jnp (same math as the Pallas kernel).

    x: (B,L,H,P) dt: (B,L,H) a: (H,) b,c: (B,L,N) -> (y, final_state(B,H,N,P))
    """
    bs, l0, h, p = x.shape
    n = b.shape[-1]
    cl = min(chunk, l0)
    pad = (-l0) % cl
    if pad:
        # dt=0 padding is exact: decay=exp(0)=1, update=0 → state unchanged
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    l = l0 + pad
    nc = l // cl

    f32 = jnp.float32
    adt = dt.astype(f32) * a.astype(f32)                   # (B,L,H)
    xdt = x.astype(f32) * dt.astype(f32)[..., None]        # (B,L,H,P)

    adt = adt.reshape(bs, nc, cl, h)
    xdt = xdt.reshape(bs, nc, cl, h, p)
    bc = b.astype(f32).reshape(bs, nc, cl, n)
    cc = c.astype(f32).reshape(bs, nc, cl, n)

    a_cs = jnp.cumsum(adt, axis=2)                         # (B,NC,cl,H)
    a_tot = a_cs[:, :, -1, :]                              # (B,NC,H)

    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)
    tri = jnp.tril(jnp.ones((cl, cl), bool))
    lmask = jnp.where(tri[None, None, :, :, None],
                      jnp.exp(a_cs[:, :, :, None, :] - a_cs[:, :, None, :, :]),
                      0.0)                                  # (B,NC,cl,cl,H)
    y_diag = jnp.einsum("bcij,bcijh,bcjhp->bcihp", cb, lmask, xdt)

    decay_out = jnp.exp(a_tot[:, :, None, :] - a_cs)       # (B,NC,cl,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", bc, decay_out, xdt)

    s0 = (jnp.zeros((bs, h, n, p), f32) if initial_state is None
          else initial_state.astype(f32))

    def step(s, xs):
        st, dec = xs                                       # (B,H,N,P), (B,H)
        s_next = s * jnp.exp(dec)[..., None, None] + st
        return s_next, s                                   # emit state BEFORE chunk

    final, s_prev = jax.lax.scan(
        step, s0, (states.swapaxes(0, 1), a_tot.swapaxes(0, 1)))
    s_prev = s_prev.swapaxes(0, 1)                         # (B,NC,H,N,P)

    y_off = jnp.einsum("bcin,bchnp,bcih->bcihp", cc, s_prev, jnp.exp(a_cs))
    y = (y_diag + y_off).reshape(bs, l, h, p)[:, :l0]
    return y.astype(x.dtype), final


def ssd(x, dt, a, b, c, *, chunk: int = 128, backend: Optional[str] = None,
        initial_state=None) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. Returns (y (B,L,H,P), final_state (B,H,N,P))."""
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.ssd_ref(x, dt, a, b, c, initial_state=initial_state)
    if backend == "jnp":
        return _ssd_chunked_jnp(x, dt, a, b, c, chunk, initial_state)
    # pallas / interpret — pre-arrange to (B·H, NC, cl, ·)
    assert initial_state is None, "pallas path starts from zero state"
    bs, l, h, p = x.shape
    n = b.shape[-1]
    cl = min(chunk, l)
    assert l % cl == 0, (l, cl)
    nc = l // cl
    f32 = jnp.float32
    xdt = (x.astype(f32) * dt.astype(f32)[..., None])      # (B,L,H,P)
    adt = dt.astype(f32) * a.astype(f32)                   # (B,L,H)
    xdt = xdt.transpose(0, 2, 1, 3).reshape(bs * h, nc, cl, p)
    adt = adt.transpose(0, 2, 1).reshape(bs * h, nc, cl)
    bb = jnp.broadcast_to(b.astype(f32)[:, None], (bs, h, l, n)).reshape(bs * h, nc, cl, n)
    cb = jnp.broadcast_to(c.astype(f32)[:, None], (bs, h, l, n)).reshape(bs * h, nc, cl, n)
    y, state = _ssd.ssd_scan(xdt, adt, bb, cb,
                             interpret=(backend == "interpret"))
    y = y.reshape(bs, h, l, p).transpose(0, 2, 1, 3).astype(x.dtype)
    state = state.reshape(bs, h, n, p)
    return y, state


def ssd_decode(x, dt, a, b, c, state):
    """One-token SSD update (no kernel needed — pure elementwise + matvec)."""
    return _ref.ssd_decode_ref(x, dt, a, b, c, state)
