"""Memory-efficient attention with a custom VJP (flash forward + backward).

Plain ``jax.grad`` through chunked attention saves per-tile softmax
residuals — O(S²) memory, catastrophic at 4k-32k sequER lengths. This module
implements the standard flash backward: the forward saves only
(q, k, v, out, logsumexp); the backward recomputes score tiles chunk by
chunk. This is the jnp twin of the Pallas kernel's recomputation strategy
and is what ``models.layers.big_attention`` uses for training.

All internals run at (b, h, s, d) layout in fp32 accumulators.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def _mask(qpos, kpos, causal: bool, window: int):
    m = jnp.ones(jnp.broadcast_shapes(qpos.shape, kpos.shape), bool)
    if causal:
        m &= qpos >= kpos
    if window:
        m &= (qpos - kpos) < window
    return m


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def flash_mha(q, k, v, q_offset, causal: bool, window: int, cq: int,
              ck: int):
    """q: (B,Sq,H,D); k,v: (B,Sk,H,D) (kv already head-repeated).

    ``q_offset`` (f32 scalar array — may be traced, e.g. an axis_index
    under shard_map) shifts the query positions for causal/window masking:
    context-parallel attention gives each shard a slice of the query
    sequence against the full keys."""
    out, _ = _fwd_impl(q, k, v, causal, window, cq, ck, q_offset)
    return out


def _fwd_impl(q, k, v, causal, window, cq, ck, q_offset=0):
    b, sq, h, d = q.shape
    sk = k.shape[1]
    nq, nk = sq // cq, sk // ck
    scale = 1.0 / np.sqrt(d)
    qc = q.reshape(b, nq, cq, h, d).transpose(1, 0, 3, 2, 4)   # (nq,b,h,cq,d)
    kc = k.reshape(b, nk, ck, h, d).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nk, ck, h, d).transpose(1, 0, 3, 2, 4)

    def per_q(args):
        qi, qblk = args                                        # (b,h,cq,d)
        qf = qblk.astype(jnp.float32)

        def inner(carry, xs):
            m, l, acc = carry
            ki, kblk, vblk = xs
            s = jnp.einsum("bhqd,bhkd->bhqk", qf,
                           kblk.astype(jnp.float32)) * scale
            qpos = q_offset + qi * cq + jnp.arange(cq)[:, None]
            kpos = ki * ck + jnp.arange(ck)[None, :]
            s = jnp.where(_mask(qpos, kpos, causal, window), s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, vblk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, cq), jnp.float32)
        a0 = jnp.zeros((b, h, cq, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(inner, (m0, l0, a0),
                                      (jnp.arange(nk), kc, vc))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return out.astype(q.dtype), lse

    outs, lses = jax.lax.map(per_q, (jnp.arange(nq), qc))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, sq, h, d)   # back to (B,S,H,D)
    lse = lses.transpose(1, 2, 0, 3).reshape(b, h, sq)
    return out, lse


def _fwd(q, k, v, q_offset, causal, window, cq, ck):
    out, lse = _fwd_impl(q, k, v, causal, window, cq, ck, q_offset)
    return out, (q, k, v, q_offset, out, lse)


def _bwd(causal, window, cq, ck, res, dout):
    q, k, v, q_offset, out, lse = res
    dq, dk, dv = _bwd_impl(causal, window, cq, ck, q_offset, res, dout)
    return dq, dk, dv, jnp.zeros((), jnp.float32)


def _bwd_impl(causal, window, cq, ck, q_offset, res, dout):
    q, k, v, _q_offset, out, lse = res
    b, sq, h, d = q.shape
    sk = k.shape[1]
    nq, nk = sq // cq, sk // ck
    scale = 1.0 / np.sqrt(d)

    # rowwise D term
    delta = jnp.einsum("bshd,bshd->bhs", dout.astype(jnp.float32),
                       out.astype(jnp.float32))

    qc = q.reshape(b, nq, cq, h, d).transpose(1, 0, 3, 2, 4)   # (nq,b,h,cq,d)
    doutc = dout.reshape(b, nq, cq, h, d).transpose(1, 0, 3, 2, 4)
    lsec = lse.reshape(b, h, nq, cq).transpose(2, 0, 1, 3)     # (nq,b,h,cq)
    deltac = delta.reshape(b, h, nq, cq).transpose(2, 0, 1, 3)
    kc = k.reshape(b, nk, ck, h, d).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nk, ck, h, d).transpose(1, 0, 3, 2, 4)

    def per_kv(carry, xs):
        dq_acc = carry                                         # (nq,b,h,cq,d) f32
        kj, kblk, vblk = xs
        kf = kblk.astype(jnp.float32)
        vf = vblk.astype(jnp.float32)

        def per_q(args):
            qi, qblk, dblk, lse_i, delta_i = args
            qf = qblk.astype(jnp.float32)
            s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
            qpos = q_offset + qi * cq + jnp.arange(cq)[:, None]
            kpos = kj * ck + jnp.arange(ck)[None, :]
            mask = _mask(qpos, kpos, causal, window)
            p = jnp.where(mask, jnp.exp(s - lse_i[..., None]), 0.0)
            df = dblk.astype(jnp.float32)
            dv_i = jnp.einsum("bhqk,bhqd->bhkd", p, df)
            dp = jnp.einsum("bhqd,bhkd->bhqk", df, vf)
            ds = p * (dp - delta_i[..., None])
            dq_i = jnp.einsum("bhqk,bhkd->bhqd", ds, kf) * scale
            dk_i = jnp.einsum("bhqk,bhqd->bhkd", ds, qf) * scale
            return dq_i, dk_i, dv_i

        dq_js, dk_js, dv_js = jax.lax.map(
            per_q, (jnp.arange(nq), qc, doutc, lsec, deltac))
        dq_acc = dq_acc + dq_js
        return dq_acc, (dk_js.sum(0), dv_js.sum(0))

    dq0 = jnp.zeros((nq, b, h, cq, d), jnp.float32)
    dq_acc, (dks, dvs) = jax.lax.scan(per_kv, dq0,
                                      (jnp.arange(nk), kc, vc))
    dq = dq_acc.transpose(1, 0, 3, 2, 4).reshape(b, sq, h, d).astype(q.dtype)
    dk = dks.transpose(1, 0, 3, 2, 4).reshape(b, sk, h, d).astype(k.dtype)
    dv = dvs.transpose(1, 0, 3, 2, 4).reshape(b, sk, h, d).astype(v.dtype)
    return dq, dk, dv


flash_mha.defvjp(_fwd, _bwd)


def flash_attention_vjp(q, k, v, *, causal: bool = True, window: int = 0,
                        chunk_q: int = 512, chunk_k: int = 512,
                        q_offset: int = 0):
    """GQA wrapper: repeats kv heads, sums grads back (linear op, so the
    repeat's transpose is handled by autodiff through jnp.repeat)."""
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    if kvh != h:
        k = jnp.repeat(k, h // kvh, axis=2)
        v = jnp.repeat(v, h // kvh, axis=2)
    cq = min(chunk_q, sq)
    ck = min(chunk_k, k.shape[1])
    assert sq % cq == 0 and k.shape[1] % ck == 0
    off = jnp.asarray(q_offset, jnp.float32)
    return flash_mha(q, k, v, off, causal, window, cq, ck)
