"""Block-table-indexed (paged) single-token decode attention as a Pallas kernel.

The paged sibling of ``repro.kernels.decode_attention``: instead of one
contiguous ``(B, C, KV, D)`` ring per sequence, K/V live in a shared pool of
fixed-size pages ``(P, page_size, KV, D)`` and each sequence owns an ordered
list of page ids — its *block table*, a ``(B, max_pages)`` int32 row (the
vLLM PagedAttention layout; on TPU the same design ships as
``ragged_paged_attention``). A sequence's logical cache position ``t`` lives
at ``(block_tables[b, t // page_size], t % page_size)``.

TPU design mirrors the ragged decode kernel: grid ``(batch, kv_heads,
max_pages)`` with the page dim innermost; the ``(rep, D)`` query group stays
resident in VMEM while pages stream HBM→VMEM; online softmax in VMEM
scratch. Both the block table AND the per-sequence lengths ride in via
scalar prefetch (``pltpu.PrefetchScalarGridSpec``) so they are available to
the *index maps*, which is where paging actually happens:

  * indirection — the K/V index map looks the j-th logical page up in the
    block table, so the kernel walks each row's pages in logical order no
    matter where they sit in the physical pool;
  * compute skip — pages entirely past a row's length are skipped with
    ``pl.when`` (same fully-masked-tile skip as ``decode_attention``);
  * DMA skip — past-length lookups clamp onto the row's last live page, so
    Pallas's revisit-elision never streams dead pages from HBM. Bandwidth
    scales with each row's actual length, not with ``max_pages``.

Rows with ``lengths == 0`` produce exact zeros (no pages run; the
finalizer's ``l`` guard returns 0) — vacant continuous-batching slots point
their whole table row at the reserved null page and cost nothing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale: float, page_size: int,
                  n_pages: int):
    bi = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    valid = len_ref[bi]
    k_start = j * page_size

    @pl.when(k_start < valid)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (rep, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # (page_size, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < valid, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == n_pages - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_decode_attention(q, k_pages, v_pages, block_tables, lengths, *,
                           interpret: bool = False):
    """q: (B, H, D); pages: (P, page_size, KV, D); block_tables:
    (B, max_pages) int32 page ids; lengths: int32 scalar or (B,).

    Returns (B, H, D). Rows with length 0 return zeros. Table entries at or
    past a row's last live page are never dereferenced (the index map clamps
    onto the last live page), so padding rows with any page id — by
    convention the null page 0 — is safe."""
    b, h, d = q.shape
    _, page_size, kvh, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    rep = h // kvh
    qg = q.reshape(b, kvh, rep, d)
    lengths = jnp.broadcast_to(
        jnp.asarray(lengths, jnp.int32).reshape(-1), (b,))
    block_tables = jnp.asarray(block_tables, jnp.int32)
    kernel = functools.partial(_paged_kernel, scale=1.0 / np.sqrt(d),
                               page_size=page_size, n_pages=max_pages)

    def kv_map(b_, g, j, tbl_ref, len_ref):
        # Clamp past-length logical pages onto the row's last live one —
        # the block index then repeats and Pallas elides the DMA, so dead
        # pages never leave HBM. The table lookup is the paging itself.
        last = jnp.maximum(
            (len_ref[b_] + page_size - 1) // page_size, 1) - 1
        page = tbl_ref[b_, jnp.minimum(j, last)]
        return (page, 0, g, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvh, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, rep, d),
                         lambda b_, g, j, tbl_ref, len_ref: (b_, g, 0, 0)),
            pl.BlockSpec((1, page_size, 1, d), kv_map),
            pl.BlockSpec((1, page_size, 1, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, d),
                               lambda b_, g, j, tbl_ref, len_ref:
                               (b_, g, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, rep, d), q.dtype),
        interpret=interpret,
    )(block_tables, lengths, qg, k_pages, v_pages)
    return out.reshape(b, h, d)
