"""Single-token (decode) GQA attention over a KV cache as a Pallas kernel.

The serving hot loop: one query token per sequence against a long cache.
TPU design: grid (batch, kv_heads, cache_blocks) with the cache-block dim
innermost; the (rep, D) query group stays resident in VMEM while cache
blocks stream HBM→VMEM; online softmax in VMEM scratch. GQA is native —
each grid cell owns one kv head and its `rep = H/KV` query heads, so the
cache is never head-repeated (the jnp lesson from EXPERIMENTS §Perf #9,
here enforced structurally).

`valid_len` masks unwritten cache slots (scalar, streamed via a (1,)
input).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(valid_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float, block_k: int,
                   nk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    valid = valid_ref[0]
    k_start = j * block_k

    @pl.when(k_start < valid)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (rep, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # (block_k, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(kpos < valid, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, valid_len, *, block_k: int = 512,
                     interpret: bool = False):
    """q: (B, H, D); caches: (B, C, KV, D); valid_len: scalar int32.

    Returns (B, H, D)."""
    b, h, d = q.shape
    _, c, kvh, _ = k_cache.shape
    rep = h // kvh
    block_k = min(block_k, c)
    assert c % block_k == 0, (c, block_k)
    nk = c // block_k
    qg = q.reshape(b, kvh, rep, d)
    valid = jnp.asarray(valid_len, jnp.int32).reshape(1)
    kernel = functools.partial(_decode_kernel, scale=1.0 / np.sqrt(d),
                               block_k=block_k, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(b, kvh, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b_, g, j: (0,)),
            pl.BlockSpec((1, 1, rep, d), lambda b_, g, j: (b_, g, 0, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda b_, g, j: (b_, j, g, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda b_, g, j: (b_, j, g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, d), lambda b_, g, j: (b_, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, rep, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, d), jnp.float32),
        ],
        interpret=interpret,
    )(valid, qg, k_cache, v_cache)
    return out.reshape(b, h, d)
