"""Single-token (decode) GQA attention over a KV cache as a Pallas kernel.

The serving hot loop: one query token per sequence against a long cache.
TPU design: grid (batch, kv_heads, cache_blocks) with the cache-block dim
innermost; the (rep, D) query group stays resident in VMEM while cache
blocks stream HBM→VMEM; online softmax in VMEM scratch. GQA is native —
each grid cell owns one kv head and its `rep = H/KV` query heads, so the
cache is never head-repeated (the jnp lesson from EXPERIMENTS §Perf #9,
here enforced structurally).

Raggedness: ``lengths`` is a per-sequence ``(B,)`` int32 vector (a scalar
is accepted and broadcast). It is delivered via scalar prefetch
(``pltpu.PrefetchScalarGridSpec``) so it is available to the *index maps*,
not just the kernel body:

  * compute skip — cache blocks entirely past a row's length are skipped
    with ``pl.when`` (the same fully-masked-tile skip proven in
    ``flash_attention``), so a 100-token row in a 4096-slot cache does 1
    block of work, not 32;
  * DMA skip — the K/V index map clamps the block index to the row's last
    valid block, so Pallas's revisit-elision never streams dead cache
    blocks from HBM. Bandwidth, not just FLOPs, scales with actual
    sequence length — that is the entire game for decode attention, which
    is memory-bound.

Rows with ``lengths == 0`` produce exact zeros (no blocks run; the
finalizer's ``l`` guard returns 0), which slot-based continuous batching
relies on for vacant slots.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float, block_k: int,
                   nk: int):
    bi = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    valid = len_ref[bi]
    k_start = j * block_k

    @pl.when(k_start < valid)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (rep, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # (block_k, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(kpos < valid, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *, block_k: int = 512,
                     interpret: bool = False):
    """q: (B, H, D); caches: (B, C, KV, D); lengths: int32 scalar or (B,).

    Returns (B, H, D). Rows with length 0 return zeros."""
    b, h, d = q.shape
    _, c, kvh, _ = k_cache.shape
    rep = h // kvh
    block_k = min(block_k, c)
    while block_k > 1 and c % block_k:      # largest divisor <= requested
        block_k //= 2
    assert c % block_k == 0, (c, block_k)
    nk = c // block_k
    qg = q.reshape(b, kvh, rep, d)
    lengths = jnp.broadcast_to(
        jnp.asarray(lengths, jnp.int32).reshape(-1), (b,))
    kernel = functools.partial(_decode_kernel, scale=1.0 / np.sqrt(d),
                               block_k=block_k, nk=nk)

    def kv_map(b_, g, j, len_ref):
        # Clamp past-length blocks onto the row's last live block: Pallas
        # elides the DMA when the block index repeats, so dead cache never
        # leaves HBM.
        last = jnp.maximum((len_ref[b_] + block_k - 1) // block_k, 1) - 1
        return (b_, jnp.minimum(j, last), g, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kvh, nk),
        in_specs=[
            pl.BlockSpec((1, 1, rep, d), lambda b_, g, j, len_ref: (b_, g, 0, 0)),
            pl.BlockSpec((1, block_k, 1, d), kv_map),
            pl.BlockSpec((1, block_k, 1, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, d),
                               lambda b_, g, j, len_ref: (b_, g, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, rep, d), q.dtype),
        interpret=interpret,
    )(lengths, qg, k_cache, v_cache)
    return out.reshape(b, h, d)
