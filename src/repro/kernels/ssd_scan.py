"""Mamba2 SSD (state-space duality) chunked scan as a Pallas TPU kernel.

TPU adaptation of the SSD block decomposition (arXiv:2405.21060 §6): the
sequence is split into chunks; within a chunk the dual *quadratic* form runs
on the MXU (two (cl,cl)·(cl,P) matmuls — exactly what the systolic array
wants), while the O(1)-state inter-chunk recurrence is carried in VMEM
scratch across sequential grid steps. This replaces the GPU formulation's
warp-level associative scan — on TPU the scan is simply the innermost grid
dimension with "arbitrary" semantics.

Inputs are pre-arranged by ``ops.ssd`` to (B·H, NC, cl, ·) blocks:
  xdt: (BH, NC, cl, P)   — dt-scaled inputs
  a:   (BH, NC, cl)      — dt·A (negative) log-decays
  b,c: (BH, NC, cl, N)   — input/output projections (shared across heads,
                            pre-broadcast per head by the wrapper)
Outputs: y (BH, NC, cl, P) and the final state (BH, N, P).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xdt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, s_scr, *,
                nc: int, cl: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    xdt = xdt_ref[0, 0].astype(jnp.float32)          # (cl, P)
    a = a_ref[0, 0].astype(jnp.float32)              # (cl,)
    b = b_ref[0, 0].astype(jnp.float32)              # (cl, N)
    c = c_ref[0, 0].astype(jnp.float32)              # (cl, N)

    a_cs = jnp.cumsum(a)                             # inclusive (cl,)
    a_total = a_cs[-1]

    # intra-chunk: Y_diag = (C·Bᵀ ⊙ L) @ xdt, L[i,j] = exp(a_cs[i]-a_cs[j])·[i>=j]
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)     # (cl, cl)
    ii = jax.lax.broadcasted_iota(jnp.int32, (cl, cl), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (cl, cl), 1)
    decay = jnp.exp(a_cs[:, None] - a_cs[None, :])
    lmask = jnp.where(ii >= jj, decay, 0.0)
    y_diag = jax.lax.dot_general(cb * lmask, xdt, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (cl, P)

    # contribution of the inbound state
    s_prev = s_scr[...]                              # (N, P)
    c_in = c * jnp.exp(a_cs)[:, None]                # decay from chunk start
    y_off = jax.lax.dot_general(c_in, s_prev, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    y_ref[0, 0] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: S ← S·exp(Σa) + Σ_t exp(a_cs[-1]-a_cs[t])·b_t ⊗ xdt_t
    b_w = b * jnp.exp(a_total - a_cs)[:, None]       # (cl, N)
    s_new = s_prev * jnp.exp(a_total) + jax.lax.dot_general(
        b_w, xdt, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    s_scr[...] = s_new

    @pl.when(ci == nc - 1)
    def _final():
        state_ref[0] = s_new.astype(state_ref.dtype)


def ssd_scan(xdt, a, b, c, *, interpret: bool = False):
    """xdt: (BH, NC, cl, P); a: (BH, NC, cl); b,c: (BH, NC, cl, N)."""
    bh, nc, cl, p = xdt.shape
    n = b.shape[-1]
    kernel = functools.partial(_ssd_kernel, nc=nc, cl=cl)
    return pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, 1, cl, p), lambda g, ci: (g, ci, 0, 0)),
            pl.BlockSpec((1, 1, cl), lambda g, ci: (g, ci, 0)),
            pl.BlockSpec((1, 1, cl, n), lambda g, ci: (g, ci, 0, 0)),
            pl.BlockSpec((1, 1, cl, n), lambda g, ci: (g, ci, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, cl, p), lambda g, ci: (g, ci, 0, 0)),
            pl.BlockSpec((1, n, p), lambda g, ci: (g, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, nc, cl, p), xdt.dtype),
            jax.ShapeDtypeStruct((bh, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(xdt, a, b, c)
