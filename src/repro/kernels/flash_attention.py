"""Flash attention as a Pallas TPU kernel.

TPU-native design (not a CUDA port): the grid is (batch, q_heads, q_blocks,
kv_blocks) with the kv_blocks dimension innermost ("arbitrary" semantics —
sequential revisits of the same output tile); running max / denominator /
accumulator live in VMEM scratch so the softmax is computed online without
ever materializing the (S, S) score matrix in HBM. Tile shapes are chosen so
q·kᵀ hits the MXU with lane-aligned (multiple-of-128) contractions.

Fully-masked tiles (future tiles under causality, expired tiles under a
sliding window) are *skipped* via ``pl.when`` — this is the part the
chunked-jnp fallback cannot do with static shapes, and is worth ~2× on
causal prefill.

GQA is native: the kv-head block index is derived as ``h * KV // H``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, nk: int):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = i * block_q
    k_start = j * block_k

    should_run = jnp.bool_(True)
    if causal:
        should_run &= k_start <= q_start + block_q - 1
    if window:
        # tile fully expired if even the newest key is outside the window
        should_run &= (q_start - (k_start + block_k - 1)) < window

    @pl.when(should_run)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    # last kv tile this q tile will ever see
    if causal:
        last_j = jnp.minimum(nk - 1, (q_start + block_q - 1) // block_k)
    else:
        last_j = nk - 1

    @pl.when(j == last_j)
    def _finalize():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False):
    """q: (B,S,H,D); k,v: (B,S,KV,D) -> (B,S,H,D)."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    nq, nk = s // block_q, s // block_k
    scale = 1.0 / np.sqrt(d)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, nk=nk)

    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d), lambda b_, h_, i, j: (b_, i, h_, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda b_, h_, i, j: (b_, j, h_ * kvh // h, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda b_, h_, i, j: (b_, j, h_ * kvh // h, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, d),
                               lambda b_, h_, i, j: (b_, i, h_, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
