"""Flash attention as a Pallas TPU kernel.

TPU-native design (not a CUDA port): the grid is (batch, q_heads, q_blocks,
kv_blocks) with the kv_blocks dimension innermost ("arbitrary" semantics —
sequential revisits of the same output tile); running max / denominator /
accumulator live in VMEM scratch so the softmax is computed online without
ever materializing the (S, S) score matrix in HBM. Tile shapes are chosen so
q·kᵀ hits the MXU with lane-aligned (multiple-of-128) contractions.

Fully-masked tiles (future tiles under causality, expired tiles under a
sliding window) are *skipped* via ``pl.when`` — this is the part the
chunked-jnp fallback cannot do with static shapes, and is worth ~2× on
causal prefill.

GQA is native: the kv-head block index is derived as ``h * KV // H``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, nk: int):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = i * block_q
    k_start = j * block_k

    should_run = jnp.bool_(True)
    if causal:
        should_run &= k_start <= q_start + block_q - 1
    if window:
        # tile fully expired if even the newest key is outside the window
        should_run &= (q_start - (k_start + block_k - 1)) < window

    @pl.when(should_run)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    # last kv tile this q tile will ever see
    if causal:
        last_j = jnp.minimum(nk - 1, (q_start + block_q - 1) // block_k)
    else:
        last_j = nk - 1

    @pl.when(j == last_j)
    def _finalize():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def _segment_flash_kernel(seg_smem, q_ref, k_ref, v_ref, qseg_ref, kseg_ref,
                          o_ref, m_scr, l_scr, acc_scr, *, scale: float,
                          window: int, block_q: int, block_k: int, nk: int):
    """Packed-prefill flash body: same online softmax as ``_flash_kernel``
    plus a segment-equality mask, with fully cross-segment tiles skipped.

    ``seg_smem`` is the scalar-prefetched (B, T) segment-id vector — the
    segment *boundaries* read at tile granularity (the same trick the
    paged decode kernel plays with its block table): because ids are
    non-decreasing along the packed row, a kv tile whose LAST id is below
    the q tile's FIRST id lies entirely in earlier segments and is skipped
    wholesale via ``pl.when``. ``qseg_ref``/``kseg_ref`` are the same ids
    as VMEM tiles for the per-element mask inside surviving tiles."""
    bi = pl.program_id(0)
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = i * block_q
    k_start = j * block_k

    # causal skip (future tiles) + segment skip (tiles wholly in earlier
    # segments: max kv-tile id < min q-tile id)
    should_run = k_start <= q_start + block_q - 1
    should_run &= seg_smem[bi, k_start + block_k - 1] >= seg_smem[bi, q_start]
    if window:
        should_run &= (q_start - (k_start + block_k - 1)) < window

    @pl.when(should_run)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        qseg = qseg_ref[0, :].reshape(block_q, 1)
        kseg = kseg_ref[0, :].reshape(1, block_k)
        mask = (qseg == kseg) & (qpos >= kpos)
        if window:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    # the diagonal tile always runs (a token attends at least to itself,
    # and its kv tile's last id >= its own id), so finalizing there is safe
    last_j = jnp.minimum(nk - 1, (q_start + block_q - 1) // block_k)

    @pl.when(j == last_j)
    def _finalize():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def segment_flash_attention(q, k, v, seg_ids, *, window: int = 0,
                            block_q: int = 512, block_k: int = 512,
                            interpret: bool = False):
    """Segment-masked causal flash attention for packed ragged prefill.

    q: (B,T,H,D); k,v: (B,T,KV,D); seg_ids: (T,) or (B,T) non-decreasing
    int32 segment ids (padding tokens carry an id no real token shares).
    Token i attends to token j iff their ids match and j <= i. Tiles that
    lie entirely in earlier segments are skipped via the scalar-prefetched
    boundary test — packed mixed-length batches pay for their actual
    token pairs, not the (sum of lengths)² rectangle."""
    b, t, h, d = q.shape
    kvh = k.shape[2]
    block_q = min(block_q, t)
    block_k = min(block_k, t)
    assert t % block_q == 0 and t % block_k == 0, (t, block_q, block_k)
    nq, nk = t // block_q, t // block_k
    scale = 1.0 / np.sqrt(d)
    seg = jnp.asarray(seg_ids, jnp.int32)
    seg = jnp.broadcast_to(seg.reshape(-1, t) if seg.ndim > 1
                           else seg[None, :], (b, t))

    kernel = functools.partial(
        _segment_flash_kernel, scale=scale, window=window,
        block_q=block_q, block_k=block_k, nk=nk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d),
                         lambda b_, h_, i, j, seg_ref: (b_, i, h_, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda b_, h_, i, j, seg_ref: (b_, j, h_ * kvh // h, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda b_, h_, i, j, seg_ref: (b_, j, h_ * kvh // h, 0)),
            pl.BlockSpec((1, block_q),
                         lambda b_, h_, i, j, seg_ref: (b_, i)),
            pl.BlockSpec((1, block_k),
                         lambda b_, h_, i, j, seg_ref: (b_, j)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, d),
                               lambda b_, h_, i, j, seg_ref: (b_, i, h_, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(seg, q, k, v, seg, seg)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False):
    """q: (B,S,H,D); k,v: (B,S,KV,D) -> (B,S,H,D)."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    nq, nk = s // block_q, s // block_k
    scale = 1.0 / np.sqrt(d)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, nk=nk)

    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d), lambda b_, h_, i, j: (b_, i, h_, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda b_, h_, i, j: (b_, j, h_ * kvh // h, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda b_, h_, i, j: (b_, j, h_ * kvh // h, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, d),
                               lambda b_, h_, i, j: (b_, i, h_, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
