"""Pure-jnp oracles for every Pallas kernel (the ``assert_allclose`` targets).

These are deliberately the *simplest correct* implementations — quadratic
attention, sequential SSD recurrence — used by tests to validate both the
Pallas kernels (interpret mode) and the production chunked-jnp paths.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B,S,H,D), k/v: (B,S,KV,D) -> (B,S,H,D). fp32 internals."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    kr = jnp.repeat(k, rep, axis=2)
    vr = jnp.repeat(v, rep, axis=2)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                    kr.astype(jnp.float32)) / np.sqrt(d)
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= qp >= kp
    if window:
        mask &= qp - kp < window
    sc = jnp.where(mask, sc, -jnp.inf)
    w = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w, vr.astype(jnp.float32)).astype(q.dtype)


def packed_attention_ref(q, k, v, seg_ids, *, window: int = 0):
    """Segment-blocked causal attention over a packed token row.

    q: (B,T,H,D), k/v: (B,T,KV,D); seg_ids: (T,) or (B,T) int32 — token t
    belongs to segment seg_ids[..., t] (non-decreasing; padding tokens
    carry an id no real token shares). Token i attends to token j iff
    their ids match and j <= i (packed positions are globally ascending,
    so global causality == within-segment causality). fp32 internals."""
    b, t, h, d = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    kr = jnp.repeat(k, rep, axis=2)
    vr = jnp.repeat(v, rep, axis=2)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                    kr.astype(jnp.float32)) / np.sqrt(d)
    seg = jnp.asarray(seg_ids, jnp.int32)
    seg = jnp.broadcast_to(seg.reshape(-1, t) if seg.ndim > 1
                           else seg[None, :], (b, t))
    qp = jnp.arange(t)[:, None]
    kp = jnp.arange(t)[None, :]
    mask = (seg[:, :, None] == seg[:, None, :]) & (qp >= kp)   # (B,T,T)
    if window:
        mask &= qp - kp < window
    sc = jnp.where(mask[:, None], sc, -jnp.inf)
    w = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w, vr.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, valid_len):
    """q: (B,H,D); caches (B,C,KV,D); valid_len: scalar or (B,) lengths.

    Per row, entries >= its length are masked; length-0 rows return zeros
    (matching the Pallas kernel's no-blocks-run convention)."""
    b, c, kvh, d = k_cache.shape
    h = q.shape[1]
    rep = h // kvh
    lengths = jnp.broadcast_to(
        jnp.asarray(valid_len, jnp.int32).reshape(-1), (b,))
    kr = jnp.repeat(k_cache, rep, axis=2).astype(jnp.float32)
    vr = jnp.repeat(v_cache, rep, axis=2).astype(jnp.float32)
    sc = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32), kr) / np.sqrt(d)
    sc = jnp.where(jnp.arange(c)[None, None, :] < lengths[:, None, None],
                   sc, -jnp.inf)
    w = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", w, vr)
    out = jnp.where(lengths[:, None, None] > 0, out, 0.0)
    return out.astype(q.dtype)


def chunk_attention_ref(q, k_hist, v_hist, k_chunk, v_chunk, hist_len, *,
                        window: int = 0):
    """Incremental chunk attention oracle: chunk queries attend history
    K/V already resident plus the chunk's own K/V causally.

    q/k_chunk/v_chunk: (B,R,H|KV,D) — R new tokens per row; k_hist/v_hist:
    (B,C,KV,D) with the first ``hist_len[b]`` entries live. Query r in row
    b sits at absolute position hist_len[b] + r and attends history keys
    [0, hist_len[b]) plus chunk keys [0, r]. ``window`` keeps only the
    trailing ``window`` positions. fp32 internals; rows never have zero
    attendable keys (the query itself always is one)."""
    b, r, h, d = q.shape
    c = k_hist.shape[1]
    kvh = k_hist.shape[2]
    rep = h // kvh
    hist = jnp.broadcast_to(jnp.asarray(hist_len, jnp.int32).reshape(-1), (b,))
    kh = jnp.repeat(k_hist, rep, axis=2).astype(jnp.float32)
    vh = jnp.repeat(v_hist, rep, axis=2).astype(jnp.float32)
    kc = jnp.repeat(k_chunk, rep, axis=2).astype(jnp.float32)
    vc = jnp.repeat(v_chunk, rep, axis=2).astype(jnp.float32)
    qf = q.astype(jnp.float32)
    # scores over the concatenated [history | chunk] key axis
    sh = jnp.einsum("brhd,bkhd->bhrk", qf, kh) / np.sqrt(d)
    sc = jnp.einsum("brhd,bkhd->bhrk", qf, kc) / np.sqrt(d)
    qpos = hist[:, None] + jnp.arange(r)[None, :]               # (B,R) absolute
    hmask = jnp.arange(c)[None, None, :] < hist[:, None, None]  # (B,1,C)
    hmask = jnp.broadcast_to(hmask, (b, r, c))
    cmask = jnp.arange(r)[None, None, :] <= jnp.arange(r)[None, :, None]
    cmask = jnp.broadcast_to(cmask, (b, r, r))
    if window:
        kpos_h = jnp.arange(c)[None, None, :]
        kpos_c = hist[:, None, None] + jnp.arange(r)[None, None, :]
        hmask &= qpos[:, :, None] - kpos_h < window
        cmask &= qpos[:, :, None] - kpos_c < window
    sh = jnp.where(hmask[:, None], sh, -jnp.inf)
    sc = jnp.where(cmask[:, None], sc, -jnp.inf)
    s = jnp.concatenate([sh, sc], axis=-1)
    w = jax.nn.softmax(s, axis=-1)
    vcat = jnp.concatenate([vh, vc], axis=1)
    return jnp.einsum("bhrk,bkhd->brhd", w, vcat).astype(q.dtype)


def ssd_ref(x, dt, a, b, c, initial_state=None):
    """Sequential Mamba2/SSD recurrence — the exact oracle.

    x: (B,L,H,P)  dt: (B,L,H)  a: (H,) negative  b,c: (B,L,N)
    state: (B,H,N,P);   s_t = s_{t-1}·exp(dt_t·a) + dt_t·(b_t ⊗ x_t)
                        y_t = c_t · s_t
    Returns (y: (B,L,H,P), final_state).
    """
    bs, l, h, p = x.shape
    n = b.shape[-1]
    s0 = (jnp.zeros((bs, h, n, p), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(state, inp):
        xt, dtt, bt, ct = inp                     # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dtt.astype(jnp.float32) * a.astype(jnp.float32))
        update = jnp.einsum("bh,bn,bhp->bhnp", dtt.astype(jnp.float32),
                            bt.astype(jnp.float32), xt.astype(jnp.float32))
        state = state * decay[..., None, None] + update
        y = jnp.einsum("bn,bhnp->bhp", ct.astype(jnp.float32), state)
        return state, y

    xs = (x.swapaxes(0, 1), dt.swapaxes(0, 1), b.swapaxes(0, 1), c.swapaxes(0, 1))
    final, ys = jax.lax.scan(step, s0, xs)
    return ys.swapaxes(0, 1).astype(x.dtype), final


def ssd_decode_ref(x, dt, a, b, c, state):
    """One SSD decode step. x:(B,H,P) dt:(B,H) b,c:(B,N) state:(B,H,N,P)."""
    decay = jnp.exp(dt.astype(jnp.float32) * a.astype(jnp.float32))
    update = jnp.einsum("bh,bn,bhp->bhnp", dt.astype(jnp.float32),
                        b.astype(jnp.float32), x.astype(jnp.float32))
    state = state.astype(jnp.float32) * decay[..., None, None] + update
    y = jnp.einsum("bn,bhnp->bhp", c.astype(jnp.float32), state)
    return y.astype(x.dtype), state
