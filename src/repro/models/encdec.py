"""Whisper-style encoder-decoder transformer backbone.

The mel-spectrogram + conv feature extractor is a STUB per the brief:
``batch["enc_embeds"]`` carries precomputed frame embeddings
(B, encoder_seq, d_model). Learned positional embeddings; decoder layers use
self-attention (causal, KV-cached) + cross-attention over the encoder output
(cross K/V computed once at prefill).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import ParamDef

MAX_DEC_POS = 32_768


def enc_layer_plan(cfg) -> dict:
    return {
        "ln1": L.norm_plan(cfg.d_model, cfg.norm),
        "attn": L.attn_plan(cfg),
        "ln2": L.norm_plan(cfg.d_model, cfg.norm),
        "mlp": L.mlp_plan(cfg),
    }


def dec_layer_plan(cfg) -> dict:
    return {
        "ln1": L.norm_plan(cfg.d_model, cfg.norm),
        "self_attn": L.attn_plan(cfg),
        "ln2": L.norm_plan(cfg.d_model, cfg.norm),
        "cross_attn": L.attn_plan(cfg),
        "ln3": L.norm_plan(cfg.d_model, cfg.norm),
        "mlp": L.mlp_plan(cfg),
    }


def plan(cfg) -> dict:
    return {
        "embed": L.embed_plan(cfg),
        "enc_pos": ParamDef((cfg.encoder_seq, cfg.d_model), (None, "embed")),
        "dec_pos": ParamDef((MAX_DEC_POS, cfg.d_model), (None, "embed")),
        "enc_layers": L.stack_plan(enc_layer_plan(cfg), cfg.encoder_layers),
        "enc_final": L.norm_plan(cfg.d_model, cfg.norm),
        "layers": L.stack_plan(dec_layer_plan(cfg), cfg.num_layers),
        "final_norm": L.norm_plan(cfg.d_model, cfg.norm),
    }


def init(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 7)
    return {
        "embed": L.init_from_plan(ks[0], L.embed_plan(cfg), dtype),
        "enc_pos": L.init_from_plan(
            ks[1], ParamDef((cfg.encoder_seq, cfg.d_model), None), dtype),
        "dec_pos": L.init_from_plan(
            ks[2], ParamDef((MAX_DEC_POS, cfg.d_model), None), dtype),
        "enc_layers": L.init_stacked(ks[3], enc_layer_plan(cfg), cfg.encoder_layers, dtype),
        "enc_final": L.init_from_plan(ks[4], L.norm_plan(cfg.d_model, cfg.norm), dtype),
        "layers": L.init_stacked(ks[5], dec_layer_plan(cfg), cfg.num_layers, dtype),
        "final_norm": L.init_from_plan(ks[6], L.norm_plan(cfg.d_model, cfg.norm), dtype),
    }


# --------------------------------------------------------------------------
# encoder
# --------------------------------------------------------------------------
def encode(params, cfg, enc_embeds):
    dtype = jnp.dtype(cfg.dtype)
    s = enc_embeds.shape[1]
    x = enc_embeds.astype(dtype) + params["enc_pos"][:s].astype(dtype)
    positions = jnp.arange(s)[None, :]

    def body(carry, lp):
        h = L.apply_norm(lp["ln1"], carry, cfg.norm)
        q, k, v = L.attn_qkv(lp["attn"], cfg, h, positions)
        attn = L.cp_attention(cfg, q, k, v, causal=False)
        x1 = carry + L.attn_out(lp["attn"], carry.dtype, attn)
        h2 = L.apply_norm(lp["ln2"], x1, cfg.norm)
        return x1 + L.apply_mlp(lp["mlp"], h2), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.apply_norm(params["enc_final"], x, cfg.norm)


def _cross_kv(lp, cfg, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wv"].astype(enc_out.dtype))
    if cfg.qkv_bias:
        k = k + lp["cross_attn"]["bk"].astype(enc_out.dtype)
        v = v + lp["cross_attn"]["bv"].astype(enc_out.dtype)
    return k, v


def _dec_block(lp, cfg, x, positions, enc_out):
    h = L.apply_norm(lp["ln1"], x, cfg.norm)
    q, k, v = L.attn_qkv(lp["self_attn"], cfg, h, positions)
    attn = L.cp_attention(cfg, q, k, v, causal=True)
    x = x + L.attn_out(lp["self_attn"], x.dtype, attn)

    h = L.apply_norm(lp["ln2"], x, cfg.norm)
    qc = jnp.einsum("bsd,dhk->bshk", h, lp["cross_attn"]["wq"].astype(x.dtype))
    kc, vc = _cross_kv(lp, cfg, enc_out)
    cross = L.cp_attention(cfg, qc, kc, vc, causal=False)
    x = x + L.attn_out(lp["cross_attn"], x.dtype, cross)

    h = L.apply_norm(lp["ln3"], x, cfg.norm)
    return x + L.apply_mlp(lp["mlp"], h)


def forward(params, cfg, batch_tokens, enc_embeds, *, remat: bool = False):
    dtype = jnp.dtype(cfg.dtype)
    enc_out = encode(params, cfg, enc_embeds)
    b, s = batch_tokens.shape
    x = (L.embed_tokens(params["embed"], batch_tokens, dtype)
         + params["dec_pos"][:s].astype(dtype))
    positions = jnp.arange(s)[None, :]

    from repro.utils.sharding import maybe_constrain

    def body(carry, lp):
        y = _dec_block(lp, cfg, carry, positions, enc_out)
        return maybe_constrain(y, "batch", None, "act_embed"), None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.unembed(params["embed"], x, cfg)
    aux = {"load_balance_loss": jnp.float32(0.0),
           "dropped_fraction": jnp.float32(0.0)}
    return logits, aux


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------
# decoder self-attention KV pages; the cross K/V is a fixed encoder_seq-long
# read-only block per request, so it stays a per-slot dense leaf
PAGED_KEYS = ("k", "v")


def cache_plan(cfg, batch: int, cache_len: int) -> dict:
    hd = cfg.resolved_head_dim
    kv_shape = (cfg.num_layers, batch, cache_len, cfg.num_kv_heads, hd)
    cross_shape = (cfg.num_layers, batch, cfg.encoder_seq, cfg.num_kv_heads, hd)
    spec = L.kv_cache_spec(cfg)
    return {
        "k": ParamDef(kv_shape, spec, "zeros"),
        "v": ParamDef(kv_shape, spec, "zeros"),
        "cross_k": ParamDef(cross_shape, spec, "zeros"),
        "cross_v": ParamDef(cross_shape, spec, "zeros"),
        # per-sequence positions: ragged batches + slot reuse
        "pos": ParamDef((batch,), None, "zeros"),
    }


def init_cache(cfg, batch: int, cache_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    cp = cache_plan(cfg, batch, cache_len)
    return {k: (jnp.zeros((batch,), jnp.int32) if k == "pos"
                else jnp.zeros(cp[k].shape, dtype))
            for k in cp}


def paged_cache_plan(cfg, batch: int, num_pages: int, page_size: int,
                     max_pages: int) -> dict:
    hd = cfg.resolved_head_dim
    kv_shape = (cfg.num_layers, num_pages, page_size, cfg.num_kv_heads, hd)
    cross_shape = (cfg.num_layers, batch, cfg.encoder_seq, cfg.num_kv_heads, hd)
    return {
        "k": ParamDef(kv_shape, L.paged_kv_cache_spec(cfg), "zeros"),
        "v": ParamDef(kv_shape, L.paged_kv_cache_spec(cfg), "zeros"),
        "cross_k": ParamDef(cross_shape, L.kv_cache_spec(cfg), "zeros"),
        "cross_v": ParamDef(cross_shape, L.kv_cache_spec(cfg), "zeros"),
        "block_tables": ParamDef((batch, max_pages), None, "zeros"),
        "pos": ParamDef((batch,), None, "zeros"),
    }


def init_paged_cache(cfg, batch: int, num_pages: int, page_size: int,
                     max_pages: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    cp = paged_cache_plan(cfg, batch, num_pages, page_size, max_pages)
    return {k: (jnp.zeros(cp[k].shape, jnp.int32)
                if k in ("pos", "block_tables")
                else jnp.zeros(cp[k].shape, dtype))
            for k in cp}


def prefill(params, cfg, tokens, cache_len: int, enc_embeds):
    """Encode the (stub) audio, cache cross K/V, run the decoder prompt."""
    dtype = jnp.dtype(cfg.dtype)
    b, s = tokens.shape
    enc_out = encode(params, cfg, enc_embeds)
    x = (L.embed_tokens(params["embed"], tokens, dtype)
         + params["dec_pos"][:s].astype(dtype))
    positions = jnp.arange(s)[None, :]

    def body(carry, lp):
        h = L.apply_norm(lp["ln1"], carry, cfg.norm)
        q, k, v = L.attn_qkv(lp["self_attn"], cfg, h, positions)
        attn = L.cp_attention(cfg, q, k, v, causal=True)
        x1 = carry + L.attn_out(lp["self_attn"], carry.dtype, attn)

        h2 = L.apply_norm(lp["ln2"], x1, cfg.norm)
        qc = jnp.einsum("bsd,dhk->bshk", h2, lp["cross_attn"]["wq"].astype(x1.dtype))
        kc, vc = _cross_kv(lp, cfg, enc_out)
        cross = L.cp_attention(cfg, qc, kc, vc, causal=False)
        x2 = x1 + L.attn_out(lp["cross_attn"], x1.dtype, cross)

        h3 = L.apply_norm(lp["ln3"], x2, cfg.norm)
        x3 = x2 + L.apply_mlp(lp["mlp"], h3)
        k_out = jnp.zeros((b, cache_len) + k.shape[2:], k.dtype).at[:, :s].set(k)
        v_out = jnp.zeros((b, cache_len) + v.shape[2:], v.dtype).at[:, :s].set(v)
        return x3, (k_out, v_out, kc, vc)

    x, (ks, vs, cks, cvs) = jax.lax.scan(body, x, params["layers"])
    x = L.apply_norm(params["final_norm"], x[:, -1], cfg.norm)
    logits = L.unembed(params["embed"], x, cfg)
    return logits, {"k": ks, "v": vs, "cross_k": cks, "cross_v": cvs,
                    "pos": jnp.full((b,), s, jnp.int32)}


def prefill_packed(params, cfg, packed, max_seg_len: int):
    """Packed ragged prefill: only the DECODER side packs. The encoder
    runs densely over the per-segment ``enc_embeds`` stack (S, enc_seq,
    d) — encoder frames are fixed-length per request, there is nothing
    ragged to pack — and each packed decoder token cross-attends its own
    segment's encoder output (``layers.packed_cross_attention``).
    Decoder self-attention K/V comes back in packed per-token order
    (layers, T, KV, D) for the engine's direct-to-pages scatter; the
    cross K/V stays a per-segment dense block, exactly like the per-slot
    layout it is scattered into."""
    tokens = packed["tokens"]
    seg_ids, seg_starts = packed["seg_ids"], packed["seg_starts"]
    seg_lens = packed["seg_lens"]
    dtype = jnp.dtype(cfg.dtype)
    b, t = tokens.shape
    enc_out = encode(params, cfg, packed["enc_embeds"])   # (S, enc_seq, d)
    pos = L.packed_positions(seg_ids, seg_starts)
    positions = pos[None, :]
    x = (L.embed_tokens(params["embed"], tokens, dtype)
         + params["dec_pos"][pos][None].astype(dtype))

    def body(carry, lp):
        h = L.apply_norm(lp["ln1"], carry, cfg.norm)
        q, k, v = L.attn_qkv(lp["self_attn"], cfg, h, positions)
        attn = L.packed_prefill_attention(q, k, v, seg_ids, pos,
                                          seg_starts, seg_lens,
                                          row_len=max_seg_len)
        x1 = carry + L.attn_out(lp["self_attn"], carry.dtype, attn)

        h2 = L.apply_norm(lp["ln2"], x1, cfg.norm)
        qc = jnp.einsum("bsd,dhk->bshk", h2,
                        lp["cross_attn"]["wq"].astype(x1.dtype))
        kc, vc = _cross_kv(lp, cfg, enc_out)              # (S, enc, KV, hd)
        cross = L.packed_cross_attention(qc, kc, vc, seg_ids, pos,
                                         seg_starts, seg_lens,
                                         row_len=max_seg_len)
        x2 = x1 + L.attn_out(lp["cross_attn"], x1.dtype, cross)

        h3 = L.apply_norm(lp["ln3"], x2, cfg.norm)
        x3 = x2 + L.apply_mlp(lp["mlp"], h3)
        return x3, (k[0], v[0], kc, vc)

    x, (ks, vs, cks, cvs) = jax.lax.scan(body, x, params["layers"])
    last = jnp.clip(seg_starts + seg_lens - 1, 0, t - 1)
    xl = L.apply_norm(params["final_norm"], x[0, last], cfg.norm)
    logits = L.unembed(params["embed"], xl, cfg)
    return logits, {"k": ks, "v": vs, "cross_k": cks, "cross_v": cvs,
                    "pos": seg_lens.astype(jnp.int32)}


def decode_step(params, cfg, token, cache):
    """Self-attention cache is carried + updated in place; the read-only
    cross K/V streams through the scan as xs (no double-buffering)."""
    dtype = jnp.dtype(cfg.dtype)
    pos = jnp.broadcast_to(jnp.asarray(cache["pos"], jnp.int32), token.shape)
    update, attend, _ = L.decode_index(pos, cache, "k")
    x = (L.embed_tokens(params["embed"], token, dtype)
         + params["dec_pos"][pos].astype(dtype))
    positions = pos
    enc_len = cache["cross_k"].shape[2]

    def body(carry, xs):
        h0, kfull, vfull = carry
        lp, ck, cv, idx = xs
        h = L.apply_norm(lp["ln1"], h0, cfg.norm)
        q, k, v = L.attn_qkv(lp["self_attn"], cfg, h[:, None, :], positions[:, None])
        q = L.constrain_q_decode(cfg, q[:, 0])
        kc = jax.lax.dynamic_slice_in_dim(kfull, idx, 1, axis=0)[0]
        vc = jax.lax.dynamic_slice_in_dim(vfull, idx, 1, axis=0)[0]
        kc = update(kc, k)
        vc = update(vc, v)
        attn = attend(q, kc, vc)
        x1 = h0 + L.attn_out(lp["self_attn"], h0.dtype, attn)

        h2 = L.apply_norm(lp["ln2"], x1, cfg.norm)
        qc = jnp.einsum("bd,dhk->bhk", h2, lp["cross_attn"]["wq"].astype(x1.dtype))
        qc = L.constrain_q_decode(cfg, qc)
        cross = L.decode_attention(qc, ck, cv, enc_len)
        x2 = x1 + L.attn_out(lp["cross_attn"], x1.dtype, cross)

        h3 = L.apply_norm(lp["ln3"], x2, cfg.norm)
        x3 = x2 + L.apply_mlp(lp["mlp"], h3)
        kfull = jax.lax.dynamic_update_slice_in_dim(kfull, kc[None], idx, axis=0)
        vfull = jax.lax.dynamic_update_slice_in_dim(vfull, vc[None], idx, axis=0)
        return (x3, kfull, vfull), None

    (x, ks, vs), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"]),
        (params["layers"], cache["cross_k"], cache["cross_v"],
         jnp.arange(cfg.num_layers)))
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.unembed(params["embed"], x, cfg)
    return logits, L.carry_cache_meta(
        {"k": ks, "v": vs, "cross_k": cache["cross_k"],
         "cross_v": cache["cross_v"], "pos": pos + 1}, cache)
