"""Shared model-building primitives (pure JAX, no flax).

Parameters are declared via a *plan*: a pytree of ``ParamDef(shape, spec,
init)``. The same plan drives initialization (``init_from_plan``), sharding
(``utils.sharding.tree_specs``) and abstract eval (``abstract_params``), so
the three can never drift apart.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# parameter plans
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    spec: Optional[Tuple[Optional[str], ...]]       # logical axes
    init: str = "normal"                             # normal | zeros | ones
    std: float = 0.02


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _materialize(key, pd: ParamDef, dtype) -> jax.Array:
    if pd.init == "zeros":
        return jnp.zeros(pd.shape, dtype)
    if pd.init == "ones":
        return jnp.ones(pd.shape, dtype)
    return (pd.std * jax.random.normal(key, pd.shape, jnp.float32)).astype(dtype)


def init_from_plan(key, plan, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(plan, is_leaf=_is_def)
    keys = jax.random.split(key, max(1, len(leaves)))
    return jax.tree.unflatten(
        treedef, [_materialize(k, pd, dtype) for k, pd in zip(keys, leaves)]
    )


def init_stacked(key, plan, n: int, dtype=jnp.float32):
    """Initialize ``n`` copies of ``plan`` stacked on a leading axis (for scan)."""
    keys = jax.random.split(key, n)
    per_layer = jax.vmap(lambda k: init_from_plan(k, plan, dtype))(keys)
    return per_layer


def stack_plan(plan, n: int):
    """The plan describing the stacked params (leading ``stack`` axis)."""
    return jax.tree.map(
        lambda pd: ParamDef((n,) + tuple(pd.shape), ("stack",) + tuple(pd.spec or (None,) * len(pd.shape)), pd.init, pd.std),
        plan,
        is_leaf=_is_def,
    )


def abstract_params(plan, dtype=jnp.float32):
    return jax.tree.map(
        lambda pd: jax.ShapeDtypeStruct(tuple(pd.shape), dtype), plan, is_leaf=_is_def
    )


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def norm_plan(d: int, kind: str):
    if kind == "rmsnorm":
        return {"scale": ParamDef((d,), ("embed",), "ones")}
    if kind == "layernorm":
        return {"scale": ParamDef((d,), ("embed",), "ones"),
                "bias": ParamDef((d,), ("embed",), "zeros")}
    if kind == "layernorm_nonparam":
        return {}
    raise ValueError(kind)


def apply_norm(p, x, kind: str, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if kind == "layernorm":
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------
def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) * 2.0 / d))
    ang = positions[..., None].astype(jnp.float32) * freq          # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                                # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half: 2 * half]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    out = jnp.concatenate([y1, y2, x[..., 2 * half:]], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------
def repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return k
    b, s, kv, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, d)).reshape(b, s, kv * n_rep, d)


def attention_dense(q, k, v, *, causal: bool, q_offset=0, bias_mask=None):
    """Plain quadratic attention. q:(B,Sq,H,D) k,v:(B,Sk,KV,D)."""
    b, sq, h, d = q.shape
    kv = k.shape[2]
    k = repeat_kv(k, h // kv)
    v = repeat_kv(v, h // kv)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / np.sqrt(d)
    if causal:
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(k.shape[1])[None, :]
        scores = jnp.where(qpos >= kpos, scores, -1e30)
    if bias_mask is not None:
        scores = jnp.where(bias_mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


def attention_chunked(q, k, v, *, causal: bool = True, chunk_q: int = 1024,
                      chunk_k: int = 1024, window: int = 0):
    """Flash-style chunked attention in pure jnp (O(S·chunk) memory).

    Computes all (q-chunk × kv-chunk) tiles with masking — the Pallas TPU
    kernel (repro.kernels.flash_attention) skips fully-masked tiles; this jnp
    fallback trades ~2x attention FLOPs for static shapes under scan.
    """
    b, s, h, d = q.shape
    sk = k.shape[1]
    kvh = k.shape[2]
    n_rep = h // kvh
    cq = min(chunk_q, s)
    ck = min(chunk_k, sk)
    nq, nk = s // cq, sk // ck
    assert s % cq == 0 and sk % ck == 0, (s, sk, cq, ck)
    scale = 1.0 / np.sqrt(d)

    qc = q.reshape(b, nq, cq, h, d)
    kc = k.reshape(b, nk, ck, kvh, d)
    vc = v.reshape(b, nk, ck, kvh, d)

    def per_q_chunk(qi, qblk):
        # qblk: (b, cq, h, d)
        def inner(carry, xs):
            m, l, acc = carry
            ki, kblk, vblk = xs
            kblk = repeat_kv(kblk, n_rep)          # (b, ck, h, d)
            vblk = repeat_kv(vblk, n_rep)
            sc = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk).astype(jnp.float32) * scale
            qpos = qi * cq + jnp.arange(cq)[:, None]
            kpos = ki * ck + jnp.arange(ck)[None, :]
            mask = jnp.ones((cq, ck), bool)
            if causal:
                mask &= qpos >= kpos
            if window:
                mask &= qpos - kpos < window
            sc = jnp.where(mask, sc, -1e30)
            m_new = jnp.maximum(m, sc.max(-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(qblk.dtype), vblk
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, cq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, cq), jnp.float32)
        a0 = jnp.zeros((b, h, cq, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            inner, (m0, l0, a0), (jnp.arange(nk), kc.swapaxes(0, 1), vc.swapaxes(0, 1))
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.swapaxes(1, 2).astype(q.dtype)   # (b, cq, h, d)

    outs = jax.lax.map(lambda xs: per_q_chunk(xs[0], xs[1]),
                       (jnp.arange(nq), qc.swapaxes(0, 1)))
    return outs.swapaxes(0, 1).reshape(b, s, h, d)


def big_attention(q, k, v, *, causal: bool, window: int = 0):
    """Dispatch: Pallas flash kernel on real TPUs; flash-with-custom-VJP
    (O(S) residuals, tile recomputation in backward) elsewhere."""
    s, sk = q.shape[1], k.shape[1]
    if jax.default_backend() == "tpu" and s % 512 == 0 and sk % 512 == 0:
        from repro.kernels.ops import flash_attention
        return flash_attention(q, k, v, causal=causal, window=window)
    if max(s, sk) > 1024:
        from repro.kernels.flash_vjp import flash_attention_vjp
        cq = 512 if s % 512 == 0 else s
        ck = 512 if sk % 512 == 0 else sk
        return flash_attention_vjp(q, k, v, causal=causal, window=window,
                                   chunk_q=cq, chunk_k=ck)
    if window:
        qp = jnp.arange(s)[:, None]
        kp = jnp.arange(sk)[None, :]
        mask = (qp - kp < window) & ((qp >= kp) if causal else True)
        return attention_dense(q, k, v, causal=False, bias_mask=mask)
    return attention_dense(q, k, v, causal=causal)


# --------------------------------------------------------------------------
# packed ragged prefill
# --------------------------------------------------------------------------
def packed_positions(seg_ids, seg_starts):
    """Within-segment position of every token in a packed row.

    seg_ids: (T,) int32 non-decreasing segment id per token (padding
    tokens carry id == S, one past the last real segment); seg_starts:
    (S,) int32 packed offset of each segment's first token. Padding
    tokens get position 0 (their rope/pos-embed values are never read —
    attention masks them and their outputs are discarded)."""
    t = jnp.arange(seg_ids.shape[0], dtype=jnp.int32)
    s = seg_starts.shape[0]
    start = seg_starts[jnp.minimum(seg_ids, s - 1)]
    return jnp.where(seg_ids < s, t - start, 0)


def segments_to_rows(x, seg_starts, seg_lens, row_len):
    """Gather a packed (T, ...) tensor into per-segment rows
    (S, row_len, ...): row i holds its segment's tokens at columns
    0..len_i-1 and exact zeros after — the layout a per-request prefill
    would see. Segments are CONTIGUOUS in the packed row, so this is a
    masked gather (start + column), not a scatter — measurably cheaper on
    the CPU fallback and trivially parallel. Together with
    ``rows_to_segments`` this bridges the packed layout (where the
    O(tokens) ops run) and the per-segment row layout the sequence-mixing
    fallbacks (dense attention, conv, SSD scan) need."""
    t = x.shape[0]
    idx = seg_starts[:, None] + jnp.arange(row_len, dtype=jnp.int32)[None, :]
    rows = x[jnp.clip(idx, 0, t - 1)]                  # (S, row_len, ...)
    valid = jnp.arange(row_len)[None, :] < seg_lens[:, None]
    return jnp.where(valid.reshape(valid.shape + (1,) * (x.ndim - 1)),
                     rows, 0)


def rows_to_segments(rows, seg_ids, positions):
    """Gather per-segment rows back to the packed (T, ...) layout — the
    inverse of ``segments_to_rows`` for real tokens. Padding tokens (a
    clamped row/column) read garbage that every consumer discards: their
    K/V lands on the null page, their activations feed no segment's last
    logits."""
    r = jnp.clip(seg_ids, 0, rows.shape[0] - 1)
    c = jnp.clip(positions, 0, rows.shape[1] - 1)
    return rows[r, c]


def packed_prefill_attention(q, k, v, seg_ids, positions, seg_starts,
                             seg_lens, *, row_len: int, window: int = 0):
    """Segment-blocked causal self-attention over a packed token row.

    q: (1, T, H, D); k/v: (1, T, KV, D); seg_ids/positions: (T,);
    seg_starts/seg_lens: (S,). Token i attends to token j iff
    seg_ids[i] == seg_ids[j] and j <= i.

    On real TPUs this dispatches to the segment flash kernel
    (repro.kernels.flash_attention.segment_flash_attention), whose
    scalar-prefetched segment boundaries skip fully cross-segment tiles —
    the packed row pays for its actual token pairs. The fallback gathers
    each segment into its own row (q/k/v in ONE fused gather along the
    head axis) and runs the SAME ``attention_dense`` body the padded
    prefill path runs — same key set, same reduction order, exact-zero
    padding terms — so packed and padded prefill greedy outputs agree
    bit-for-bit on CPU; its attention FLOPs match pad-to-``row_len``
    while every other prefill op runs on sum(lens) tokens instead of
    batch × max."""
    b, t, h, d = q.shape
    kvh = k.shape[2]
    # half-step buckets (3·2^k) are 256-multiples, not 512-multiples —
    # drop to 256-wide tiles there so the kernel stays in play for every
    # packed bucket >= 512
    if jax.default_backend() == "tpu" and t >= 512 and t % 256 == 0:
        from repro.kernels.ops import segment_flash_attention
        blk = 512 if t % 512 == 0 else 256
        return segment_flash_attention(q, k, v, seg_ids, window=window,
                                       block_q=blk, block_k=blk)
    qkv = jnp.concatenate([q[0], k[0], v[0]], axis=1)   # (T, H+2KV, D)
    rows = segments_to_rows(qkv, seg_starts, seg_lens, row_len)
    qr, kr, vr = rows[:, :, :h], rows[:, :, h:h + kvh], rows[:, :, h + kvh:]
    # big_attention applies the SAME dispatch rule the padded prefill path
    # uses (dense under 1024, chunked flash above — no materialized
    # (S, H, row, row) scores for long rows, and bit-parity with padded
    # prefill holds whenever both land on the same side of that rule)
    ar = big_attention(qr, kr, vr, causal=True, window=window)
    return rows_to_segments(ar, seg_ids, positions)[None]


def packed_cross_attention(q, k_cross, v_cross, seg_ids, positions,
                           seg_starts, seg_lens, *, row_len: int):
    """Per-segment cross-attention for packed encoder-decoder prefill.

    q: (1, T, H, D) packed decoder queries; k_cross/v_cross:
    (S, enc_seq, KV, D) — one read-only encoder block per segment. Each
    packed token attends its OWN segment's encoder output: queries are
    gathered to per-segment rows, run through the same dense non-causal
    attention the padded path uses, and gathered back."""
    qr = segments_to_rows(q[0], seg_starts, seg_lens, row_len)
    ar = attention_dense(qr, k_cross, v_cross, causal=False)
    return rows_to_segments(ar, seg_ids, positions)[None]


def cache_row_update(buf, new, slot):
    """Write ``new`` (B, 1, ...) into ``buf`` (B, C, ...) at per-row ring
    position ``slot`` (B,) along axis 1.

    Implemented as a batched scatter (``.at[b, slot_b]``), which touches one
    row per sequence; inside the decode layer-scan the buffer is a carry, so
    XLA applies it in place. The one-hot-select alternative rewrites the
    whole cache every layer — measured 1.5x slower per decode step at
    C=128 on CPU, and O(cache) instead of O(row) HBM traffic at real
    cache lengths."""
    bidx = jnp.arange(buf.shape[0])
    return buf.at[bidx, slot].set(new[:, 0])


def paged_cache_update(buf, new, pages, slots):
    """Write ``new`` (B, 1, ...) into a paged pool ``buf``
    (P, page_size, ...) at per-row physical page ``pages`` (B,) and
    in-page offset ``slots`` (B,).

    Live rows own disjoint pages so the scatter rows never collide; vacant
    rows all target the reserved null page 0 at offset 0 — duplicate
    indices there are harmless because the null page is never read (see
    ``repro.serving.kv_cache``)."""
    return buf.at[pages, slots].set(new[:, 0])


def _masked_decode_attention(q, k_cache, v_cache, lengths):
    """The jnp (CPU/dry-run) decode-attention body: masked full-cache
    compute with static shapes. Shared verbatim by the contiguous and the
    paged (post-gather) paths so ring and paged greedy decode stay
    bit-exact on the fallback backend."""
    b, c, kvh, d = k_cache.shape
    h = q.shape[1]
    qg = q.reshape(b, kvh, h // kvh, d)
    # preferred_element_type keeps the cache operands bf16 (no hoisted
    # full-cache f32 convert) while accumulating scores in f32
    sc = jnp.einsum("bgrd,bkgd->bgrk", qg, k_cache,
                    preferred_element_type=jnp.float32)
    sc = sc / np.sqrt(d)
    mask = jnp.arange(c)[None, None, None, :] < lengths[:, None, None, None]
    sc = jnp.where(mask, sc, -1e30)
    w = jax.nn.softmax(sc, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgrk,bkgd->bgrd", w, v_cache,
                     preferred_element_type=jnp.float32)
    out = jnp.where(lengths[:, None, None, None] > 0, out, 0.0)
    return out.reshape(b, h, d).astype(q.dtype)


def paged_decode_attention(q, k_pages, v_pages, block_tables, valid_len):
    """Single-token attention over a block-table paged KV cache.

    q: (B, H, D); k_pages/v_pages: (P, page_size, KV, D) shared physical
    pools; block_tables: (B, max_pages) int32 page ids; valid_len: scalar
    or per-sequence (B,) int32 lengths. Rows with length 0 (vacant slots,
    table rows parked on the null page) return zeros.

    On real TPUs this dispatches to the paged Pallas kernel
    (repro.kernels.paged_attention): the block table rides in via scalar
    prefetch and pages the index maps directly, so HBM traffic scales with
    each row's actual length. The fallback gathers each row's pages into
    logical order and reuses the exact masked-decode body of the
    contiguous path — bit-identical to ring decode for equal contents.
    """
    b = q.shape[0]
    _, page_size, kvh, d = k_pages.shape
    max_pages = block_tables.shape[1]
    lengths = jnp.broadcast_to(
        jnp.asarray(valid_len, jnp.int32).reshape(-1), (b,))
    # sublane-aligned pages dispatch to the kernel (the streamed page is a
    # (page_size, d) tile — same shape family the ragged decode kernel
    # streams); everything the repo builds uses page_size % 8 == 0
    if jax.default_backend() == "tpu" and page_size % 8 == 0:
        from repro.kernels.paged_attention import \
            paged_decode_attention as _pallas
        return _pallas(q, k_pages, v_pages, block_tables, lengths)
    # jnp gather fallback: pages -> logical (B, C, KV, D) view
    kc = k_pages[block_tables].reshape(b, max_pages * page_size, kvh, d)
    vc = v_pages[block_tables].reshape(b, max_pages * page_size, kvh, d)
    return _masked_decode_attention(q, kc, vc, lengths)


def _masked_chunk_attention(q_rows, k_cache, v_cache, lengths):
    """The jnp (CPU/dry-run) incremental chunk-attention body: each of the
    R chunk queries runs the EXACT single-token masked-decode body against
    the (virtual) per-segment cache with its own valid length — so a
    verify chunk's logits are bit-identical to the decode steps it
    replaces on the fallback backend (the property speculative decoding's
    bit-exactness rests on).

    q_rows: (B, R, H, D); caches: (B, C, KV, D) with the chunk's own K/V
    already scattered in at positions [hist, hist + R); lengths: (B, R)
    int32 — query r attends cache entries [0, lengths[b, r]), and rows
    with length 0 (padding) return zeros."""

    def per_pos(args):
        q, ln = args
        return _masked_decode_attention(q, k_cache, v_cache, ln)

    out = jax.lax.map(per_pos, (q_rows.swapaxes(0, 1), lengths.T))
    return out.swapaxes(0, 1)


def paged_chunk_attention(q_rows, k_pages, v_pages, k_rows, v_rows,
                          block_tables, hist_lens, seg_lens):
    """Incremental chunk attention: R new tokens per segment attend the
    K/V their sequence already wrote into the shared page pool plus the
    chunk's own K/V causally — the continuation/verification sibling of
    ``paged_decode_attention``.

    q_rows/k_rows/v_rows: (S, R, H|KV, D) per-segment chunk rows (row r
    of segment s sits at absolute position hist_lens[s] + r);
    k_pages/v_pages: (P, page_size, KV, D); block_tables: (S, max_pages)
    int32; hist_lens/seg_lens: (S,) int32. Rows r >= seg_lens[s] are
    padding (zeros in, garbage out — callers discard them).

    On real TPUs this dispatches to the chunked paged Pallas kernel
    (repro.kernels.chunk_attention); the fallback gathers each segment's
    pages into logical order, scatters the chunk rows in at their
    absolute positions, and runs the exact masked-decode body per chunk
    position — bit-identical to the decode steps the chunk replaces."""
    s, r_len, h, d = q_rows.shape
    _, page_size, kvh, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    hist = jnp.broadcast_to(
        jnp.asarray(hist_lens, jnp.int32).reshape(-1), (s,))
    slen = jnp.broadcast_to(
        jnp.asarray(seg_lens, jnp.int32).reshape(-1), (s,))
    if jax.default_backend() == "tpu" and page_size % 8 == 0:
        from repro.kernels.chunk_attention import \
            paged_chunk_attention as _pallas
        return _pallas(q_rows, k_pages, v_pages, k_rows, v_rows,
                       block_tables, hist, slen)
    kc = k_pages[block_tables].reshape(s, max_pages * page_size, kvh, d)
    vc = v_pages[block_tables].reshape(s, max_pages * page_size, kvh, d)
    pos = hist[:, None] + jnp.arange(r_len, dtype=jnp.int32)[None, :]
    sidx = jnp.arange(s)[:, None]
    kc = kc.at[sidx, pos].set(k_rows, mode="drop")
    vc = vc.at[sidx, pos].set(v_rows, mode="drop")
    lengths = jnp.where(
        jnp.arange(r_len, dtype=jnp.int32)[None, :] < slen[:, None],
        pos + 1, 0)
    return _masked_chunk_attention(q_rows, kc, vc, lengths)


def decode_index(pos, cache, key):
    """Per-row write/read machinery for one decode step over EITHER cache
    layout — the single place the paged-vs-ring storage contract lives, so
    the three attention families cannot drift (the layout is a static
    pytree property: ``block_tables`` present = paged).

    pos: (B,) int32 current positions; ``key``: the K leaf the layout is
    read from. Returns ``(update, attend, valid)``: ``update(buf, new)``
    writes the step's (B, 1, ...) entries at each row's coordinates;
    ``attend(q, kc, vc, window=0)`` runs decode attention against the
    updated buffer; ``valid`` is the (B,) lengths vector."""
    if "block_tables" in cache:
        tables = cache["block_tables"]
        page_size = cache[key].shape[2]
        bidx = jnp.arange(pos.shape[0])
        # past-capacity clamp is belt-and-braces: the engine caps every
        # slot's token budget at its page capacity, so live rows never
        # reach it (vacant rows sit at pos 0 on the null page)
        page = tables[bidx, jnp.minimum(pos // page_size,
                                        tables.shape[1] - 1)]
        slot = pos % page_size
        valid = jnp.minimum(pos + 1, tables.shape[1] * page_size)

        def update(buf, new):
            return paged_cache_update(buf, new, page, slot)

        def attend(q, kc, vc, window: int = 0):
            if window:
                # a paged slot retains FULL history (pages never evict),
                # so windowed attention needs page-level masking that is
                # not implemented — the engine keeps windowed configs on
                # ring slots, whose overwrite IS the window. Loud > wrong.
                raise NotImplementedError(
                    "sliding-window attention over a paged cache")
            return paged_decode_attention(q, kc, vc, tables, valid)

        return update, attend, valid

    cache_len = cache[key].shape[2]
    slot = (pos % cache_len) if cache_len > 0 else jnp.zeros_like(pos)
    valid = jnp.minimum(pos + 1, cache_len)

    def update(buf, new):
        return cache_row_update(buf, new, slot)

    def attend(q, kc, vc, window: int = 0):
        return decode_attention(q, kc, vc, valid, window=window)

    return update, attend, valid


def carry_cache_meta(out, cache):
    """Thread the storage-contract leaves a decode step only reads
    (``block_tables``) from the old cache into the new one, preserving the
    pytree structure the donated input had — the other half of the
    contract ``decode_index`` owns, so model families never hand-write
    paged-vs-ring knowledge."""
    if "block_tables" in cache:
        out["block_tables"] = cache["block_tables"]
    return out


def decode_attention(q, k_cache, v_cache, valid_len, *, window: int = 0,
                     ring_pos=None):
    """Single-token attention over a KV cache.

    q: (B, H, D); k_cache/v_cache: (B, C, KV, D); valid_len: scalar int or
    per-sequence (B,) int32 lengths — number of valid cache entries per row,
    so mixed-length batches don't pay for the longest sequence. For
    ring-buffer (sliding-window) caches the whole buffer is valid once full;
    masking handles the partial-fill phase. Rows with length 0 (vacant
    continuous-batching slots) return zeros.

    On real TPUs this dispatches to the ragged Pallas kernel
    (repro.kernels.decode_attention), whose per-row cache-block skip makes
    HBM traffic scale with each row's actual length. The jnp path below is
    the CPU/dry-run fallback: masked full-cache compute with static shapes.

    GQA is computed as a grouped einsum — NOT a materialized repeat_kv.
    A repeat broadcasts the whole cache to H heads, which under SPMD turns
    a sequence-sharded cache into a full all-gather per layer (measured:
    25.8 GB/layer on yi-9b decode_32k).
    """
    b, c, kvh, d = k_cache.shape
    lengths = jnp.broadcast_to(
        jnp.asarray(valid_len, jnp.int32).reshape(-1), (b,))
    if jax.default_backend() == "tpu" and c % 128 == 0:
        from repro.kernels.decode_attention import decode_attention as _pallas
        return _pallas(q, k_cache, v_cache, lengths)
    return _masked_decode_attention(q, k_cache, v_cache, lengths)


# --------------------------------------------------------------------------
# GQA attention block (params + apply)
# --------------------------------------------------------------------------
def attn_plan(cfg) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    # NOTE: head_dim is deliberately NOT a fallback shard axis here — a
    # head_dim-sharded q/k makes every attention score tile a partial-sum
    # all-reduce (measured: qwen2 prefill_32k went collective-dominated,
    # ~2.9 TB/device of tile ARs). Non-divisible head counts replicate the
    # (small) projection weights; the KV cache memory is handled by
    # sequence-sharding instead (see cache plans).
    p = {
        "wq": ParamDef((d, h, hd), ("embed", "heads", None)),
        "wk": ParamDef((d, kv, hd), ("embed", "kv_heads", None)),
        "wv": ParamDef((d, kv, hd), ("embed", "kv_heads", None)),
        "wo": ParamDef((h, hd, d), ("heads", None, "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = ParamDef((h, hd), ("heads", None), "zeros")
        p["bk"] = ParamDef((kv, hd), ("kv_heads", None), "zeros")
        p["bv"] = ParamDef((kv, hd), ("kv_heads", None), "zeros")
    return p


def constrain_q_prefill(cfg, q, tp: int = 16):
    """Context parallelism for archs whose q-head count doesn't divide the
    TP width (qwen2: 14, whisper: 12, granite: 24): shard the q SEQUENCE so
    attention compute splits tp-ways with only a tiny all-gather of the
    (GQA-small) k/v — instead of replicating the whole S² computation."""
    if cfg.num_heads % tp:
        from repro.utils.sharding import maybe_constrain
        return maybe_constrain(q, "batch", "kv_seq", None, None)
    return q


def cp_attention(cfg, q, k, v, *, causal: bool, window: int = 0):
    """Context-parallel self-attention for replicated-head architectures.

    Sharding constraints alone do NOT make XLA partition the chunked
    attention's lax.map/scan over the sequence (measured: qwen2 prefill
    attention stayed 16x-replicated). This dispatcher makes the split
    explicit with shard_map: each TP shard runs flash attention on its
    sequence slice of q against the (small, GQA) full k/v, with the causal
    mask shifted by the shard's offset.
    """
    from repro.utils.sharding import active_mesh, batch_axes, resolve_spec
    mesh = active_mesh()
    s = q.shape[1]
    if (mesh is None or "model" not in mesh.axis_names
            or cfg.num_heads % mesh.shape["model"] == 0
            or s % (mesh.shape["model"] * 512) != 0
            or q.shape[0] % max(1, np.prod([mesh.shape[a]
                                            for a in batch_axes(mesh)])) != 0):
        q = constrain_q_prefill(cfg, q)
        return big_attention(q, k, v, causal=causal, window=window)

    from jax.sharding import PartitionSpec as P
    from repro.kernels.flash_vjp import flash_attention_vjp
    ba = batch_axes(mesh)
    bspec = ba if len(ba) > 1 else (ba[0] if ba else None)
    q_spec = P(bspec, "model")
    kv_spec = P(bspec)
    local_s = s // mesh.shape["model"]

    def local(q_l, k_l, v_l):
        off = (jax.lax.axis_index("model") * local_s).astype(jnp.float32)
        return flash_attention_vjp(q_l, k_l, v_l, causal=causal,
                                   window=window, q_offset=off)

    return jax.shard_map(local, mesh=mesh,
                         in_specs=(q_spec, kv_spec, kv_spec),
                         out_specs=q_spec, check_vma=False)(q, k, v)


def constrain_q_decode(cfg, q, tp: int = 16):
    """Against a sequence-sharded cache (kv heads non-divisible), the
    single-token q must be replicated across the TP group: scores are then
    computed per cache shard and combined by a (batch, heads)-sized
    distributed softmax — bytes, not gigabytes, of all-reduce."""
    if cfg.num_kv_heads % tp:
        from repro.utils.sharding import maybe_constrain
        return maybe_constrain(q, "batch", None, None)
    return q


def kv_cache_spec(cfg, tp: int = 16):
    """Sharding for a (layers, batch, seq, kv_heads, head_dim) cache.

    KV heads shard when they divide the TP width (zero-communication local
    decode attention); otherwise the *sequence* dim shards — decode
    attention then does a distributed softmax whose all-reduce is only
    (batch, heads[, head_dim]) per layer, thousands of times smaller than
    head_dim-sharded partial sums."""
    if cfg.num_kv_heads and cfg.num_kv_heads % tp == 0:
        return ("stack", "batch", None, "kv_heads", None)
    return ("stack", "batch", "kv_seq", None, None)


def paged_kv_cache_spec(cfg, tp: int = 16):
    """Sharding for a (layers, num_pages, page_size, kv_heads, head_dim)
    paged pool. KV heads shard when they divide the TP width (same local
    decode-attention argument as ``kv_cache_spec``); otherwise the *page*
    dim shards — pages are the paged analogue of the sequence dim, and the
    block table (host-replicated int32) stays tiny either way."""
    if cfg.num_kv_heads and cfg.num_kv_heads % tp == 0:
        return ("stack", None, None, "kv_heads", None)
    return ("stack", "kv_seq", None, None, None)


def attn_qkv(p, cfg, x, positions):
    """Project + rope. x: (B,S,d) -> q:(B,S,H,hd), k,v:(B,S,KV,hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if not cfg.learned_pos_emb:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_out(p, x_dtype, attn):
    """attn: (B,S,H,hd) or (B,H,hd) -> project back to d_model."""
    return jnp.einsum("...hk,hkd->...d", attn, p["wo"].astype(x_dtype))


# --------------------------------------------------------------------------
# SwiGLU MLP
# --------------------------------------------------------------------------
def mlp_plan(cfg, d_ff: Optional[int] = None) -> dict:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    return {
        "wi_gate": ParamDef((d, ff), ("embed", "mlp")),
        "wi_up": ParamDef((d, ff), ("embed", "mlp")),
        "wo": ParamDef((ff, d), ("mlp", "embed")),
    }


def apply_mlp(p, x):
    g = jnp.einsum("...d,df->...f", x, p["wi_gate"].astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, p["wi_up"].astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, p["wo"].astype(x.dtype))


# --------------------------------------------------------------------------
# sampling
# --------------------------------------------------------------------------
def top_k_top_p_filter(logits: jax.Array, *, top_k: int = 0,
                       top_p: float = 1.0) -> jax.Array:
    """Mask logits outside the top-k set and/or the top-p nucleus to -1e30.

    ``top_k``/``top_p`` are static Python values, so this is jit-safe inside
    the decode scan body — each (top_k, top_p) pair is one executable, not a
    per-step branch. The arg-max token is always kept, so a degenerate
    ``top_p`` can never mask the whole vocabulary."""
    if top_k and top_k < logits.shape[-1]:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    if top_p < 1.0:
        srt = jnp.sort(logits, axis=-1)[..., ::-1]          # descending
        probs = jax.nn.softmax(srt.astype(jnp.float32), axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # keep tokens whose cumulative mass BEFORE them is < top_p
        keep = (cum - probs) < top_p
        keep = keep.at[..., 0].set(True)
        thresh = jnp.min(jnp.where(keep, srt, jnp.inf), axis=-1,
                         keepdims=True).astype(logits.dtype)
        logits = jnp.where(logits < thresh, -1e30, logits)
    return logits


def sample_logits(rng: jax.Array, logits: jax.Array, *,
                  temperature: float = 1.0, top_k: int = 0,
                  top_p: float = 1.0) -> jax.Array:
    """Draw next tokens (B,) int32 from (B, V) logits.

    temperature <= 0 degenerates to greedy arg-max (bit-exact with the
    greedy decode path); otherwise temperature-scaled top-k/top-p
    (nucleus) sampling via Gumbel trick (``jax.random.categorical``)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, -1).astype(jnp.int32)
    lg = logits.astype(jnp.float32) / temperature
    lg = top_k_top_p_filter(lg, top_k=top_k, top_p=top_p)
    return jax.random.categorical(rng, lg).astype(jnp.int32)


# --------------------------------------------------------------------------
# embeddings / unembedding
# --------------------------------------------------------------------------
def embed_plan(cfg) -> dict:
    v = cfg.padded_vocab
    p = {"embedding": ParamDef((v, cfg.d_model), ("vocab", "embed"))}
    if not cfg.tie_embeddings:
        p["lm_head"] = ParamDef((cfg.d_model, v), ("embed", "vocab"))
    return p


def embed_tokens(p, tokens, dtype):
    return p["embedding"].astype(dtype)[tokens]


def unembed(p, x, cfg):
    """Logits over the PADDED vocab; pad rows masked to -inf (sampling and
    cross-entropy both ignore them; slicing back would break the vocab
    sharding)."""
    w = p.get("lm_head")
    if w is None:
        w = p["embedding"].T
    logits = jnp.einsum("...d,dv->...v", x, w.astype(x.dtype))
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, jnp.asarray(-1e9, logits.dtype), logits)
    return logits
