"""Mamba2 (SSD — state-space duality) decoder, attention-free.

The SSD scan itself lives in ``repro.kernels`` (Pallas TPU kernel + chunked
jnp fallback); this module provides the block plumbing: gated in-projection,
shared causal depthwise conv over (x, B, C), dt softplus, gated RMSNorm and
out-projection — plus the recurrent decode path that makes `long_500k`
native (O(1) state, no KV cache).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import layers as L
from repro.models.layers import ParamDef


# --------------------------------------------------------------------------
# plans
# --------------------------------------------------------------------------
def mamba_layer_plan(cfg) -> dict:
    d, di, n, h, w = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                      cfg.ssm_heads, cfg.ssm_conv_width)
    return {
        "norm": L.norm_plan(d, cfg.norm),
        "wz": ParamDef((d, di), ("embed", "ssm_inner")),
        "wx": ParamDef((d, di), ("embed", "ssm_inner")),
        "wB": ParamDef((d, n), ("embed", None)),
        "wC": ParamDef((d, n), ("embed", None)),
        "wdt": ParamDef((d, h), ("embed", "ssm_heads")),
        "dt_bias": ParamDef((h,), ("ssm_heads",), "zeros"),
        "A_log": ParamDef((h,), ("ssm_heads",), "zeros"),     # A = -exp(A_log)
        "D": ParamDef((h,), ("ssm_heads",), "ones"),
        "conv_x": ParamDef((w, di), (None, "ssm_inner"), std=0.2),
        "conv_B": ParamDef((w, n), (None, None), std=0.2),
        "conv_C": ParamDef((w, n), (None, None), std=0.2),
        "gate_norm": {"scale": ParamDef((di,), ("ssm_inner",), "ones")},
        "wo": ParamDef((di, d), ("ssm_inner", "embed")),
    }


def plan(cfg) -> dict:
    return {
        "embed": L.embed_plan(cfg),
        "layers": L.stack_plan(mamba_layer_plan(cfg), cfg.num_layers),
        "final_norm": L.norm_plan(cfg.d_model, cfg.norm),
    }


def init(key, cfg, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "embed": L.init_from_plan(k1, L.embed_plan(cfg), dtype),
        "layers": L.init_stacked(k2, mamba_layer_plan(cfg), cfg.num_layers, dtype),
        "final_norm": L.init_from_plan(k3, L.norm_plan(cfg.d_model, cfg.norm), dtype),
    }


# --------------------------------------------------------------------------
# block internals
# --------------------------------------------------------------------------
def _causal_conv(x, w):
    """x: (B, Lpad..., C) depthwise causal; w: (W, C)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(width))
    return out


def _proj_in(lp, cfg, xin):
    dt_f = xin.astype(jnp.float32)
    z = jnp.einsum("...d,de->...e", xin, lp["wz"].astype(xin.dtype))
    xr = jnp.einsum("...d,de->...e", xin, lp["wx"].astype(xin.dtype))
    bc = jnp.einsum("...d,dn->...n", xin, lp["wB"].astype(xin.dtype))
    cc = jnp.einsum("...d,dn->...n", xin, lp["wC"].astype(xin.dtype))
    dt = jax.nn.softplus(
        jnp.einsum("...d,dh->...h", dt_f, lp["wdt"].astype(jnp.float32))
        + lp["dt_bias"].astype(jnp.float32))
    return z, xr, bc, cc, dt


def _gate_out(lp, cfg, y, z, dtype):
    g = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    g = g * jax.lax.rsqrt(jnp.mean(g * g, -1, keepdims=True) + 1e-5)
    g = (g * lp["gate_norm"]["scale"].astype(jnp.float32)).astype(dtype)
    return jnp.einsum("...e,ed->...d", g, lp["wo"].astype(dtype))


def mamba_block(lp, cfg, h, *, backend=None) -> Tuple[jax.Array, Tuple]:
    """Full-sequence mamba2 block. h: (B,S,d).

    Returns (h_out, (ssm_state (B,H,N,P), conv_tail (B,W-1,di+2N))).
    """
    b, s, d = h.shape
    di, n, nh, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    w = cfg.ssm_conv_width
    xin = L.apply_norm(lp["norm"], h, cfg.norm)
    z, xr, bc, cc, dt = _proj_in(lp, cfg, xin)

    xbc = jnp.concatenate([xr, bc, cc], axis=-1)                # (B,S,di+2N)
    conv_tail = xbc[:, max(0, s - (w - 1)):, :]
    if s < w - 1:                                               # degenerate tiny-seq
        conv_tail = jnp.pad(xbc, ((0, 0), (w - 1 - s, 0), (0, 0)))
    conv_w = jnp.concatenate(
        [lp["conv_x"], lp["conv_B"], lp["conv_C"]], axis=-1).astype(h.dtype)
    xbc = jax.nn.silu(_causal_conv(xbc, conv_w).astype(jnp.float32)).astype(h.dtype)
    xr, bc, cc = jnp.split(xbc, [di, di + n], axis=-1)

    x4 = xr.reshape(b, s, nh, p)
    a = -jnp.exp(lp["A_log"].astype(jnp.float32))
    y, state = ops.ssd(x4, dt, a, bc, cc, chunk=cfg.ssm_chunk, backend=backend)
    y = y + x4 * lp["D"].astype(y.dtype)[None, None, :, None]
    out = _gate_out(lp, cfg, y.reshape(b, s, di), z, h.dtype)
    return h + out, (state, conv_tail)


def mamba_block_decode(lp, cfg, h, ssm_state, conv_buf) -> Tuple[jax.Array, Tuple]:
    """Single-token recurrent step. h: (B,d); state (B,H,N,P);
    conv_buf: (B, W-1, di+2N) raw (pre-conv) inputs."""
    b, d = h.shape
    di, n, nh, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    xin = L.apply_norm(lp["norm"], h, cfg.norm)
    z, xr, bc, cc, dt = _proj_in(lp, cfg, xin)

    xbc_new = jnp.concatenate([xr, bc, cc], axis=-1)            # (B, di+2N)
    window = jnp.concatenate([conv_buf, xbc_new[:, None, :]], axis=1)
    conv_w = jnp.concatenate(
        [lp["conv_x"], lp["conv_B"], lp["conv_C"]], axis=-1).astype(h.dtype)
    conv_out = (window * conv_w[None]).sum(axis=1)
    xbc = jax.nn.silu(conv_out.astype(jnp.float32)).astype(h.dtype)
    xr, bc, cc = jnp.split(xbc, [di, di + n], axis=-1)

    x4 = xr.reshape(b, nh, p)
    a = -jnp.exp(lp["A_log"].astype(jnp.float32))
    y, state = ops.ssd_decode(x4, dt, a, bc, cc, ssm_state)
    y = y + x4 * lp["D"].astype(y.dtype)[None, :, None]
    out = _gate_out(lp, cfg, y.reshape(b, di), z, h.dtype)
    return h + out, (state, window[:, 1:, :])


def mamba_block_packed(lp, cfg, h, seg_ids, pos, seg_starts, seg_lens,
                       row_len: int) -> Tuple[jax.Array, Tuple]:
    """Packed-ragged mamba2 block. h: (1, T, d) packed tokens.

    The FLOP-heavy parts (projections, gating, out-projection) run on the
    packed row — sum(lens) tokens, no padding. Only the sequence-mixing
    ops (causal conv, SSD scan) need contiguous per-sequence layout: the
    post-projection activations are gathered into per-segment rows
    (``layers.segments_to_rows``), mixed there, and gathered back. The
    scan state RESETS at segment boundaries for free — each segment is
    its own row, and ``dt`` is exactly zero on row padding (the masked
    gather zeroes it; decay exp(0)=1, update 0: the state freezes EXACTLY
    at each segment's last token, so the returned per-segment states
    match per-request prefill bit for bit; see ops._ssd_chunked_jnp's
    padding note).

    Returns (h_out (1, T, d), (per-segment ssm states (S, H, N, P),
    per-segment conv tails (S, W-1, di+2N)))."""
    b, t, d = h.shape
    di, n, nh, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    w = cfg.ssm_conv_width
    s_max = seg_lens.shape[0]
    xin = L.apply_norm(lp["norm"], h, cfg.norm)
    z, xr, bc, cc, dt = _proj_in(lp, cfg, xin)                  # packed

    xbc = jnp.concatenate([xr, bc, cc], axis=-1)                # (1,T,di+2N)
    raw_rows = L.segments_to_rows(xbc[0], seg_starts, seg_lens, row_len)
    conv_w = jnp.concatenate(
        [lp["conv_x"], lp["conv_B"], lp["conv_C"]], axis=-1).astype(h.dtype)
    mixed = jax.nn.silu(
        _causal_conv(raw_rows, conv_w).astype(jnp.float32)).astype(h.dtype)
    xr_r, bc_r, cc_r = jnp.split(mixed, [di, di + n], axis=-1)

    dt_rows = L.segments_to_rows(dt[0], seg_starts, seg_lens, row_len)

    x4 = xr_r.reshape(s_max, row_len, nh, p)
    a = -jnp.exp(lp["A_log"].astype(jnp.float32))
    y_r, states = ops.ssd(x4, dt_rows, a, bc_r, cc_r, chunk=cfg.ssm_chunk)
    y_r = y_r + x4 * lp["D"].astype(y_r.dtype)[None, None, :, None]
    y = L.rows_to_segments(y_r.reshape(s_max, row_len, di),
                           seg_ids, pos)[None]
    out = _gate_out(lp, cfg, y, z, h.dtype)

    # conv tail: each segment's last W-1 RAW (pre-conv) inputs,
    # left-padded with zeros for segments shorter than the window
    j = jnp.arange(w - 1)
    idx = seg_lens[:, None] - (w - 1) + j[None, :]              # (S, W-1)
    tails = raw_rows[jnp.arange(s_max)[:, None],
                     jnp.clip(idx, 0, row_len - 1)]
    tails = jnp.where((idx >= 0)[..., None], tails, 0.0).astype(h.dtype)
    return h + out, (states, tails)


# --------------------------------------------------------------------------
# model-level API
# --------------------------------------------------------------------------
def forward(params, cfg, tokens, *, remat: bool = False):
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed_tokens(params["embed"], tokens, dtype)

    from repro.utils.sharding import maybe_constrain

    def body(carry, lp):
        y, _ = mamba_block(lp, cfg, carry)
        y = maybe_constrain(y, "batch", None, "act_embed")
        return y, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.unembed(params["embed"], x, cfg)
    aux = {"load_balance_loss": jnp.float32(0.0),
           "dropped_fraction": jnp.float32(0.0)}
    return logits, aux


# the SSD state is O(1) per sequence — there is nothing to page. The paged
# engine still runs this family (shared lengths/done-flag plumbing); it
# just skips the page allocator.
PAGED_KEYS = ()


def cache_plan(cfg, batch: int, cache_len: int) -> dict:
    nlayer = cfg.num_layers
    di, n, nh, p, w = (cfg.d_inner, cfg.ssm_state, cfg.ssm_heads,
                       cfg.ssm_head_dim, cfg.ssm_conv_width)
    return {
        "ssm": ParamDef((nlayer, batch, nh, n, p),
                        ("stack", "batch", "ssm_heads", None, None), "zeros"),
        "conv": ParamDef((nlayer, batch, w - 1, di + 2 * n),
                         ("stack", "batch", None, None), "zeros"),
        # per-sequence positions: slot-based continuous batching
        "pos": ParamDef((batch,), None, "zeros"),
    }


def init_cache(cfg, batch: int, cache_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    cp = cache_plan(cfg, batch, cache_len)
    return {
        "ssm": jnp.zeros(cp["ssm"].shape, jnp.float32),
        "conv": jnp.zeros(cp["conv"].shape, dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def prefill(params, cfg, tokens, cache_len: int):
    dtype = jnp.dtype(cfg.dtype)
    b, s = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, dtype)

    def body(carry, lp):
        y, (state, conv_tail) = mamba_block(lp, cfg, carry)
        return y, (state, conv_tail)

    x, (states, convs) = jax.lax.scan(body, x, params["layers"])
    x = L.apply_norm(params["final_norm"], x[:, -1], cfg.norm)
    logits = L.unembed(params["embed"], x, cfg)
    return logits, {"ssm": states, "conv": convs,
                    "pos": jnp.full((b,), s, jnp.int32)}


def prefill_packed(params, cfg, packed, max_seg_len: int):
    """Packed ragged prefill for the attention-free family: one
    (1, total_tokens) row, SSD state reset at segment boundaries (see
    ``mamba_block_packed``). Returns per-segment last logits (S, V) and a
    per-segment cache ({ssm, conv, pos} — there is nothing per-token to
    page; the engine dense-scatters the S rows into slot rows)."""
    tokens = packed["tokens"]
    seg_ids, seg_starts = packed["seg_ids"], packed["seg_starts"]
    seg_lens = packed["seg_lens"]
    dtype = jnp.dtype(cfg.dtype)
    b, t = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, dtype)
    pos = L.packed_positions(seg_ids, seg_starts)

    def body(carry, lp):
        y, (states, tails) = mamba_block_packed(
            lp, cfg, carry, seg_ids, pos, seg_starts, seg_lens, max_seg_len)
        return y, (states, tails)

    x, (states, convs) = jax.lax.scan(body, x, params["layers"])
    last = jnp.clip(seg_starts + seg_lens - 1, 0, t - 1)
    xl = L.apply_norm(params["final_norm"], x[0, last], cfg.norm)
    logits = L.unembed(params["embed"], xl, cfg)
    return logits, {"ssm": states, "conv": convs,
                    "pos": seg_lens.astype(jnp.int32)}


def decode_step(params, cfg, token, cache):
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed_tokens(params["embed"], token, dtype)           # (B, d)

    def body(carry, xs):
        lp, state, conv = xs
        y, (state, conv) = mamba_block_decode(lp, cfg, carry, state, conv)
        return y, (state, conv)

    x, (states, convs) = jax.lax.scan(
        body, x, (params["layers"], cache["ssm"], cache["conv"]))
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.unembed(params["embed"], x, cfg)
    pos = jnp.broadcast_to(jnp.asarray(cache["pos"], jnp.int32), token.shape)
    return logits, {"ssm": states, "conv": convs, "pos": pos + 1}
