"""Uniform model API over all assigned architecture families.

``build_model(cfg)`` returns a ``ModelAPI`` whose five callables have the
same signatures regardless of family — the serving engine, trainer, and
dry-run never branch on architecture:

  forward(params, batch, remat=False)        -> (logits (B,S,V), aux)
  prefill(params, batch, cache)              -> (last_logits (B,V), cache)
  prefill_packed(params, packed, row_len)    -> (seg_logits (S,V), packed cache)
  decode_step(params, token (B,), cache)     -> (logits (B,V), cache)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import encdec, hybrid, ssm, transformer
from repro.models import layers as L
from repro.utils.sharding import resolve_spec, tree_specs


@dataclasses.dataclass
class ModelAPI:
    cfg: ModelConfig
    plan: Any
    init: Callable
    forward: Callable
    prefill: Callable
    # packed ragged prefill: a whole admission batch concatenated into one
    # (1, total_tokens) row with per-token segment ids (see each family's
    # ``prefill_packed``); returns per-SEGMENT last logits plus a packed
    # cache whose per-token leaves the engine scatters straight into pages
    prefill_packed: Callable
    decode_step: Callable
    cache_plan: Callable
    init_cache: Callable
    # paged KV cache (block tables; see repro.serving.kv_cache). Families
    # with no KV to page (pure SSM) have paged_keys == () and None
    # builders — the engine then falls back to per-slot dense state while
    # keeping the shared ragged-lengths/done-flag plumbing.
    paged_keys: tuple = ()
    paged_cache_plan: Optional[Callable] = None
    init_paged_cache: Optional[Callable] = None
    # incremental chunk attention: score NEW tokens against K/V already
    # resident in the paged pool (chunked-prefill continuations and
    # speculative-decoding verification). None for families without it —
    # the engine then recomputes continuations from token 0.
    prefill_chunk: Optional[Callable] = None

    # ------------------------------------------------------------- sharding
    def param_specs(self, mesh):
        return tree_specs(self.plan, mesh)

    def cache_specs(self, mesh, batch: int, cache_len: int):
        return tree_specs(self.cache_plan(batch, cache_len), mesh)

    def abstract_params(self, dtype=jnp.float32):
        return L.abstract_params(self.plan, dtype)

    def abstract_cache(self, batch: int, cache_len: int, dtype=None):
        dtype = dtype or jnp.dtype(self.cfg.dtype)
        cp = self.cache_plan(batch, cache_len)
        return jax.tree.map(
            lambda pd: jax.ShapeDtypeStruct(
                tuple(pd.shape),
                # 0/1-D leaves are the int32 per-sequence position vector
                jnp.int32 if len(pd.shape) <= 1 else
                (jnp.float32 if pd.spec and "ssm_heads" in pd.spec and len(pd.shape) == 5
                 else dtype)),
            cp, is_leaf=lambda x: isinstance(x, L.ParamDef))

    # -------------------------------------------------------------- inputs
    def input_specs(self, shape: InputShape, mesh=None) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of this shape."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        dt = jnp.dtype(cfg.dtype)
        if shape.kind == "train":
            specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        elif shape.kind == "prefill":
            specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        else:  # decode: ONE new token against a seq_len-sized cache
            specs = {"token": jax.ShapeDtypeStruct((b,), jnp.int32)}
        if cfg.has_encoder and shape.kind != "decode":
            specs["enc_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), dt)
        return specs

    def input_shardings(self, shape: InputShape, mesh):
        specs = self.input_specs(shape)
        out = {}
        for name, sds in specs.items():
            logical = ("batch",) + (None,) * (len(sds.shape) - 1)
            out[name] = resolve_spec(logical, sds.shape, mesh)
        return out


def build_model(cfg: ModelConfig) -> ModelAPI:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        mod = transformer
    elif fam == "ssm":
        mod = ssm
    elif fam == "hybrid":
        mod = hybrid
    elif fam == "audio":
        mod = encdec
    else:
        raise ValueError(fam)

    if fam == "audio":
        def forward(params, batch, remat=False):
            return encdec.forward(params, cfg, batch["tokens"],
                                  batch["enc_embeds"], remat=remat)

        def prefill(params, batch, cache_len):
            return encdec.prefill(params, cfg, batch["tokens"], cache_len,
                                  batch["enc_embeds"])
    else:
        def forward(params, batch, remat=False):
            return mod.forward(params, cfg, batch["tokens"], remat=remat)

        def prefill(params, batch, cache_len):
            return mod.prefill(params, cfg, batch["tokens"], cache_len)

    paged_keys = tuple(getattr(mod, "PAGED_KEYS", ()))
    paged_plan = init_paged = None
    if paged_keys:
        def paged_plan(batch, num_pages, page_size, max_pages):
            return mod.paged_cache_plan(cfg, batch, num_pages, page_size,
                                        max_pages)

        def init_paged(batch, num_pages, page_size, max_pages, dtype=None):
            return mod.init_paged_cache(cfg, batch, num_pages, page_size,
                                        max_pages, dtype)

    def prefill_packed(params, packed, max_seg_len):
        return mod.prefill_packed(params, cfg, packed, max_seg_len)

    chunk_fn = getattr(mod, "prefill_chunk", None)
    prefill_chunk = None
    if chunk_fn is not None:
        def prefill_chunk(params, packed, cache, max_seg_len):
            return chunk_fn(params, cfg, packed, cache, max_seg_len)

    return ModelAPI(
        cfg=cfg,
        plan=mod.plan(cfg),
        init=lambda key, dtype=jnp.float32: mod.init(key, cfg, dtype),
        forward=forward,
        prefill=prefill,
        prefill_packed=prefill_packed,
        decode_step=lambda params, token, cache: mod.decode_step(
            params, cfg, token, cache),
        cache_plan=lambda batch, cache_len: mod.cache_plan(cfg, batch, cache_len),
        init_cache=lambda batch, cache_len, dtype=None: mod.init_cache(
            cfg, batch, cache_len, dtype),
        paged_keys=paged_keys,
        paged_cache_plan=paged_plan,
        init_paged_cache=init_paged,
        prefill_chunk=prefill_chunk,
    )
