"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention block.

81 mamba2 layers; a single weight-tied full-attention block (attn + SwiGLU
MLP) is applied after every ``cfg.attn_every``-th layer. Because the
attention weights are shared, the scan over mamba layers can invoke it via
``jax.lax.cond`` inside the scan body — per-invocation KV caches are indexed
by ``layer_idx // attn_every``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import ssm
from repro.models.layers import ParamDef


def n_attn_blocks(cfg) -> int:
    return cfg.num_layers // cfg.attn_every


def shared_attn_plan(cfg) -> dict:
    return {
        "ln1": L.norm_plan(cfg.d_model, cfg.norm),
        "attn": L.attn_plan(cfg),
        "ln2": L.norm_plan(cfg.d_model, cfg.norm),
        "mlp": L.mlp_plan(cfg),
    }


def plan(cfg) -> dict:
    return {
        "embed": L.embed_plan(cfg),
        "layers": L.stack_plan(ssm.mamba_layer_plan(cfg), cfg.num_layers),
        "shared_attn": shared_attn_plan(cfg),
        "final_norm": L.norm_plan(cfg.d_model, cfg.norm),
    }


def init(key, cfg, dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "embed": L.init_from_plan(k1, L.embed_plan(cfg), dtype),
        "layers": L.init_stacked(k2, ssm.mamba_layer_plan(cfg), cfg.num_layers, dtype),
        "shared_attn": L.init_from_plan(k3, shared_attn_plan(cfg), dtype),
        "final_norm": L.init_from_plan(k4, L.norm_plan(cfg.d_model, cfg.norm), dtype),
    }


def _apply_shared_full(sp, cfg, x, positions):
    h = L.apply_norm(sp["ln1"], x, cfg.norm)
    q, k, v = L.attn_qkv(sp["attn"], cfg, h, positions)
    q = L.constrain_q_prefill(cfg, q)
    attn = L.big_attention(q, k, v, causal=True)
    x = x + L.attn_out(sp["attn"], x.dtype, attn)
    h = L.apply_norm(sp["ln2"], x, cfg.norm)
    return x + L.apply_mlp(sp["mlp"], h), (k, v)


def forward(params, cfg, tokens, *, remat: bool = False):
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed_tokens(params["embed"], tokens, dtype)
    positions = jnp.arange(tokens.shape[1])[None, :]
    sp = params["shared_attn"]

    from repro.utils.sharding import maybe_constrain

    def body(carry, xs):
        lp, idx = xs
        y, _ = ssm.mamba_block(lp, cfg, carry)
        y = jax.lax.cond(
            (idx + 1) % cfg.attn_every == 0,
            lambda t: _apply_shared_full(sp, cfg, t, positions)[0],
            lambda t: t,
            y)
        y = maybe_constrain(y, "batch", None, "act_embed")
        return y, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(
        body, x, (params["layers"], jnp.arange(cfg.num_layers)))
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.unembed(params["embed"], x, cfg)
    aux = {"load_balance_loss": jnp.float32(0.0),
           "dropped_fraction": jnp.float32(0.0)}
    return logits, aux


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------
# only the shared-attention KV is paged; the mamba state is O(1) per row
# and stays per-slot dense (paging a fixed-size state buys nothing)
PAGED_KEYS = ("attn_k", "attn_v")


def cache_plan(cfg, batch: int, cache_len: int) -> dict:
    base = ssm.cache_plan(cfg, batch, cache_len)
    na = n_attn_blocks(cfg)
    kv_shape = (na, batch, cache_len, cfg.num_kv_heads, cfg.resolved_head_dim)
    spec = L.kv_cache_spec(cfg)
    base["attn_k"] = ParamDef(kv_shape, spec, "zeros")
    base["attn_v"] = ParamDef(kv_shape, spec, "zeros")
    return base


def init_cache(cfg, batch: int, cache_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    cache = ssm.init_cache(cfg, batch, cache_len, dtype)
    cp = cache_plan(cfg, batch, cache_len)
    cache["attn_k"] = jnp.zeros(cp["attn_k"].shape, dtype)
    cache["attn_v"] = jnp.zeros(cp["attn_v"].shape, dtype)
    return cache


def paged_cache_plan(cfg, batch: int, num_pages: int, page_size: int,
                     max_pages: int) -> dict:
    base = ssm.cache_plan(cfg, batch, 0)
    na = n_attn_blocks(cfg)
    kv_shape = (na, num_pages, page_size, cfg.num_kv_heads,
                cfg.resolved_head_dim)
    spec = L.paged_kv_cache_spec(cfg)
    base["attn_k"] = ParamDef(kv_shape, spec, "zeros")
    base["attn_v"] = ParamDef(kv_shape, spec, "zeros")
    base["block_tables"] = ParamDef((batch, max_pages), None, "zeros")
    return base


def init_paged_cache(cfg, batch: int, num_pages: int, page_size: int,
                     max_pages: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    cache = ssm.init_cache(cfg, batch, 0, dtype)
    cp = paged_cache_plan(cfg, batch, num_pages, page_size, max_pages)
    cache["attn_k"] = jnp.zeros(cp["attn_k"].shape, dtype)
    cache["attn_v"] = jnp.zeros(cp["attn_v"].shape, dtype)
    cache["block_tables"] = jnp.zeros((batch, max_pages), jnp.int32)
    return cache


def prefill(params, cfg, tokens, cache_len: int):
    dtype = jnp.dtype(cfg.dtype)
    b, s = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, dtype)
    positions = jnp.arange(s)[None, :]
    sp = params["shared_attn"]
    na = n_attn_blocks(cfg)

    def body(carry, xs):
        h, kc, vc = carry
        lp, idx = xs
        h, (state, conv_tail) = ssm.mamba_block(lp, cfg, h)

        def attn_branch(args):
            h_, kc_, vc_ = args
            h2, (k, v) = _apply_shared_full(sp, cfg, h_, positions)
            if s <= cache_len:
                kk = jnp.zeros((b, cache_len) + k.shape[2:], k.dtype).at[:, :s].set(k)
                vv = jnp.zeros((b, cache_len) + v.shape[2:], v.dtype).at[:, :s].set(v)
            else:
                kk, vv = k[:, s - cache_len:], v[:, s - cache_len:]
            j = jnp.minimum(idx // cfg.attn_every, na - 1)
            kc_ = jax.lax.dynamic_update_slice_in_dim(kc_, kk[None], j, axis=0)
            vc_ = jax.lax.dynamic_update_slice_in_dim(vc_, vv[None], j, axis=0)
            return h2, kc_, vc_

        h, kc, vc = jax.lax.cond(
            (idx + 1) % cfg.attn_every == 0, attn_branch,
            lambda args: args, (h, kc, vc))
        return (h, kc, vc), (state, conv_tail)

    na_shape = (na, b, cache_len, cfg.num_kv_heads, cfg.resolved_head_dim)
    kc0 = jnp.zeros(na_shape, dtype)
    vc0 = jnp.zeros(na_shape, dtype)
    (x, kc, vc), (states, convs) = jax.lax.scan(
        body, (x, kc0, vc0),
        (params["layers"], jnp.arange(cfg.num_layers)))
    x = L.apply_norm(params["final_norm"], x[:, -1], cfg.norm)
    logits = L.unembed(params["embed"], x, cfg)
    return logits, {"ssm": states, "conv": convs, "attn_k": kc, "attn_v": vc,
                    "pos": jnp.full((b,), s, jnp.int32)}


def prefill_packed(params, cfg, packed, max_seg_len: int):
    """Packed ragged prefill: mamba backbone with per-segment state resets
    (``ssm.mamba_block_packed``) + the shared attention block run
    segment-masked over the packed row. The shared-attention K/V stays in
    PACKED per-token order (na, T, KV, D) so the engine can scatter each
    segment's tokens straight into its slot's pages; mamba state/conv are
    per-segment rows like the pure-SSM family."""
    tokens = packed["tokens"]
    seg_ids, seg_starts = packed["seg_ids"], packed["seg_starts"]
    seg_lens = packed["seg_lens"]
    dtype = jnp.dtype(cfg.dtype)
    b, t = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, dtype)
    pos = L.packed_positions(seg_ids, seg_starts)
    positions = pos[None, :]
    sp = params["shared_attn"]
    na = n_attn_blocks(cfg)

    def body(carry, xs):
        h, kc, vc = carry
        lp, idx = xs
        h, (states, tails) = ssm.mamba_block_packed(
            lp, cfg, h, seg_ids, pos, seg_starts, seg_lens, max_seg_len)

        def attn_branch(args):
            h_, kc_, vc_ = args
            hh = L.apply_norm(sp["ln1"], h_, cfg.norm)
            q, k, v = L.attn_qkv(sp["attn"], cfg, hh, positions)
            attn = L.packed_prefill_attention(
                q, k, v, seg_ids, pos, seg_starts, seg_lens,
                row_len=max_seg_len)
            h2 = h_ + L.attn_out(sp["attn"], h_.dtype, attn)
            hh2 = L.apply_norm(sp["ln2"], h2, cfg.norm)
            h2 = h2 + L.apply_mlp(sp["mlp"], hh2)
            j = jnp.minimum(idx // cfg.attn_every, na - 1)
            kc_ = jax.lax.dynamic_update_slice_in_dim(kc_, k, j, axis=0)
            vc_ = jax.lax.dynamic_update_slice_in_dim(vc_, v, j, axis=0)
            return h2, kc_, vc_

        h, kc, vc = jax.lax.cond(
            (idx + 1) % cfg.attn_every == 0, attn_branch,
            lambda args: args, (h, kc, vc))
        return (h, kc, vc), (states, tails)

    kv_shape = (na, t, cfg.num_kv_heads, cfg.resolved_head_dim)
    kc0 = jnp.zeros(kv_shape, dtype)
    vc0 = jnp.zeros(kv_shape, dtype)
    (x, kc, vc), (states, convs) = jax.lax.scan(
        body, (x, kc0, vc0), (params["layers"], jnp.arange(cfg.num_layers)))
    last = jnp.clip(seg_starts + seg_lens - 1, 0, t - 1)
    xl = L.apply_norm(params["final_norm"], x[0, last], cfg.norm)
    logits = L.unembed(params["embed"], xl, cfg)
    return logits, {"ssm": states, "conv": convs, "attn_k": kc, "attn_v": vc,
                    "pos": seg_lens.astype(jnp.int32)}


def decode_step(params, cfg, token, cache):
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed_tokens(params["embed"], token, dtype)
    pos = jnp.broadcast_to(jnp.asarray(cache["pos"], jnp.int32), token.shape)
    update, attend, _ = L.decode_index(pos, cache, "attn_k")
    positions = pos
    sp = params["shared_attn"]
    na = n_attn_blocks(cfg)

    def body(carry, xs):
        h, kc, vc = carry
        lp, state, conv, idx = xs
        h, (state, conv) = ssm.mamba_block_decode(lp, cfg, h, state, conv)

        def attn_branch(args):
            h_, kc_, vc_ = args
            j = jnp.minimum(idx // cfg.attn_every, na - 1)
            hh = L.apply_norm(sp["ln1"], h_, cfg.norm)
            q, k, v = L.attn_qkv(sp["attn"], cfg, hh[:, None, :], positions[:, None])
            q = L.constrain_q_decode(cfg, q[:, 0])
            kj = jax.lax.dynamic_slice_in_dim(kc_, j, 1, axis=0)[0]
            vj = jax.lax.dynamic_slice_in_dim(vc_, j, 1, axis=0)[0]
            kj = update(kj, k)
            vj = update(vj, v)
            attn = attend(q, kj, vj)
            h2 = h_ + L.attn_out(sp["attn"], h_.dtype, attn)
            hh2 = L.apply_norm(sp["ln2"], h2, cfg.norm)
            h2 = h2 + L.apply_mlp(sp["mlp"], hh2)
            kc_ = jax.lax.dynamic_update_slice_in_dim(kc_, kj[None], j, axis=0)
            vc_ = jax.lax.dynamic_update_slice_in_dim(vc_, vj[None], j, axis=0)
            return h2, kc_, vc_

        h, kc, vc = jax.lax.cond(
            (idx + 1) % cfg.attn_every == 0, attn_branch,
            lambda args: args, (h, kc, vc))
        return (h, kc, vc), (state, conv)

    (x, kc, vc), (states, convs) = jax.lax.scan(
        body, (x, cache["attn_k"], cache["attn_v"]),
        (params["layers"], cache["ssm"], cache["conv"],
         jnp.arange(cfg.num_layers)))
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.unembed(params["embed"], x, cfg)
    return logits, L.carry_cache_meta(
        {"ssm": states, "conv": convs, "attn_k": kc, "attn_v": vc,
         "pos": pos + 1}, cache)
