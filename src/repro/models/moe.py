"""Top-k mixture-of-experts with capacity-based scatter dispatch.

TPU-native adaptation: instead of a GShard one-hot dispatch einsum (which
materializes a (tokens, experts, capacity) tensor) we compute per-token slot
positions with a cumsum over expert one-hots, then ``scatter`` tokens into an
``(experts, capacity, d_model)`` buffer, run a grouped expert matmul, and
gather back. Overflowing tokens are dropped (standard capacity-factor
semantics); dropped tokens pass through on the residual path.

Expert weights are sharded on the ``expert`` axis when divisible by the mesh
``model`` axis (phi3.5: 16 experts), otherwise the per-expert ffn dim shards
(granite: 40 experts, d_ff=512 → ffn shards 16-way).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import ParamDef


# Default train-time capacity factor; tests may raise it (cf >= E/k
# guarantees zero drops). Read at call time so it is monkeypatch-able.
CAPACITY_FACTOR = 1.25


def moe_plan(cfg) -> dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        # Megatron-style expert tensor-parallelism: the per-expert ffn dim
        # shards; the expert dim stays replicated. Expert-dim sharding makes
        # the token scatter a cross-device reshard that XLA SPMD handles
        # with involuntary full rematerialization (see DESIGN.md §7) — ffn
        # sharding keeps dispatch local to the batch shard and works for
        # non-divisible expert counts (granite: 40 experts on 16-way mesh).
        "router": ParamDef((d, e), ("embed", None)),
        "wi_gate": ParamDef((e, d, ff), (None, "embed", "mlp")),
        "wi_up": ParamDef((e, d, ff), (None, "embed", "mlp")),
        "wo": ParamDef((e, ff, d), (None, "mlp", "embed")),
    }


def capacity_for(tokens: int, cfg, capacity_factor: float = 1.25) -> int:
    c = int(tokens * cfg.experts_per_token * capacity_factor / cfg.num_experts)
    return max(8, -(-c // 8) * 8)          # round up to multiple of 8


def _dispatch(p, cfg, x3, cap: int):
    """Batched grouped dispatch. x3: (b, t, d) — one dispatch group per
    batch row; buffers carry the batch sharding (GShard groups).

    Returns (y (b,t,d), probs (b,t,e), gate_i (b,t,k), dropped (b,t*k)).
    """
    from repro.utils.sharding import maybe_constrain
    b, t, d = x3.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    ff = cfg.d_ff

    logits = jnp.einsum("btd,de->bte", x3.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_i = jax.lax.top_k(probs, k)                     # (b, t, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # slot position of each (token, choice) within its expert's capacity
    flat_e = gate_i.reshape(b, t * k)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)          # (b, tk, e)
    pos = jnp.cumsum(onehot, axis=1) - onehot                    # exclusive
    flat_pos = jnp.take_along_axis(pos, flat_e[..., None], axis=2)[..., 0]

    tok_idx = jnp.arange(t * k) // k
    xk = jnp.take(x3, tok_idx, axis=1)                           # (b, tk, d)
    bidx = jnp.broadcast_to(jnp.arange(b)[:, None], (b, t * k))

    grp_spec = ("batch", None, None, None)
    buffer = maybe_constrain(jnp.zeros((b, e, cap, d), x3.dtype), *grp_spec)
    # out-of-capacity positions fall off the end: scatter mode "drop"
    buffer = buffer.at[bidx, flat_e, flat_pos].add(xk, mode="drop")
    buffer = maybe_constrain(buffer, *grp_spec)

    g = jnp.einsum("becd,edf->becf", buffer, p["wi_gate"].astype(x3.dtype))
    u = jnp.einsum("becd,edf->becf", buffer, p["wi_up"].astype(x3.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x3.dtype) * u
    out = jnp.einsum("becf,efd->becd", h, p["wo"].astype(x3.dtype))
    out = maybe_constrain(out, *grp_spec)

    # gather back; dropped slots read as zero
    y_flat = out.at[bidx, flat_e, flat_pos].get(mode="fill", fill_value=0)
    dropped = flat_pos >= cap
    y_flat = jnp.where(dropped[..., None], 0, y_flat)
    # combine in compute dtype: fp32 here makes every backward temp fp32
    # (2x the activation-memory bill for <0.1% loss effect)
    y = (y_flat.reshape(b, t, k, d)
         * gate_w[..., None].astype(y_flat.dtype)).sum(axis=2)
    return y.astype(x3.dtype), probs, gate_i, dropped


def apply_moe(p, cfg, x, *, capacity_factor: float = None):
    """x: (..., d_model) -> (same shape, aux dict).

    Dispatch is grouped by batch row for sequence inputs (GShard groups):
    each row dispatches into its own (E, C_row, d) buffer slice, so buffers
    inherit the batch sharding instead of replicating — without this, a
    non-divisible expert count (granite's 40 on a 16-way mesh) replicates a
    multi-GB dispatch buffer on every device.
    """
    from repro.utils.sharding import maybe_constrain
    if capacity_factor is None:
        capacity_factor = CAPACITY_FACTOR
    orig_shape = x.shape
    d = orig_shape[-1]
    e = cfg.num_experts

    if x.ndim == 3 and x.shape[1] >= 256:
        cap = capacity_for(x.shape[1], cfg, capacity_factor)
        x3 = maybe_constrain(x, "batch", None, None)
    else:
        cap = capacity_for(int(jnp.size(x)) // d, cfg, capacity_factor)
        x3 = x.reshape(1, -1, d)
    y, probs, gate_i, dropped = _dispatch(p, cfg, x3, cap)

    # GShard/Switch load-balance auxiliary loss
    me = probs.reshape(-1, e).mean(axis=0)
    ce = jax.nn.one_hot(gate_i.reshape(-1, cfg.experts_per_token)[:, 0], e,
                        dtype=jnp.float32).mean(axis=0)
    aux = {
        "load_balance_loss": e * jnp.sum(me * ce),
        "dropped_fraction": dropped.astype(jnp.float32).mean(),
    }
    return y.reshape(orig_shape).astype(x.dtype), aux
