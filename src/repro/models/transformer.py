"""Dense / MoE / early-fusion-VLM decoder-only transformer.

One code path serves olmo-1b, yi-9b, qwen2-0.5b, deepseek-7b (dense),
phi3.5-moe + granite-moe (``cfg.num_experts > 0``) and chameleon-34b
(early-fusion: VQ image tokens share the vocab, so the backbone is identical).

Layers are ``jax.lax.scan``-ned over stacked params: HLO size and compile
time are depth-independent.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.moe import apply_moe, moe_plan


# --------------------------------------------------------------------------
# plans
# --------------------------------------------------------------------------
def layer_plan(cfg) -> dict:
    p = {
        "ln1": L.norm_plan(cfg.d_model, cfg.norm),
        "attn": L.attn_plan(cfg),
        "ln2": L.norm_plan(cfg.d_model, cfg.norm),
    }
    if cfg.num_experts:
        p["moe"] = moe_plan(cfg)
    else:
        p["mlp"] = L.mlp_plan(cfg)
    return p


def plan(cfg) -> dict:
    return {
        "embed": L.embed_plan(cfg),
        "layers": L.stack_plan(layer_plan(cfg), cfg.num_layers),
        "final_norm": L.norm_plan(cfg.d_model, cfg.norm),
    }


def init(key, cfg, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "embed": L.init_from_plan(k1, L.embed_plan(cfg), dtype),
        "layers": L.init_stacked(k2, layer_plan(cfg), cfg.num_layers, dtype),
        "final_norm": L.init_from_plan(k3, L.norm_plan(cfg.d_model, cfg.norm), dtype),
    }


# --------------------------------------------------------------------------
# full-sequence forward (training / prefill compute)
# --------------------------------------------------------------------------
def _block(cfg, lp, x, positions, window: int):
    h = L.apply_norm(lp["ln1"], x, cfg.norm)
    q, k, v = L.attn_qkv(lp["attn"], cfg, h, positions)
    attn = L.cp_attention(cfg, q, k, v, causal=True, window=window)
    x = x + L.attn_out(lp["attn"], x.dtype, attn)

    h = L.apply_norm(lp["ln2"], x, cfg.norm)
    if cfg.num_experts:
        y, aux = apply_moe(lp["moe"], cfg, h)
    else:
        y, aux = L.apply_mlp(lp["mlp"], h), {"load_balance_loss": jnp.float32(0.0),
                                             "dropped_fraction": jnp.float32(0.0)}
    return x + y, aux


def forward(params, cfg, tokens, *, remat: bool = False) -> Tuple[jax.Array, dict]:
    """tokens: (B, S) int32 -> logits (B, S, V) plus aux losses."""
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed_tokens(params["embed"], tokens, dtype)
    positions = jnp.arange(tokens.shape[1])[None, :]
    window = cfg.sliding_window

    from repro.utils.sharding import maybe_constrain

    def body(carry, lp):
        y, aux = _block(cfg, lp, carry, positions, window)
        # Megatron-SP style: the remat-saved per-layer carry is sharded on
        # d_model; XLA inserts AG/RS around the attention/mlp einsums.
        y = maybe_constrain(y, "batch", None, "act_embed")
        return y, aux

    if remat:
        body = jax.checkpoint(body)
    x, auxes = jax.lax.scan(body, x, params["layers"])
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.unembed(params["embed"], x, cfg)
    aux = jax.tree.map(jnp.mean, auxes)
    return logits, aux


# --------------------------------------------------------------------------
# KV-cache serving
# --------------------------------------------------------------------------
# cache leaves that live in the shared page pool when the cache is paged
# (everything else — here just "pos" — stays per-row)
PAGED_KEYS = ("k", "v")


def cache_plan(cfg, batch: int, cache_len: int) -> dict:
    lcfg = (cfg.num_layers, batch, cache_len, cfg.num_kv_heads, cfg.resolved_head_dim)
    spec = L.kv_cache_spec(cfg)
    return {
        "k": L.ParamDef(lcfg, spec, "zeros"),
        "v": L.ParamDef(lcfg, spec, "zeros"),
        # per-sequence positions/lengths: ragged batches + slot reuse
        "pos": L.ParamDef((batch,), None, "zeros"),
    }


def init_cache(cfg, batch: int, cache_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    cp = cache_plan(cfg, batch, cache_len)
    return {
        "k": jnp.zeros(cp["k"].shape, dtype),
        "v": jnp.zeros(cp["v"].shape, dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def paged_cache_plan(cfg, batch: int, num_pages: int, page_size: int,
                     max_pages: int) -> dict:
    """Block-table paged layout: K/V live in a shared (num_pages,
    page_size) pool; each row maps logical pages to physical via its
    ``block_tables`` row (see ``repro.serving.kv_cache``)."""
    lcfg = (cfg.num_layers, num_pages, page_size, cfg.num_kv_heads,
            cfg.resolved_head_dim)
    spec = L.paged_kv_cache_spec(cfg)
    return {
        "k": L.ParamDef(lcfg, spec, "zeros"),
        "v": L.ParamDef(lcfg, spec, "zeros"),
        "block_tables": L.ParamDef((batch, max_pages), None, "zeros"),
        "pos": L.ParamDef((batch,), None, "zeros"),
    }


def init_paged_cache(cfg, batch: int, num_pages: int, page_size: int,
                     max_pages: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    cp = paged_cache_plan(cfg, batch, num_pages, page_size, max_pages)
    return {
        "k": jnp.zeros(cp["k"].shape, dtype),
        "v": jnp.zeros(cp["v"].shape, dtype),
        "block_tables": jnp.zeros((batch, max_pages), jnp.int32),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def prefill(params, cfg, tokens, cache_len: int):
    """Run the prompt through the model, building a fresh KV cache.

    Returns logits of the *last* position (B, V) and the cache.
    """
    dtype = jnp.dtype(cfg.dtype)
    b, s = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, dtype)
    positions = jnp.arange(s)[None, :]
    window = cfg.sliding_window

    def body(carry, lp):
        h = L.apply_norm(lp["ln1"], carry, cfg.norm)
        q, k, v = L.attn_qkv(lp["attn"], cfg, h, positions)
        attn = L.cp_attention(cfg, q, k, v, causal=True, window=window)
        x1 = carry + L.attn_out(lp["attn"], carry.dtype, attn)
        h2 = L.apply_norm(lp["ln2"], x1, cfg.norm)
        if cfg.num_experts:
            y, _ = apply_moe(lp["moe"], cfg, h2)
        else:
            y = L.apply_mlp(lp["mlp"], h2)
        # write last ``cache_len`` keys into the (possibly ring) cache
        if s <= cache_len:
            k_out = jnp.zeros((b, cache_len) + k.shape[2:], k.dtype).at[:, :s].set(k)
            v_out = jnp.zeros((b, cache_len) + v.shape[2:], v.dtype).at[:, :s].set(v)
        else:  # sliding-window cache smaller than prompt: keep the tail
            k_out, v_out = k[:, s - cache_len:], v[:, s - cache_len:]
        return x1 + y, (k_out, v_out)

    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    x = L.apply_norm(params["final_norm"], x[:, -1], cfg.norm)
    logits = L.unembed(params["embed"], x, cfg)
    new_cache = {"k": ks, "v": vs, "pos": jnp.full((b,), s, jnp.int32)}
    return logits, new_cache


def prefill_packed(params, cfg, packed, max_seg_len: int):
    """Packed ragged prefill: a whole admission batch of variable-length
    prompts concatenated into ONE (1, total_tokens) row.

    ``packed`` carries ``tokens`` (1, T), ``seg_ids`` (T,) non-decreasing
    int32 (padding tokens = S), ``seg_starts``/``seg_lens`` (S,). Returns
    (per-segment last-token logits (S, V), a PACKED cache: per-token K/V
    (layers, T, KV, D) in packed order — the engine scatters each
    segment's tokens straight into its slot's pages — and ``pos`` =
    seg_lens). Unlike ``prefill`` there is no padding to a common prompt
    length: every non-attention op runs on sum(lens) tokens, and the
    attention is segment-masked (see ``layers.packed_prefill_attention``).

    MoE caveat: expert-capacity dropping is computed per dispatch group,
    so a packed MoE prefill can drop different tokens than per-request
    prefills of the same prompts (dense families are bit-exact)."""
    dtype = jnp.dtype(cfg.dtype)
    tokens = packed["tokens"]
    seg_ids, seg_starts = packed["seg_ids"], packed["seg_starts"]
    seg_lens = packed["seg_lens"]
    b, t = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, dtype)
    pos = L.packed_positions(seg_ids, seg_starts)
    positions = pos[None, :]
    window = cfg.sliding_window

    def body(carry, lp):
        h = L.apply_norm(lp["ln1"], carry, cfg.norm)
        q, k, v = L.attn_qkv(lp["attn"], cfg, h, positions)
        attn = L.packed_prefill_attention(q, k, v, seg_ids, pos,
                                          seg_starts, seg_lens,
                                          row_len=max_seg_len, window=window)
        x1 = carry + L.attn_out(lp["attn"], carry.dtype, attn)
        h2 = L.apply_norm(lp["ln2"], x1, cfg.norm)
        if cfg.num_experts:
            y, _ = apply_moe(lp["moe"], cfg, h2)
        else:
            y = L.apply_mlp(lp["mlp"], h2)
        return x1 + y, (k[0], v[0])

    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    last = jnp.clip(seg_starts + seg_lens - 1, 0, t - 1)
    xl = L.apply_norm(params["final_norm"], x[0, last], cfg.norm)
    logits = L.unembed(params["embed"], xl, cfg)
    return logits, {"k": ks, "v": vs, "pos": seg_lens.astype(jnp.int32)}


def prefill_chunk(params, cfg, packed, cache, max_seg_len: int):
    """Incremental chunked prefill: score a packed batch of NEW token
    segments against the K/V their sequences already hold in the paged
    pool — each chunk token attends its slot's resident history (through
    its block-table row) plus the chunk's earlier tokens causally, so a
    continuation costs O(chunk) attention instead of recomputing the
    whole prefix. The same dispatch powers speculative-decoding
    verification: the k draft tokens are the chunk, and every position's
    argmax is returned so the engine can score the draft on host.

    ``packed`` carries the usual ``tokens`` (1, T) / ``seg_ids`` (T,) /
    ``seg_starts``/``seg_lens`` (S,) plus ``seg_slots`` (S,) — the cache
    row each segment's history lives in (padding = n_rows, clamped) —
    and ``hist_lens`` (S,) — tokens already resident per segment
    (padding = 0). ``cache`` is the engine's paged slot cache, READ
    ONLY: (layers, P, page_size, KV, D) pools + (n_rows, max_pages)
    ``block_tables``. Returns (per-segment last-position logits (S, V),
    per-token argmax (T,) int32, a packed cache {k/v: (layers, T, KV, D),
    pos: hist + seg_lens}) — the engine scatters the chunk's K/V into
    pages afterwards via the same segment scatter admissions use.

    On the jnp fallback every chunk position runs the exact masked-decode
    attention body (see ``layers._masked_chunk_attention``), so chunk
    logits are bit-identical to the decode steps they replace."""
    dtype = jnp.dtype(cfg.dtype)
    tokens = packed["tokens"]
    seg_ids, seg_starts = packed["seg_ids"], packed["seg_starts"]
    seg_lens = packed["seg_lens"]
    seg_slots = packed["seg_slots"]
    hist = jnp.asarray(packed["hist_lens"], jnp.int32)
    b, t = tokens.shape
    s = seg_starts.shape[0]
    x = L.embed_tokens(params["embed"], tokens, dtype)
    local = L.packed_positions(seg_ids, seg_starts)
    hist_t = jnp.where(seg_ids < s, hist[jnp.minimum(seg_ids, s - 1)], 0)
    positions = (local + hist_t)[None, :]
    n_rows = cache["block_tables"].shape[0]
    tables = cache["block_tables"][jnp.clip(seg_slots, 0, n_rows - 1)]

    def body(carry, xs):
        lp, kp, vp = xs
        h = L.apply_norm(lp["ln1"], carry, cfg.norm)
        q, k, v = L.attn_qkv(lp["attn"], cfg, h, positions)
        qr = L.segments_to_rows(q[0], seg_starts, seg_lens, max_seg_len)
        kr = L.segments_to_rows(k[0], seg_starts, seg_lens, max_seg_len)
        vr = L.segments_to_rows(v[0], seg_starts, seg_lens, max_seg_len)
        ar = L.paged_chunk_attention(qr, kp, vp, kr, vr, tables, hist,
                                     seg_lens)
        attn = L.rows_to_segments(ar, seg_ids, local)[None]
        x1 = carry + L.attn_out(lp["attn"], carry.dtype, attn)
        h2 = L.apply_norm(lp["ln2"], x1, cfg.norm)
        if cfg.num_experts:
            y, _ = apply_moe(lp["moe"], cfg, h2)
        else:
            y = L.apply_mlp(lp["mlp"], h2)
        return x1 + y, (k[0], v[0])

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"]))
    xl = L.apply_norm(params["final_norm"], x[0], cfg.norm)
    logits_all = L.unembed(params["embed"], xl, cfg)           # (T, V)
    tok_argmax = jnp.argmax(logits_all, -1).astype(jnp.int32)
    last = jnp.clip(seg_starts + seg_lens - 1, 0, t - 1)
    seg_logits = logits_all[last]
    return seg_logits, tok_argmax, {
        "k": ks, "v": vs, "pos": (hist + seg_lens).astype(jnp.int32)}


def decode_step(params, cfg, token, cache) -> Tuple[jax.Array, dict]:
    """token: (B,) int32; one autoregressive step against the KV cache.

    ``cache["pos"]`` is a per-sequence (B,) vector: each row writes its new
    K/V at its own ring slot and attends only up to its own length, so a
    mixed-length (ragged) batch never pays for the longest row and vacant
    continuous-batching slots cost nothing but the row's lane.

    The cache is threaded through the layer scan as CARRY and updated with
    dynamic_update_slice at the layer index — a scan-over-(xs -> ys) cache
    double-buffers (measured +2x cache HBM on deepseek decode_32k); the
    carried buffer updates in place and aliases with the donated input.

    A cache built by ``init_paged_cache`` (it carries ``block_tables``; a
    static pytree property, so this is a trace-time branch, not a runtime
    one) stores K/V in the shared page pool instead: each row writes its
    new entry at (block_tables[b, pos // page_size], pos % page_size) and
    attends through ``paged_decode_attention``. Same scan-carry structure,
    same per-sequence raggedness — only the storage indexing differs.
    """
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed_tokens(params["embed"], token, dtype)          # (B, d)
    pos = jnp.broadcast_to(jnp.asarray(cache["pos"], jnp.int32), token.shape)
    positions = pos
    update, attend, _ = L.decode_index(pos, cache, "k")

    def body(carry, xs):
        h0, kfull, vfull = carry
        lp, idx = xs
        h = L.apply_norm(lp["ln1"], h0, cfg.norm)
        q, k, v = L.attn_qkv(lp["attn"], cfg, h[:, None, :], positions[:, None])
        q = L.constrain_q_decode(cfg, q[:, 0])                 # (B, H, hd)
        kc = jax.lax.dynamic_slice_in_dim(kfull, idx, 1, axis=0)[0]
        vc = jax.lax.dynamic_slice_in_dim(vfull, idx, 1, axis=0)[0]
        kc = update(kc, k)
        vc = update(vc, v)
        attn = attend(q, kc, vc, window=cfg.sliding_window)
        x1 = h0 + L.attn_out(lp["attn"], h0.dtype, attn)
        h2 = L.apply_norm(lp["ln2"], x1, cfg.norm)
        if cfg.num_experts:
            y, _ = apply_moe(lp["moe"], cfg, h2)
        else:
            y = L.apply_mlp(lp["mlp"], h2)
        kfull = jax.lax.dynamic_update_slice_in_dim(kfull, kc[None], idx, axis=0)
        vfull = jax.lax.dynamic_update_slice_in_dim(vfull, vc[None], idx, axis=0)
        return (x1 + y, kfull, vfull), None

    (x, ks, vs), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"]),
        (params["layers"], jnp.arange(cfg.num_layers)))
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.unembed(params["embed"], x, cfg)
    return logits, L.carry_cache_meta({"k": ks, "v": vs, "pos": pos + 1},
                                      cache)
