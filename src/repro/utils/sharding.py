"""Logical-axis → mesh-axis sharding rules with divisibility fallback.

Params declare *logical* axes (e.g. ``("vocab", "embed")``); a rule table maps
logical axes to mesh axes. A logical axis only shards if the tensor dim is
divisible by the mesh axis size — otherwise it silently falls back to
replication (needed for e.g. qwen2's 14 heads or whisper's 51865 vocab on a
16-way ``model`` axis).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# default logical → mesh-axis rules ("model" = tensor-parallel axis)
DEFAULT_RULES = {
    "batch": ("data",),          # expanded to ("pod","data") on multi-pod meshes
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": ("model",),      # fallback when head count is non-divisible
    "kv_seq": ("model",),        # sequence-sharded KV cache (GQA fallback)
    "mlp": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "ssm_inner": ("model",),
    "ssm_heads": ("model",),
    "embed": (),
    "act_embed": ("model",),     # Megatron-SP: shard *activation* d_model
    "stack": (),                 # scanned layer dim — never sharded
    None: (),
}


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes that carry the global batch."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def resolve_spec(
    logical: Optional[Sequence[Optional[str]]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Optional[dict] = None,
) -> P:
    """Map a tuple of logical axis names to a PartitionSpec for ``mesh``."""
    if logical is None:
        return P()
    rules = rules or DEFAULT_RULES
    out = []
    used: set = set()
    for dim, name in zip(shape, logical):
        axes = rules.get(name, ())
        if name == "batch":
            axes = batch_axes(mesh)
        picked: Tuple[str, ...] = ()
        size = 1
        for ax in axes:
            if ax in mesh.axis_names and ax not in used:
                size *= mesh.shape[ax]
                picked += (ax,)
        if picked and size and dim % size == 0:
            used.update(picked)
            out.append(picked if len(picked) > 1 else picked[0])
        else:
            out.append(None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_specs(plan_tree, mesh: Mesh) -> "jax.tree_util.PyTreeDef":
    """Map a tree of ParamDef → tree of PartitionSpec (see models.layers)."""
    return jax.tree.map(
        lambda pd: resolve_spec(pd.spec, pd.shape, mesh),
        plan_tree,
        is_leaf=lambda x: hasattr(x, "spec") and hasattr(x, "shape"),
    )


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def constrain(x, mesh: Mesh, *logical: Optional[str]):
    """with_sharding_constraint via logical names (inside jit under mesh)."""
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, resolve_spec(logical, x.shape, mesh))
    )


def active_mesh() -> Optional[Mesh]:
    """The mesh from the enclosing ``with mesh:`` context, if any."""
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:  # noqa: BLE001 — jax internals may move
        return None


def maybe_constrain(x, *logical: Optional[str]):
    """Sharding constraint iff compiling under a mesh context (the dry-run
    / production path); no-op for single-device smoke tests."""
    mesh = active_mesh()
    if mesh is None:
        return x
    return constrain(x, mesh, *logical)
