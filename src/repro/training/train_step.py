"""Training step: causal LM loss + MoE aux loss, remat'd scanned layers."""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.registry import ModelAPI
from repro.training.optimizer import AdamW, AdamWState


def _chunked_ce(logits, labels, n_chunks: int = 16):
    """Cross-entropy with the fp32 softmax materialized one sequence-chunk
    at a time (checkpointed) — avoids 4 full fp32 (B,S,V) buffers."""
    b, s, v = logits.shape
    while s % n_chunks:
        n_chunks //= 2
    cs = s // n_chunks

    @jax.checkpoint
    def chunk(args):
        lg, lb = args                                # (B, cs, V), (B, cs)
        logp = jax.nn.log_softmax(lg.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, lb[..., None], axis=-1)[..., 0]
        mask = (lb >= 0).astype(jnp.float32)
        return (nll * mask).sum(), mask.sum()

    # chunk the SEQUENCE axis only — batch/vocab shardings stay intact
    nll_sum, cnt = jax.lax.map(
        chunk, (logits.reshape(b, n_chunks, cs, v).swapaxes(0, 1),
                labels.reshape(b, n_chunks, cs).swapaxes(0, 1)))
    return nll_sum.sum() / jnp.maximum(cnt.sum(), 1.0)


def lm_loss(api: ModelAPI, params, batch, *, remat: bool = True,
            aux_weight: float = 0.01) -> Tuple[jax.Array, Dict]:
    logits, aux = api.forward(params, batch, remat=remat)
    loss = _chunked_ce(logits, batch["labels"])
    total = loss + aux_weight * aux["load_balance_loss"]
    metrics = {"loss": loss, "aux_loss": aux["load_balance_loss"],
               "dropped_fraction": aux["dropped_fraction"]}
    return total, metrics


def make_train_step(api: ModelAPI, opt: AdamW, *, remat: bool = True,
                    aux_weight: float = 0.01):
    """Returns train_step(params, opt_state, batch) -> (params, state, metrics)."""

    def train_step(params, opt_state: AdamWState, batch):
        (_, metrics), grads = jax.value_and_grad(
            lambda p: lm_loss(api, p, batch, remat=remat, aux_weight=aux_weight),
            has_aux=True)(params)
        params, opt_state, opt_metrics = opt.update(grads, opt_state, params)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step


def make_eval_step(api: ModelAPI):
    def eval_step(params, batch):
        _, metrics = lm_loss(api, params, batch, remat=False)
        return metrics
    return eval_step
