"""AdamW in pure JAX (no optax in this environment)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    max_grad_norm: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda t: jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), t)
        return AdamWState(jnp.zeros((), jnp.int32), zeros(params), zeros(params))

    def schedule(self, step) -> jax.Array:
        warm = jnp.minimum(1.0, (step + 1) / max(1, self.warmup_steps))
        prog = jnp.clip((step - self.warmup_steps)
                        / max(1, self.total_steps - self.warmup_steps), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return self.lr * warm * (0.1 + 0.9 * cos)

    def update(self, grads, state: AdamWState, params):
        # global-norm clip
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)) + 1e-12)
        scale = jnp.minimum(1.0, self.max_grad_norm / gnorm)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

        step = state.step + 1
        lr = self.schedule(state.step)
        m = jax.tree.map(lambda mm, g: self.b1 * mm + (1 - self.b1) * g,
                         state.m, grads)
        v = jax.tree.map(lambda vv, g: self.b2 * vv + (1 - self.b2) * g * g,
                         state.v, grads)
        bc1 = 1 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1 - self.b2 ** step.astype(jnp.float32)

        def upd(p, mm, vv):
            mhat = mm / bc1
            vhat = vv / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            # decoupled weight decay on matrices only (ndim >= 2)
            if p.ndim >= 2:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamWState(step, m, v), {"grad_norm": gnorm, "lr": lr}
