"""Flat msgpack checkpointing for arbitrary param/opt pytrees."""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        flat[key] = {
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
            "data": arr.tobytes(),
        }
    return flat


def save(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "wb") as f:
        f.write(msgpack.packb(_flatten(tree)))


def load(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shapes/dtypes must match)."""
    with open(path, "rb") as f:
        flat = msgpack.unpackb(f.read())
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        rec = flat[key]
        arr = np.frombuffer(rec["data"], dtype=rec["dtype"]).reshape(rec["shape"])
        expect = jnp.shape(leaf)
        assert tuple(arr.shape) == tuple(expect), (key, arr.shape, expect)
        leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)
